"""Layer-shape tables for the paper's four evaluation CNNs (Section V-A3)
at CIFAR-10 resolution (32x32, B=1 edge inference) — feeds the Figs 12-13
system-level benchmark through the dataflow/tiling engine.

Depthwise convolutions are modeled as K=channels, C=1 (no channel
reduction); pointwise as FY=FX=1.
"""

from __future__ import annotations

from typing import List

from repro.core.dataflow import LayerShape


def _conv(name, k, c, hw, f=3, b=1):
    return LayerShape(name, B=b, K=k, C=c, OY=hw, OX=hw, FY=f, FX=f)


def _fc(name, k, c, b=1):
    return LayerShape(name, B=b, K=k, C=c, OY=1, OX=1)


def resnet18() -> List[LayerShape]:
    layers = [_conv("conv1", 64, 3, 32)]
    spec = [(64, 32, 2), (128, 16, 2), (256, 8, 2), (512, 4, 2)]
    in_c = 64
    for k, hw, n_blocks in spec:
        for b in range(n_blocks):
            layers.append(_conv(f"l{k}b{b}a", k, in_c, hw))
            layers.append(_conv(f"l{k}b{b}b", k, k, hw))
            if in_c != k:
                layers.append(LayerShape(f"l{k}b{b}s", 1, k, in_c, hw, hw, 1, 1))
            in_c = k
    layers.append(_fc("fc", 10, 512))
    return layers


def vgg16() -> List[LayerShape]:
    cfg = [(64, 32, 2), (128, 16, 2), (256, 8, 3), (512, 4, 3), (512, 2, 3)]
    layers = []
    in_c = 3
    for k, hw, reps in cfg:
        for r in range(reps):
            layers.append(_conv(f"c{k}_{r}@{hw}", k, in_c, hw))
            in_c = k
    layers += [_fc("fc1", 4096, 512 * 1 * 1), _fc("fc2", 4096, 4096),
               _fc("fc3", 10, 4096)]
    return layers


def alexnet() -> List[LayerShape]:
    return [
        _conv("conv1", 64, 3, 16, f=5),
        _conv("conv2", 192, 64, 8, f=5),
        _conv("conv3", 384, 192, 4),
        _conv("conv4", 256, 384, 4),
        _conv("conv5", 256, 256, 4),
        _fc("fc1", 4096, 256 * 2 * 2),
        _fc("fc2", 4096, 4096),
        _fc("fc3", 10, 4096),
    ]


def mobilenet_v2() -> List[LayerShape]:
    """Inverted residuals: expand (1x1) -> depthwise 3x3 -> project (1x1)."""
    layers = [_conv("conv1", 32, 3, 32)]
    # (expansion t, out c, repeats, spatial)
    spec = [(1, 16, 1, 32), (6, 24, 2, 16), (6, 32, 3, 16), (6, 64, 4, 8),
            (6, 96, 3, 8), (6, 160, 3, 4), (6, 320, 1, 4)]
    in_c = 32
    for t, c_out, reps, hw in spec:
        for r in range(reps):
            mid = in_c * t
            if t != 1:
                layers.append(LayerShape(f"exp{c_out}_{r}", 1, mid, in_c,
                                         hw, hw, 1, 1))
            layers.append(LayerShape(f"dw{c_out}_{r}", 1, mid, 1, hw, hw, 3, 3))
            layers.append(LayerShape(f"prj{c_out}_{r}", 1, c_out, mid,
                                     hw, hw, 1, 1))
            in_c = c_out
    layers.append(LayerShape("head", 1, 1280, 320, 4, 4, 1, 1))
    layers.append(_fc("fc", 10, 1280))
    return layers


NETWORKS = {
    "resnet18": resnet18,
    "mobilenet_v2": mobilenet_v2,
    "vgg16": vgg16,
    "alexnet": alexnet,
}

# measured value sparsity of activations per network (paper Section IV-B3:
# MobileNetV2 has near-zero value sparsity; others significant)
ACT_VALUE_SPARSITY = {"resnet18": 0.45, "mobilenet_v2": 0.05,
                      "vgg16": 0.55, "alexnet": 0.6}
BIT_SPARSITY = {"resnet18": 0.65, "mobilenet_v2": 0.62,
                "vgg16": 0.66, "alexnet": 0.67}
