"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (kv=16) per-expert d_ff=1408,
MoE 64 experts top-6 (Moonlight)  [hf:moonshotai/Moonlight-16B-A3B]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe", num_layers=48, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=163840,
    head_dim=128, ffn_type="swiglu", rope_theta=1e6,
    num_experts=64, top_k=6,
)
