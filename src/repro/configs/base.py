"""Architecture & workload-shape configuration system.

Every assigned architecture is a frozen ``ArchConfig``; every workload shape
is a ``ShapeConfig``.  ``runnable_cells()`` yields the (arch x shape) grid
with the assignment's applicability rules applied (long_500k only for
sub-quadratic families; encoder-only would skip decode — all our archs have
decoders).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

VOCAB_PAD_MULTIPLE = 256  # divisible by every mesh (data x model) product


def pad_vocab(v: int) -> int:
    return -(-v // VOCAB_PAD_MULTIPLE) * VOCAB_PAD_MULTIPLE


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    ffn_type: str = "swiglu"          # swiglu | gelu
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # MoE
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    attn_every: int = 0               # zamba2: shared attn applied every N layers
    # RWKV
    rwkv_head_dim: int = 64
    # encoder-decoder
    encoder_layers: int = 0
    # VLM (M-RoPE)
    mrope_sections: Tuple[int, ...] = ()
    # numerics / BitParticle backend: bf16 | qat | bp_exact | bp_approx
    matmul_mode: str = "bf16"
    # quantized-matmul execution backend: auto | xla | kernel |
    # kernel_interpret.  "auto" routes bp_* contractions through the fused
    # Pallas kernel on TPU and the pure-XLA formulation elsewhere;
    # "kernel_interpret" forces the kernel in interpret mode (CPU oracle).
    matmul_backend: str = "auto"
    # int8 KV cache with per-token-per-head scales (serving memory term)
    kv_cache_int8: bool = False

    # ---------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // max(self.num_heads, 1)

    @property
    def vocab_padded(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        kw = dict(
            num_layers=min(self.num_layers, 4 if not self.attn_every else 2 * self.attn_every),
            d_model=128,
            num_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads,
                                    4 * self.num_kv_heads // max(self.num_heads, 1), 4)),
            d_ff=256,
            vocab_size=512,
            head_dim=32,
        )
        if self.num_experts:
            kw.update(num_experts=min(self.num_experts, 8),
                      top_k=min(self.top_k, 2), d_ff=64)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=32)
        if self.family == "ssm":
            kw.update(rwkv_head_dim=32, num_heads=4)
        if self.encoder_layers:
            kw.update(encoder_layers=2)
        if self.mrope_sections:
            kw.update(mrope_sections=(4, 6, 6))  # sums to head_dim/2 = 16
        return self.replace(**kw)

    # parameter-count estimate (for 6*N*D model FLOPs)
    def param_count(self, *, active_only: bool = False) -> int:
        d, l = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) + self.num_heads * hd * d
        if self.ffn_type == "swiglu":
            ffn_dense = 3 * d * self.d_ff
        else:
            ffn_dense = 2 * d * self.d_ff
        if self.num_experts:
            n_exp = self.top_k if active_only else self.num_experts
            ffn = n_exp * ffn_dense + d * self.num_experts  # + router
        else:
            ffn = ffn_dense
        if self.family == "ssm":                      # rwkv6 block
            blk = 5 * d * d + 2 * d * self.d_ff       # time-mix + channel-mix
        elif self.family == "hybrid":                 # mamba2 + shared attn amortized
            d_in = 2 * d
            blk = d * (2 * d_in + 2 * self.ssm_state) + d_in * d
            n_attn = l // max(self.attn_every, 1)
            blk += (attn + ffn) * n_attn / max(l, 1)
        else:
            blk = attn + ffn
        # 6ND convention: the LM head participates in matmul FLOPs, the
        # embedding lookup does not — count the vocab matrix once
        total = l * blk + self.vocab_padded * d
        if self.encoder_layers:
            total += self.encoder_layers * (attn + ffn)   # encoder stack
            total += l * (d * hd * (self.num_heads + 2 * self.num_kv_heads)
                          + self.num_heads * hd * d)      # cross-attention
        return int(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = (
    "phi3-medium-14b", "granite-34b", "qwen2-1.5b", "qwen2-7b", "qwen2-vl-7b",
    "rwkv6-7b", "zamba2-2.7b", "moonshot-v1-16b-a3b", "granite-moe-1b-a400m",
    "seamless-m4t-medium",
)


def get_arch(name: str) -> ArchConfig:
    mod = importlib.import_module(
        f"repro.configs.{name.replace('-', '_').replace('.', '_')}")
    return mod.CONFIG


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        # needs sub-quadratic attention: SSM / hybrid only (DESIGN.md §5)
        return arch.sub_quadratic
    return True


def runnable_cells():
    """All (arch_id, shape_name) cells per the assignment rules."""
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for sname, shape in SHAPES.items():
            if shape_applicable(arch, shape):
                yield aid, sname
