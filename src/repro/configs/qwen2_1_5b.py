"""qwen2-1.5b [dense]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
GQA with QKV bias; tied embeddings  [arXiv:2407.10671]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b", family="dense", num_layers=28, d_model=1536,
    num_heads=12, num_kv_heads=2, d_ff=8960, vocab_size=151936,
    head_dim=128, qkv_bias=True, ffn_type="swiglu", rope_theta=1e6,
    tie_embeddings=True,
)
