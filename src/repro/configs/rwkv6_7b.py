"""rwkv6-7b [ssm]: 32L d=4096 attention-free (Finch: data-dependent decay)
d_ff=14336 vocab=65536; head_dim 64 => 64 WKV heads  [arXiv:2404.05892]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm", num_layers=32, d_model=4096,
    num_heads=64, num_kv_heads=64, d_ff=14336, vocab_size=65536,
    rwkv_head_dim=64, ffn_type="rwkv",
)
