"""qwen2-7b [dense]: 28L d=3584 28H (GQA kv=4) d_ff=18944 vocab=152064
GQA with QKV bias  [arXiv:2407.10671]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
    head_dim=128, qkv_bias=True, ffn_type="swiglu", rope_theta=1e6,
)
