"""zamba2-2.7b [hybrid]: 54 Mamba2 layers d=2560, ssm_state=64, plus a
SHARED attention+MLP block (32H, kv=32, d_ff=10240) applied every 6 layers
with per-invocation input norm (DESIGN.md §7 simplification)
[arXiv:2411.15242]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
    head_dim=80, ssm_state=64, ssm_head_dim=64, attn_every=6,
    ffn_type="gelu", rope_theta=1e4,
)
