"""seamless-m4t-medium [audio]: encoder-decoder, 12 encoder + 12 decoder
layers, d=1024 16H (kv=16) d_ff=4096 vocab=256206.  The speech/text modality
frontend is a STUB per the assignment: input_specs() provides precomputed
frame embeddings for the encoder  [arXiv:2308.11596]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="audio", num_layers=12, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=256206,
    head_dim=64, ffn_type="gelu", rope_theta=1e4, encoder_layers=12,
)
