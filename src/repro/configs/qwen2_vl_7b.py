"""qwen2-vl-7b [vlm]: qwen2-7b backbone + M-RoPE (3D rotary, sections
16/24/24 over head_dim/2) and dynamic-resolution patch embeddings.
The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings merged into the token stream  [arXiv:2409.12191]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
    head_dim=128, qkv_bias=True, ffn_type="swiglu", rope_theta=1e6,
    mrope_sections=(16, 24, 24),
)
