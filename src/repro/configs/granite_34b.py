"""granite-34b [dense]: 88L d=6144 48H (MQA kv=1) d_ff=24576 vocab=49152
llama-arch code model; MQA + GELU MLP (d_ff = 4*d)  [arXiv:2405.04324]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense", num_layers=88, d_model=6144,
    num_heads=48, num_kv_heads=1, d_ff=24576, vocab_size=49152,
    head_dim=128, ffn_type="gelu", rope_theta=1e5,
)
