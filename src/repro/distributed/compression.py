"""Gradient compression for cross-pod all-reduce: int8 + error feedback.

The multi-pod recipe replicates params across pods and all-reduces gradients
over the "pod" axis (DESIGN.md §4).  At 2+ pods over DCI, grad bytes dominate
the inter-pod collective term; blockwise-int8 quantization halves bf16 wire
bytes (4x vs fp32 grads; int8 + 1 f32 scale per 128-block).  Error feedback (Seide et al., 2014;
Karimireddy et al., 2019) accumulates the quantization residual locally so
the compression bias vanishes over steps — the property tests assert the
contraction property directly.

Note the symmetry with the paper: quantizing gradients to int8 exposes the
same sign-magnitude bit sparsity BitParticle exploits — ``examples/
estimate_deployment.py`` prices gradient traffic on the modeled hardware.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

BLOCK = 128
QMAX = 127.0


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    return jnp.pad(x.reshape(-1), (0, pad)), n


def compress(g) -> Tuple[jax.Array, jax.Array, tuple]:
    """g (any shape, float) -> (int8 codes, f32 per-block scales, meta)."""
    flat, n = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / QMAX
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -QMAX, QMAX).astype(jnp.int8)
    return q, scale, (g.shape, n)


def decompress(q, scale, meta):
    shape, n = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return flat.reshape(shape)


def compress_tree_with_feedback(grads, error_state):
    """(grads + carried error) -> compressed tree + new error state.

    Returns (compressed_grads, new_error_state).  ``compressed_grads`` is the
    dequantized value actually contributed to the all-reduce, so callers just
    psum/mean it; the residual stays in ``new_error_state``.
    """
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s, meta = compress(corrected)
        sent = decompress(q, s, meta)
        return sent.astype(g.dtype), corrected - sent
    out = jax.tree.map(one, grads, error_state)
    sent = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return sent, err


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_bytes(tree) -> int:
    """Wire bytes if every leaf were int8+scales (for the roofline model)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = leaf.size
        blocks = -(-n // BLOCK)
        total += n + 4 * blocks
    return total
