"""The paper's quasi-synchronous E/Q scheme lifted to cluster scale.

Mapping (DESIGN.md §2): PE -> worker host; column group -> data-parallel
replica group (which must advance in lockstep for its all-reduce); operand
queue Q -> per-host input prefetch depth; inter-group divergence E -> bounded
gradient staleness with a parameter-version ring buffer of E+1 versions (the
paper's weight buffer); zero-value filtering -> skipping empty/padded
microbatches at cost 0.

Because the scheduling semantics are *identical*, the cluster utilization
model literally reuses the cycle-accurate MAC-array simulator
(:mod:`repro.core.array_sim`) with per-(worker, group, round) compute times
in millisecond ticks — the same code that reproduces the paper's Fig. 8
prices straggler mitigation for a 1000+-node fleet.

``BoundedStalenessTrainer`` is the real-gradient counterpart: group gradients
computed against params up to E versions stale are applied through the
version buffer; tests verify convergence matches synchronous training.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.array_sim import ArrayConfig, SimResult, simulate


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    workers_per_group: int = 8     # hosts that lockstep inside one DP group
    n_groups: int = 32             # DP replica groups
    E: int = 3                     # staleness bound (param versions kept: E+1)
    Q: int = 2                     # per-host input prefetch depth
    straggler_sigma: float = 0.3   # lognormal sigma of per-round host time
    mean_round_ms: float = 100.0
    zero_skip_fraction: float = 0.0  # padded/empty microbatches (cost 0)


def sample_round_times(cfg: ClusterConfig, n_rounds: int, seed: int = 0
                       ) -> np.ndarray:
    """(workers, groups, rounds) integer ms ticks with heavy-tail stragglers."""
    rng = np.random.default_rng(seed)
    t = rng.lognormal(mean=0.0, sigma=cfg.straggler_sigma,
                      size=(cfg.workers_per_group, cfg.n_groups, n_rounds))
    t = np.maximum((t * cfg.mean_round_ms).astype(np.int32), 1)
    if cfg.zero_skip_fraction > 0:
        skip = rng.random(t.shape) < cfg.zero_skip_fraction
        t = np.where(skip, 0, t)
    return t


def cluster_utilization(cfg: ClusterConfig, n_rounds: int = 200,
                        seed: int = 0) -> SimResult:
    """Worker utilization of the fleet under the quasi-sync schedule."""
    times = sample_round_times(cfg, n_rounds, seed)
    sim_cfg = ArrayConfig(rows=cfg.workers_per_group, cols=cfg.n_groups,
                          E=cfg.E, Q=cfg.Q)
    return simulate(times, sim_cfg)


class BoundedStalenessTrainer:
    """Applies group gradients computed on params up to E versions stale.

    The param-version ring buffer (len E+1) is the cluster analogue of the
    paper's weight buffer; a gradient arriving with staleness s is applied
    with weight 1/(1+s) (stale-gradient damping).
    """

    def __init__(self, grad_fn: Callable, update_fn: Callable, params,
                 E: int = 3, seed: int = 0, n_groups: int = 4):
        self.grad_fn = grad_fn          # (params, batch) -> grads
        self.update_fn = update_fn      # (params, grads) -> params
        self.E = E
        self.n_groups = n_groups
        self.history = collections.deque([params], maxlen=E + 1)
        self.rng = np.random.default_rng(seed)
        self.step_count = 0

    @property
    def params(self):
        return self.history[-1]

    def step(self, group_batches, lags: Optional[np.ndarray] = None):
        """One global step: every group contributes a (possibly stale) grad."""
        assert len(group_batches) == self.n_groups
        if lags is None:
            lags = self.rng.integers(0, min(self.E, len(self.history) - 1) + 1,
                                     size=self.n_groups)
        grads, weights = [], []
        for g, batch in enumerate(group_batches):
            lag = int(min(lags[g], len(self.history) - 1))
            version = self.history[-1 - lag]
            grads.append(self.grad_fn(version, batch))
            weights.append(1.0 / (1.0 + lag))
        wsum = sum(weights)
        avg = jax.tree.map(
            lambda *gs: sum(w * g for w, g in zip(weights, gs)) / wsum, *grads)
        new_params = self.update_fn(self.params, avg)
        self.history.append(new_params)
        self.step_count += 1
        return new_params
