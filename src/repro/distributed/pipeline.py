"""GPipe-style pipeline parallelism as a composable primitive.

§Perf-B identified pipeline parallelism as the remaining lever for
FSDP-gather-bound dense training (granite-34b class): stages keep their
weights resident and exchange only (microbatch, seq, d_model) activations —
per-chip wire cost ~microbatches x activation bytes instead of ~3 x params.

``pipeline_apply`` runs a homogeneous stage function over ``n_stages``
stages sharded on a mesh axis, with the classic GPipe schedule expressed as
a ``shard_map`` + ``lax.ppermute`` rotation: at tick t, stage s processes
microbatch (t - s) and passes its output to stage s+1.  Bubble fraction is
(S-1)/(M+S-1); backward works through JAX autodiff of the whole schedule
(ppermute transposes to the reverse permutation automatically).

Napkin (granite-34b, 16 stages over "model", M=32 microbatches):
activations crossing each boundary per step ~ B.S.D.2 bytes = 12.9 GB / 16
chips = 0.8 GB/chip vs the measured 283 GB/chip FSDP gathers — ~350x less
wire, at the cost of a 32% bubble and stage-balanced weight residency.
Validated for exact equivalence with the sequential stack in
tests/test_pipeline.py; integrating it as a per-arch recipe is future work
(EXPERIMENTS.md §Perf-B).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _shard_map(fn, *, mesh, in_specs, out_specs):
    """Version-portable shard_map (see
    :func:`repro.distributed.sharding.portable_shard_map`, the shared
    implementation also used by the kernel wrappers)."""
    from repro.distributed.sharding import portable_shard_map
    return portable_shard_map(fn, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs)


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh,
                   axis_name: str = "model", n_microbatches: int):
    """Run ``x`` through ``n_stages`` sequential stages, pipelined.

    stage_fn: (params_slice, activations) -> activations (same shape).
    stage_params: pytree with leading dim = n_stages (stacked stage slices).
    x: (global_batch, ...) activations; global_batch % n_microbatches == 0.
    Returns stage_{S-1}(... stage_0(x)), numerically identical to the
    sequential loop.
    """
    n_stages = mesh.shape[axis_name]
    B = x.shape[0]
    assert B % n_microbatches == 0, (B, n_microbatches)
    mb = B // n_microbatches
    micro = x.reshape(n_microbatches, mb, *x.shape[1:])

    def per_stage(params_local, micro_local):
        # params_local: (1, ...) this stage's slice;  micro_local: the full
        # microbatch queue, replicated (the scheduler feeds stage 0 only)
        params_here = jax.tree.map(lambda t: t[0], params_local)
        stage_id = jax.lax.axis_index(axis_name)
        n_ticks = n_microbatches + n_stages - 1
        buf = jnp.zeros_like(micro_local[0])

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 ingests microbatch t; others use what arrived
            feed = jnp.where(t < n_microbatches,
                             micro_local[jnp.minimum(t, n_microbatches - 1)],
                             jnp.zeros_like(buf))
            inp = jnp.where(stage_id == 0, feed, buf)
            active = (t >= stage_id) & (t - stage_id < n_microbatches)
            out = stage_fn(params_here, inp)
            out = jnp.where(active, out, jnp.zeros_like(out))
            # rotate stage s -> s+1 (last stage's output falls off the ring)
            nxt = jax.lax.ppermute(
                out, axis_name,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            # last stage banks its finished microbatch
            done_idx = t - (n_stages - 1)
            is_done = (stage_id == n_stages - 1) & (done_idx >= 0)
            outputs = jnp.where(
                is_done,
                outputs.at[jnp.maximum(done_idx, 0)].set(out),
                outputs)
            return (nxt, outputs), None

        outputs0 = jnp.zeros_like(micro_local)
        (_, outputs), _ = jax.lax.scan(tick, (buf, outputs0),
                                       jnp.arange(n_ticks))
        # outputs live on the last stage; broadcast so every shard returns
        # the same value (out_specs replicate over the stage axis)
        outputs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outputs,
                      jnp.zeros_like(outputs)), axis_name)
        return outputs

    fn = _shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P())
    out = fn(stage_params, micro)
    return out.reshape(B, *x.shape[1:])
