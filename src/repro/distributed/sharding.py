"""Logical-axis sharding: recipes mapping model-logical axes onto the mesh.

The paper switches between two dataflows per layer shape (Section IV-A); at
pod scale we switch between sharding *recipes* per workload shape:

  train / prefill   batch -> ("pod", "data"); sequence -> "model"
                    (Megatron-style sequence parallelism for the residual
                    stream; KV is gathered inside attention); params
                    2D-sharded (FSDP over "data" x TP over "model").
  decode            batch -> ("pod", "data"); KV-cache seq -> "model"
                    (split-KV decode: XLA partial-softmax-reduces over the
                    sharded cache axis); params TP-sharded over "model".
  decode_long       global_batch = 1: cache seq -> ("data", "model"),
                    recurrent-state heads -> "model".

Constraints are no-ops when no mesh is active (single-device tests) and skip
mesh axes that don't exist (e.g. "pod" on the single-pod mesh), so the same
model code runs everywhere.
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

_STATE = threading.local()

# logical activation axis -> preferred mesh axes (tuples tried in order)
ACTIVATION_RULES = {
    "train": {
        "batch": ("pod", "data"),
        "seq": ("model",),
        "tokens_flat": ("pod", "data", "model"),
        "kv_seq": (),            # gathered for attention
        "experts": ("model",),
        "cache_seq": ("model",),
        "heads": (),
        "embed": (),
        "ffn": (),
    },
    "decode": {
        "batch": ("pod", "data"),
        "seq": (),
        "tokens_flat": ("pod", "data"),
        "kv_seq": ("model",),
        "experts": ("model",),
        "cache_seq": ("model",),
        "heads": (),
        "embed": (),
        "ffn": ("model",),
    },
    "decode_long": {
        "batch": (),
        "seq": (),
        "tokens_flat": (),
        "kv_seq": ("data", "model"),
        "experts": ("model",),
        "cache_seq": ("data", "model"),
        "heads": ("model",),
        "embed": (),
        "ffn": ("model",),
    },
}


def _rules() -> Optional[dict]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def recipe(name: Optional[str]):
    """Activate an activation-sharding recipe ("train" / "decode" / ...)."""
    prev = _rules()
    _STATE.rules = ACTIVATION_RULES[name] if name else None
    try:
        yield
    finally:
        _STATE.rules = prev


def _physical_mesh():
    """Thread-local physical mesh set by ``with Mesh(...)`` (None when the
    legacy context API is gone)."""
    try:
        from jax.interpreters import pxla
        return pxla.thread_resources.env.physical_mesh
    except (ImportError, AttributeError):
        return None


def _mesh_axes():
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        mesh = get_abstract()
        if mesh is None or mesh.empty:
            # `with Mesh(...)` (the jax<0.5 idiom) still sets the physical
            # mesh on newer jax — fall through so both activation styles work
            mesh = _physical_mesh()
    else:  # jax < 0.5: the thread-local physical mesh set by `with Mesh(...)`
        mesh = _physical_mesh()
    if mesh is None or mesh.empty:
        return None
    return dict(zip(mesh.axis_names, mesh.shape.values())) if hasattr(mesh.shape, "values") else dict(mesh.shape)


def mesh_axes_dict(mesh) -> dict:
    """{axis name: size} for a concrete ``jax.sharding.Mesh``."""
    if hasattr(mesh, "devices"):
        return dict(zip(mesh.axis_names, mesh.devices.shape))
    return dict(mesh.shape)


def current_mesh():
    """The mesh active for this trace — the abstract mesh on jax versions
    that have one, else the thread-local physical mesh set by ``with
    Mesh(...)`` / :func:`activate_mesh`.  Returns an object usable as the
    ``mesh`` argument of ``shard_map``, or None when no mesh is active."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        mesh = get_abstract()
        if mesh is not None and not mesh.empty:
            return mesh
    mesh = _physical_mesh()
    if mesh is None or mesh.empty:
        return None
    return mesh


def portable_shard_map(fn, *, mesh, in_specs, out_specs):
    """Version-portable shard_map: ``jax.shard_map`` (jax >= 0.7,
    ``check_vma``) with the ``jax.experimental`` spelling (``check_rep``)
    as fallback.  Replication checking is off in both: the kernel wrappers
    produce outputs whose replication the tracer cannot prove (psum-combined
    partial contractions), and parity tests assert it instead."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def combine_matmul_partials(acc, axis_name: str):
    """Sum per-shard partial contractions (split-K tensor parallelism).

    Called inside a shard_map body.  The psum runs in the accumulator's own
    dtype, so int32 split-K partials combine exactly — a split-K kernel
    matmul stays bit-identical to the unsplit contraction."""
    return jax.lax.psum(acc, axis_name)


def combine_softmax_state(acc, m, l, axis_name: str, *, eps: float = 1e-37):
    """Merge per-shard online-softmax partial state into the global output.

    Called inside a shard_map body.  Each shard contributes flash-decoding
    state over its local KV split: ``m`` running max, ``l`` running
    denominator, ``acc`` the *unnormalized* weighted-value accumulator
    (broadcastable to ``acc``'s shape on the last dim).  A shard that saw
    only masked positions has m = -inf, l = 0 and contributes exactly 0.

        m_g = pmax(m);  out = psum(acc . e^{m-m_g}) / max(psum(l . e^{m-m_g}), eps)
    """
    m_all = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - m_all)
    l_all = jax.lax.psum(l * corr, axis_name)
    acc_all = jax.lax.psum(acc * corr, axis_name)
    return acc_all / jnp.maximum(l_all, eps)


def activate_mesh(mesh):
    """Context manager activating ``mesh`` for trace-time logical-axis
    constraints across jax versions: ``jax.set_mesh`` /
    ``jax.sharding.use_mesh`` where the abstract-mesh API exists, the
    legacy ``with Mesh(...)`` physical-mesh context otherwise.
    ``shard``/:func:`force_replicated` read whichever is active."""
    for ctx in (getattr(jax, "set_mesh", None),
                getattr(jax.sharding, "use_mesh", None)):
        if ctx is not None:
            return ctx(mesh)
    return mesh  # jax < 0.5: Mesh is itself a context manager


def force_replicated(x):
    """with_sharding_constraint to fully-replicated (no-op without a mesh).

    Used to pin WHERE a reshard happens — e.g. gathering the int8-quantized
    form of a weight instead of its bf16 original (quantized FSDP gathers).
    """
    if _mesh_axes() is None:
        return x
    return jax.lax.with_sharding_constraint(x, P(*([None] * x.ndim)))


def logical_pspec(shape, logical_axes, recipe_name: str, mesh_axes: dict) -> P:
    """PartitionSpec for one array of ``shape`` whose dims carry the given
    logical axis names, resolved against a recipe + {mesh axis: size} dict.

    Mirrors :func:`shard` exactly (same silent-drop rules: a mesh axis is
    skipped when absent or when the dim is not divisible by the axis
    product), but is usable OUTSIDE a trace — the serving executor builds
    NamedShardings for params/caches from it."""
    return _resolve_spec(shape, logical_axes, ACTIVATION_RULES[recipe_name],
                         mesh_axes)


def shard(x, *logical_axes):
    """with_sharding_constraint by logical axis names (None = replicated).

    Axes are dropped silently when absent from the active mesh or when the
    dimension size is not divisible by the mesh-axis product.
    """
    rules = _rules()
    mesh = _mesh_axes()
    if rules is None or mesh is None:
        return x
    spec = _resolve_spec(x.shape, logical_axes, rules, mesh)
    return jax.lax.with_sharding_constraint(x, spec)


def _resolve_spec(shape, logical_axes, rules, mesh_axes) -> P:
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    spec = []
    used = set()
    for dim, name in zip(shape, logical_axes):
        if name is None:
            spec.append(None)
            continue
        axes = tuple(a for a in rules.get(name, ())
                     if a in mesh_axes and a not in used)
        prod = int(np.prod([mesh_axes[a] for a in axes])) if axes else 1
        if axes and dim % prod == 0:
            spec.append(axes if len(axes) > 1 else axes[0])
            used.update(axes)
        else:
            spec.append(None)
    return P(*spec)


# ---------------------------------------------------------------------------
# Parameter partition rules
# ---------------------------------------------------------------------------

_EXPERT_RE = re.compile(r"experts|expert_")
_SCAN_RE = re.compile(r"layers|blocks")


def _divisible(dim: int, mesh: dict, axis: str) -> bool:
    return axis in mesh and dim % mesh[axis] == 0


def param_spec(path: str, leaf, recipe_name: str, mesh: dict) -> P:
    """Partition spec for one parameter.

    train: 2D — last dim over "model", second-to-last over "data" (FSDP x TP).
    serve: 1D — last dim over "model" (weight-stationary TP).
    Expert tensors (..., E, d_in, d_out): E over "model", d_in over "data"
    (train only).  Scan-stacked leading layer dims stay replicated.  1D
    params (norm scales, biases) are replicated.
    """
    shape = leaf.shape
    ndim = len(shape)
    spec = [None] * ndim
    if ndim < 2:
        return P(*spec)
    is_expert = bool(_EXPERT_RE.search(path))
    if is_expert and ndim >= 3:
        e_ax = ndim - 3
        if _divisible(shape[e_ax], mesh, "model"):
            spec[e_ax] = "model"
        if recipe_name == "train" and _divisible(shape[-2], mesh, "data"):
            spec[-2] = "data"
        return P(*spec)
    if _divisible(shape[-1], mesh, "model"):
        spec[-1] = "model"
    if recipe_name == "train" and _divisible(shape[-2], mesh, "data"):
        spec[-2] = "data"
    return P(*spec)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_specs(params, recipe_name: str, mesh) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec pytree matching ``params`` for the given recipe.
    ``mesh``: a concrete Mesh or a plain {axis name: size} dict."""
    mesh_axes = mesh if isinstance(mesh, dict) else mesh_axes_dict(mesh)
    return jax.tree_util.tree_map_with_path(
        lambda p, l: param_spec(_path_str(p), l, recipe_name, mesh_axes), params)


def named_shardings(params, recipe_name: str, mesh):
    specs = param_specs(params, recipe_name, mesh)
    return jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
