"""Core BitParticle numerics, cost models and simulators."""

from repro.core import bitparticle, bp_matmul, quant, sparsity  # noqa: F401
