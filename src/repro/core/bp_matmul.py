"""Quantized matmul backends with BitParticle numerics.

Three modes, selectable per layer / per config:

  ``bf16``      plain mixed-precision matmul (the unquantized baseline).
  ``bp_exact``  W8A8 sign-magnitude int8 matmul.  BitParticle's exact MAC is
                bit-identical to integer multiply (proven exhaustively in
                tests), so the TPU lowering is a single int8xint8->int32 MXU
                contraction + dequant epilogue.
  ``bp_approx`` the paper's approximate MAC (drops IR groups {0} and {1,4}).
                Using signed low particles A0 = s.(|A| & 3), A1 = s.(|A|>>2 & 3),
                W0 = s.(|W| & 3), Wlow4 = s.(|W| & 15):

                    approx(A @ W) = A@W - A0@Wlow4 - 4*(A1@W0)

                i.e. the elementwise IR-group drop factorizes into two extra
                int8 matmuls — the TPU-native formulation of the variant.

The Pallas TPU kernel in ``repro.kernels.bitparticle_matmul`` fuses all
contractions + dequant in one VMEM pass; this module holds both the pure-jnp
(XLA) implementation — used for training, dry-runs, and as the kernel oracle
— and the backend dispatch that routes inference-path contractions through
the kernel.

Backend selection (``matmul_backend`` on ``ArchConfig`` / this module):

  ``auto``              fused Pallas kernel on TPU, pure XLA elsewhere.
  ``kernel``            force the compiled Pallas kernel.
  ``kernel_interpret``  force the kernel in interpret mode (CPU validation).
  ``xla``               force the pure-jnp three-matmul formulation.

The active backend is a trace-time choice: ``use_matmul_backend`` scopes it
around a jit trace (the serving engine wraps every compiled entry point this
way), ``set_matmul_backend`` moves the process-wide default.
"""

from __future__ import annotations

import contextlib
import logging
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import quant
# hoisted (was a per-call import inside the hot dispatch path): sharding
# only imports jax/numpy, so there is no import cycle to dodge
from repro.distributed import sharding as _shd

_log = logging.getLogger(__name__)

MODES = ("bf16", "qat", "bp_exact", "bp_approx")
BACKENDS = ("auto", "xla", "kernel", "kernel_interpret")

_matmul_backend = "auto"


def set_matmul_backend(backend: str) -> str:
    """Set the process-wide quantized-matmul backend; returns the previous
    value.  Takes effect at trace time — already-compiled functions keep the
    backend they were traced with."""
    global _matmul_backend
    if backend not in BACKENDS:
        raise ValueError(f"unknown matmul backend {backend!r}; "
                         f"expected one of {BACKENDS}")
    prev = _matmul_backend
    _matmul_backend = backend
    return prev


def get_matmul_backend() -> str:
    return _matmul_backend


@contextlib.contextmanager
def use_matmul_backend(backend: str):
    """Scope the quantized-matmul backend around a trace/call."""
    prev = set_matmul_backend(backend)
    try:
        yield
    finally:
        set_matmul_backend(prev)


def resolve_matmul_backend(backend: str = None) -> str:
    """Concrete backend ("xla" | "kernel" | "kernel_interpret") for the
    current default device.

    Kernel backends stay valid verbatim under an active mesh trace: the
    dispatch sites wrap the Pallas kernels in ``shard_map`` over the active
    mesh (per-shard fused kernel + collective combine of partial results),
    so there is no blanket mesh -> "xla" downgrade here anymore.  The rare
    remaining per-call degrades (e.g. int8 KV scale pages, which only the
    gather oracle understands) announce themselves once through
    :func:`note_backend_fallback` instead of silently resolving away."""
    b = _matmul_backend if backend is None else backend
    if b == "auto":
        b = "kernel" if jax.default_backend() == "tpu" else "xla"
    return b


def mesh_active() -> bool:
    """True when a mesh is active for the current trace (resolved once per
    trace at each dispatch site — cached executions pay nothing)."""
    return _shd.current_mesh() is not None


#: one-time fallback ledger: reason -> count.  The first occurrence of each
#: reason logs a warning; every occurrence is counted so telemetry/tests can
#: assert whether (and why) a kernel request degraded to the XLA oracle.
_FALLBACK_NOTES: dict = {}


def note_backend_fallback(reason: str) -> None:
    """Record (and log, first time per reason) a backend downgrade."""
    n = _FALLBACK_NOTES.get(reason, 0)
    _FALLBACK_NOTES[reason] = n + 1
    if n == 0:
        _log.warning("quantized-op backend fallback: %s "
                     "(further occurrences counted, not logged)", reason)


def backend_fallbacks() -> dict:
    """Snapshot of the fallback ledger ({reason: count})."""
    return dict(_FALLBACK_NOTES)


def clear_backend_fallbacks() -> None:
    _FALLBACK_NOTES.clear()


def signed_low_particles(q):
    """(q0, q1, qlow4): signed particles of the two low 2-bit groups.

    q0 = sign(q)*(|q| & 3), q1 = sign(q)*((|q| >> 2) & 3),
    qlow4 = sign(q)*(|q| & 15) = q0 + 4*q1.  All int8-range int32 arrays.
    """
    q = jnp.asarray(q, jnp.int32)
    s = jnp.sign(q)
    m = jnp.abs(q)
    q0 = s * (m & 3)
    q1 = s * ((m >> 2) & 3)
    return q0, q1, q0 + 4 * q1


def int_matmul(a_q, w_q):
    """int8 x int8 -> int32 contraction over the last/first axes (MXU-native)."""
    return jax.lax.dot_general(
        a_q.astype(jnp.int8), w_q.astype(jnp.int8),
        (((a_q.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def bp_matmul_int(a_q, w_q, mode: str = "bp_exact"):
    """Integer-domain BitParticle matmul: int8 operands -> int32 accumulators."""
    acc = int_matmul(a_q, w_q)
    if mode == "bp_exact":
        return acc
    if mode == "bp_approx":
        a0, a1, _ = signed_low_particles(a_q)
        w0, _, wlow4 = signed_low_particles(w_q)
        corr = int_matmul(a0, wlow4) + 4 * int_matmul(a1, w0)
        return acc - corr
    raise ValueError(f"unknown integer mode: {mode}")


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def quantized_matmul(x, w, w_scale, mode: str):
    """Dequantizing BitParticle matmul with a straight-through gradient.

    x: (..., K) float; w: (K, N) int8 (pre-quantized, per-channel w_scale (N,)).
    Activations are dynamically quantized PER ROW (one symmetric scale per
    token position): each row's numerics are then independent of whatever
    else shares the batch, so a token produces bit-identical logits whether
    it is decoded alone, in a continuous batch, or inside a multi-token
    speculative verify window — the invariant the serving token-identity
    guarantees stand on.  Returns (..., N) in x.dtype.
    """
    return _qmm_fwd_impl(x, w, w_scale, mode)


def _qmm_fwd_impl(x, w, w_scale, mode):
    x_scale = quant.compute_scale(x, axis=(-1,))   # (..., 1) per-row
    x_q = quant.quantize(x, x_scale)
    backend = resolve_matmul_backend()
    if backend != "xla" and mode in ("bp_exact", "bp_approx"):
        # fused Pallas path: quantize-scale plumbing + exact/approx
        # contractions + dequant epilogue in one VMEM pass.  Under an
        # active mesh the kernel runs per-shard inside shard_map (TP
        # column split / split-K psum combine) instead of degrading to XLA.
        interpret = backend == "kernel_interpret"
        mesh = _shd.current_mesh()
        if mesh is not None:
            from repro.kernels.bitparticle_matmul.ops import bp_matmul_sharded
            out = bp_matmul_sharded(x_q, w, x_scale, w_scale,
                                    approx=(mode == "bp_approx"),
                                    interpret=interpret, mesh=mesh)
        else:
            from repro.kernels.bitparticle_matmul.ops import bp_matmul
            out = bp_matmul(x_q, w, x_scale, w_scale,
                            approx=(mode == "bp_approx"),
                            interpret=interpret)
        return out.astype(x.dtype)
    acc = bp_matmul_int(x_q, w, mode)
    return (acc.astype(jnp.float32) * (x_scale * w_scale)).astype(x.dtype)


def _qmm_fwd(x, w, w_scale, mode):
    return _qmm_fwd_impl(x, w, w_scale, mode), (x, w, w_scale)


def _qmm_bwd(mode, res, g):
    x, w, w_scale = res
    # STE through quantization: grads flow as if the matmul were x @ (w*ws).
    w_f = w.astype(g.dtype) * w_scale.astype(g.dtype)
    gx = jnp.einsum("...n,kn->...k", g, w_f)
    gw = jnp.zeros_like(w)  # int weights are not trained through this path
    gws = jnp.zeros_like(w_scale)
    return gx, gw, gws


quantized_matmul.defvjp(_qmm_fwd, _qmm_bwd)


@jax.custom_vjp
def quantized_gather(w):
    """Per-channel int8 quantize -> replicate (the collective moves int8) ->
    dequantize.  STE backward: the cotangent passes straight to the sharded
    weight (its resharding transposes to a reduce-scatter under SPMD).

    This is the paper's W8 quantization applied to the *FSDP all-gather
    wire format*: weight-gather bytes halve vs bf16 (EXPERIMENTS.md §Perf B).
    """
    from repro.distributed.sharding import force_replicated
    scale = quant.compute_scale(w.astype(jnp.float32), axis=(0,))
    q = quant.quantize(w.astype(jnp.float32), scale)
    q = force_replicated(q)
    return (q.astype(jnp.float32) * scale).astype(w.dtype)


def _qg_fwd(w):
    return quantized_gather(w), None


def _qg_bwd(_, g):
    return (g,)


quantized_gather.defvjp(_qg_fwd, _qg_bwd)


def dense_apply(x, w_f, mode: str, *, precision=None):
    """Dense layer forward used by all models: float weights, mode-dependent.

    ``bf16``: plain matmul.  Quantized modes: weights are per-channel
    fake-routed through int8 (dynamic quantization of both operands) so that
    dry-run HLO contains the true int8 contraction graph.  For training the
    straight-through estimator keeps the graph differentiable.
    The ``+q8gather`` suffix routes the weight through
    :func:`quantized_gather` first (int8 on the FSDP gather wire).
    """
    if mode.endswith("+q8gather"):
        w_f = quantized_gather(w_f)
        mode = mode[: -len("+q8gather")]
    if w_f.dtype == jnp.int8:
        # pre-quantized serving weights: the scale rides along in the params
        raise ValueError("int8 weights must go through dense() with w_scale")
    if mode == "bf16":
        return jnp.einsum("...k,kn->...n", x, w_f, precision=precision)
    if mode == "qat":
        # Quantization-aware training: fake-quant both operands (STE grads
        # flow to the float weights), float MXU matmul.
        ws = quant.compute_scale(w_f.astype(jnp.float32), axis=(0,))
        w_fq = quant.fake_quant(w_f.astype(jnp.float32), ws).astype(x.dtype)
        xs = quant.compute_scale(x.astype(jnp.float32))
        x_fq = quant.fake_quant(x.astype(jnp.float32), xs).astype(x.dtype)
        return jnp.einsum("...k,kn->...n", x_fq, w_fq, precision=precision)
    w_q, w_scale = quant.quantize_per_channel(w_f.astype(jnp.float32), channel_axis=-1)
    w_scale = w_scale.reshape(-1)  # (N,)
    from repro.core import probe
    probe.record_activation(x)
    return quantized_matmul(x, w_q, w_scale, mode)
