"""Symmetric int8 quantization matched to BitParticle's sign-magnitude range.

Sign-magnitude int8 represents [-127, 127] (no -128), so all quantizers here
clip symmetrically to +/-127 — exactly the paper's "8-bit per-tensor symmetric
quantization" (Section III-B4).

Provides per-tensor and per-channel scales, a straight-through-estimator
fake-quant for quantization-aware passes, and the dequant epilogue used by
the quantized matmul backends.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

QMAX = 127  # sign-magnitude int8 magnitude range


def compute_scale(x, axis: Optional[Sequence[int]] = None, eps: float = 1e-8):
    """max-abs symmetric scale so that x/scale lands in [-127, 127].

    ``axis=None`` -> per-tensor scalar scale.  Otherwise the reduction axes;
    kept dims are preserved so the scale broadcasts against ``x``.
    """
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, eps) / QMAX


def quantize(x, scale):
    """Round-to-nearest-even symmetric quantization to int8 in [-127, 127]."""
    q = jnp.round(x / scale)
    return jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_per_tensor(x):
    scale = compute_scale(x, axis=None)
    return quantize(x, scale), scale


def quantize_per_channel(x, channel_axis: int = -1):
    """Per-channel scales along ``channel_axis`` (weights: output channel)."""
    axes = tuple(i for i in range(x.ndim) if i != channel_axis % x.ndim)
    scale = compute_scale(x, axis=axes)
    return quantize(x, scale), scale


@jax.custom_vjp
def fake_quant(x, scale):
    """Quantize-dequantize with a straight-through gradient (QAT)."""
    return dequantize(quantize(x, scale), scale)


def _fake_quant_fwd(x, scale):
    return fake_quant(x, scale), (x, scale)


def _fake_quant_bwd(res, g):
    x, scale = res
    # STE: pass gradients through where |x| is inside the clip range.
    inside = (jnp.abs(x) <= scale * QMAX).astype(g.dtype)
    return g * inside, jnp.zeros_like(scale)


fake_quant.defvjp(_fake_quant_fwd, _fake_quant_bwd)
