"""Cycle-accurate simulator of the quasi-synchronizing MAC array (Sec. IV-B).

Faithful to the paper's simulator (Section IV-B3):

  * 16 x 32 PE array; each *column* is a synchronization group (32 groups).
  * **Intra-group elasticity**: every PE owns an operand queue of depth Q.
    A column "propagates one step forward" only when all 16 of its PEs accept
    the step's operands (Q = 0 degenerates to strict in-column sync: all PEs
    must be idle).
  * **Inter-group elasticity**: the fastest column may run at most E steps
    ahead of the slowest (weight buffer holds E+1 weight versions).
  * **Zero-value filtering**: zero operands are filtered before the queue and
    cost 0 cycles.
  * Data correlation matches the dataflow: the weight of row r at step s is
    shared by all 32 columns; the activation entering column c at step s
    propagates down the rows, so PE (r, c) at column-step s multiplies
    weight[r, s] x activation[c, s - r].
  * "As long as a column is ready to advance, sufficient input data is always
    available" — no cache-miss stalls are modeled, per the paper.

Pure numpy (a discrete-cycle loop over vectorized (R, C) state) — this is
tooling around the JAX framework, mirroring the paper's C++-style simulator.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitparticle as bp
from repro.core.sparsity import sample_with_bit_sparsity


@dataclasses.dataclass(frozen=True)
class ArrayConfig:
    rows: int = 16
    cols: int = 32
    E: int = 3                 # inter-group step divergence bound
    Q: int = 2                 # per-PE operand queue depth
    zero_filter: bool = False  # pre-queue zero-value filtering
    approx: bool = False       # approximate MAC variant (cycle model)

    @property
    def weight_buffer_depth(self) -> int:
        return self.E + 1      # Section IV-B2


@dataclasses.dataclass
class SimResult:
    cycles: int
    n_steps: int
    pe_utilization: float      # busy PE-cycles / (R*C*cycles)
    avg_cycles_per_step: float # cycles / n_steps   (Fig. 9 metric)
    throughput_steps_per_cycle: float
    max_observed_divergence: int


def build_op_costs(key, cfg: ArrayConfig, n_steps: int, bit_sparsity: float,
                   w_value_sparsity: float = 0.0,
                   a_value_sparsity: float = 0.0,
                   a_bit_sparsity: Optional[float] = None) -> np.ndarray:
    """Per-(row, col, step) MAC cycle costs from the paper's data generator.

    Weights: (R, S) shared across columns.  Activations: (C, S + R - 1);
    the activation consumed by PE (r, c) at column-step s entered at step
    s - r (pipeline skew), giving the in-column reuse correlation.
    ``a_bit_sparsity`` lets the activation factor carry its own (measured)
    bit sparsity; it defaults to the weight-side ``bit_sparsity``.
    """
    kw, ka = jax.random.split(key)
    w = sample_with_bit_sparsity(kw, (cfg.rows, n_steps), bit_sparsity,
                                 w_value_sparsity)
    a = sample_with_bit_sparsity(
        ka, (cfg.cols, n_steps + cfg.rows - 1),
        bit_sparsity if a_bit_sparsity is None else a_bit_sparsity,
        a_value_sparsity)
    # a_used[r, c, s] = a[c, s - r + (R-1)]
    s_idx = np.arange(n_steps)[None, None, :]
    r_idx = np.arange(cfg.rows)[:, None, None]
    a_used = np.asarray(a)[np.arange(cfg.cols)[None, :, None],
                           s_idx - r_idx + (cfg.rows - 1)]
    w_used = np.broadcast_to(np.asarray(w)[:, None, :],
                             (cfg.rows, cfg.cols, n_steps))
    costs = np.asarray(
        bp.mac_cycles(jnp.asarray(w_used), jnp.asarray(a_used),
                      approx=cfg.approx))
    if cfg.zero_filter:
        costs = np.where((w_used == 0) | (a_used == 0), 0, costs)
    return costs.astype(np.int32)


def simulate(costs: np.ndarray, cfg: ArrayConfig) -> SimResult:
    """Run the quasi-synchronous schedule over a (R, C, S) cost tensor."""
    R, C, S = costs.shape
    assert (R, C) == (cfg.rows, cfg.cols)
    Q = cfg.Q
    qcap = max(Q, 1)
    queue = np.zeros((R, C, qcap), np.int32)   # FIFO of pending op costs
    qlen = np.zeros((R, C), np.int32)
    exec_rem = np.zeros((R, C), np.int32)
    steps = np.full(C, -1, np.int64)           # last accepted step per column
    busy_cycles = 0
    cycles = 0
    max_div = 0
    # safety bound: every op serialized + drain
    max_cycles = int(costs.sum() + 4 * S + R * C + 64)

    while True:
        # termination: everything accepted and drained
        if (steps == S - 1).all() and not exec_rem.any() and not qlen.any():
            break
        cycles += 1
        assert cycles <= max_cycles, "simulator failed to make progress"

        # --- 1. column advancement (acceptance) -------------------------
        # The divergence bound (fastest <= slowest + E) is evaluated against
        # the POST-advance step vector: columns all sitting at the same step
        # may advance together even at E = 0.  Fixpoint over the (monotone)
        # constraint set.
        if Q == 0:
            accept_ok = ((exec_rem == 0) & (qlen == 0)).all(axis=0)
        else:
            accept_ok = (qlen < Q).all(axis=0)
        adv = accept_ok & (steps < S - 1)
        while adv.any():
            new_min = np.where(adv, steps + 1, steps).min()
            adv2 = adv & (steps + 1 - new_min <= cfg.E)
            if (adv2 == adv).all():
                break
            adv = adv2
        if adv.any():
            new_steps = steps[adv] + 1
            new_costs = costs[:, adv, :][np.arange(R)[:, None],
                                         np.arange(adv.sum())[None, :],
                                         new_steps[None, :]]
            nz = new_costs > 0                 # zero-cost ops never enqueue
            cols_adv = np.where(adv)[0]
            if Q == 0:
                # straight to execution (PE proven idle)
                er = exec_rem[:, cols_adv]
                er[nz] = new_costs[nz]
                exec_rem[:, cols_adv] = er
            else:
                qv = queue[:, cols_adv, :]
                ql = qlen[:, cols_adv]
                r_i, c_i = np.nonzero(nz)
                qv[r_i, c_i, ql[r_i, c_i]] = new_costs[r_i, c_i]
                ql[r_i, c_i] += 1
                queue[:, cols_adv, :] = qv
                qlen[:, cols_adv] = ql
            steps[adv] += 1
            max_div = max(max_div, int(steps.max() - steps.min()))

        # --- 2. issue: idle PEs pop the queue head ----------------------
        pop = (exec_rem == 0) & (qlen > 0)
        if pop.any():
            exec_rem[pop] = queue[pop, 0]
            queue[pop] = np.roll(queue[pop], -1, axis=-1)
            queue[pop, qcap - 1] = 0
            qlen[pop] -= 1

        # --- 3. execute one cycle ---------------------------------------
        busy = exec_rem > 0
        busy_cycles += int(busy.sum())
        exec_rem[busy] -= 1

    return SimResult(
        cycles=cycles,
        n_steps=S,
        pe_utilization=busy_cycles / (R * C * max(cycles, 1)),
        avg_cycles_per_step=cycles / S,
        throughput_steps_per_cycle=S / max(cycles, 1),
        max_observed_divergence=max_div,
    )


def run_experiment(seed: int, cfg: ArrayConfig, n_steps: int,
                   bit_sparsity: float, w_value_sparsity: float = 0.0,
                   a_value_sparsity: float = 0.0,
                   a_bit_sparsity: Optional[float] = None) -> SimResult:
    costs = build_op_costs(jax.random.PRNGKey(seed), cfg, n_steps,
                           bit_sparsity, w_value_sparsity, a_value_sparsity,
                           a_bit_sparsity)
    return simulate(costs, cfg)
