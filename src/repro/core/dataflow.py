"""The paper's two switchable dataflows + loop tiling + mini-ZigZag mapper
(Section IV-A, used by the Figs 12-13 system-level benchmark).

PE array: 16 rows x 32 columns.  K (output channel) is spatially unrolled
over the 16 rows in both dataflows; columns unroll either

  dataflow (a):  OXu x OYu = 32, (OXu, OYu) in {(32,1), (16,2), (8,4)}
                 — early conv layers with large OX/OY;
  dataflow (b):  Bu = 32 — late conv / fully-connected layers.

Spatially-unrolled dims (K, B, OX, OY) produce independent outputs, so no
inter-PE accumulation; the reduction dims (C, FY, FX) iterate temporally
with in-PE accumulation (schedule ...-K1-FY-FX-C, reduction innermost).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, List, Tuple

from repro.core.cost_model import (ACCEL_CONFIGS, DRAM_PJ_PER_BYTE,
                                   sram_pj_per_byte)

ROWS, COLS = 16, 32
OXU_OYU_CHOICES: Tuple[Tuple[int, int], ...] = ((32, 1), (16, 2), (8, 4))


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """The 7 dimensions of a conv layer (TABLE I).  FC: OX=OY=FY=FX=1."""
    name: str
    B: int
    K: int
    C: int
    OY: int
    OX: int
    FY: int = 1
    FX: int = 1

    @property
    def total_macs(self) -> int:
        return self.B * self.K * self.C * self.OY * self.OX * self.FY * self.FX

    @property
    def weight_count(self) -> int:
        return self.K * self.C * self.FY * self.FX

    @property
    def input_count(self) -> int:
        # stride-1 approximation of the input feature map volume
        return self.B * self.C * (self.OY + self.FY - 1) * (self.OX + self.FX - 1)

    @property
    def output_count(self) -> int:
        return self.B * self.K * self.OY * self.OX


@dataclasses.dataclass(frozen=True)
class Mapping:
    dataflow: str              # "a" or "b"
    oxu: int = 1
    oyu: int = 1
    steps: int = 0             # temporal steps (each step = 512 PE MAC slots)
    spatial_utilization: float = 0.0


def _ceil(a: int, b: int) -> int:
    return -(-a // b)


def enumerate_mappings(shape: LayerShape) -> List[Mapping]:
    """All legal (dataflow, spatial-unroll) choices with their step counts."""
    out = []
    temporal_common = shape.C * shape.FY * shape.FX * _ceil(shape.K, ROWS)
    # dataflow (a): columns unroll OX x OY
    for oxu, oyu in OXU_OYU_CHOICES:
        steps = (temporal_common * shape.B
                 * _ceil(shape.OX, oxu) * _ceil(shape.OY, oyu))
        out.append(Mapping("a", oxu, oyu, steps,
                           shape.total_macs / (steps * ROWS * COLS)))
    # dataflow (b): columns unroll batch
    steps_b = temporal_common * _ceil(shape.B, COLS) * shape.OX * shape.OY
    out.append(Mapping("b", 1, 1, steps_b,
                       shape.total_macs / (steps_b * ROWS * COLS)))
    return out


def choose_mapping(shape: LayerShape) -> Mapping:
    """ZigZag-style pick: minimize temporal steps (max spatial utilization)."""
    return min(enumerate_mappings(shape), key=lambda m: m.steps)


@dataclasses.dataclass
class Traffic:
    """Access counts in elements (int8 => bytes)."""
    w_cache_reads: int
    a_cache_reads: int
    r_cache_writes: int
    dram_weight_bytes: int
    dram_act_bytes: int
    dram_out_bytes: int

    def cache_energy_pj(self, accel: str = "bitparticle") -> float:
        cfg = ACCEL_CONFIGS[accel]
        e = self.w_cache_reads * sram_pj_per_byte(cfg.w_cache_bytes)
        e += self.a_cache_reads * sram_pj_per_byte(cfg.a_cache_bytes)
        r_cache = cfg.r_cache_bytes or cfg.a_cache_bytes
        e += self.r_cache_writes * sram_pj_per_byte(r_cache)
        return e

    def dram_energy_pj(self) -> float:
        return (self.dram_weight_bytes + self.dram_act_bytes
                + self.dram_out_bytes) * DRAM_PJ_PER_BYTE


def analyze_traffic(shape: LayerShape, mapping: Mapping,
                    accel: str = "bitparticle") -> Traffic:
    """First-order reuse analysis of the chosen schedule.

    Per step: 16 weights read (one per row, shared across 32 columns) and 32
    activations read (one per column, reused down the 16 rows by
    propagation).  Outputs accumulate in-PE across the reduction loops and
    are written once.  DRAM: weights/acts fetched once if their per-tile
    working set fits the cache, else refetched per outer spatial tile
    (loop order B-OY1-OX1-K1-FY-FX-C, Section IV-A2).
    """
    cfg = ACCEL_CONFIGS[accel]
    w_cache_reads = mapping.steps * ROWS
    a_cache_reads = mapping.steps * COLS
    r_cache_writes = shape.output_count

    w_bytes = shape.weight_count  # int8
    a_bytes = shape.input_count
    o_bytes = shape.output_count

    if mapping.dataflow == "a":
        n_ox1 = _ceil(shape.OX, mapping.oxu)
        n_oy1 = _ceil(shape.OY, mapping.oyu)
        outer_spatial = shape.B * n_ox1 * n_oy1
    else:
        outer_spatial = _ceil(shape.B, COLS)
    # weights refetched per outer spatial iteration unless they fit W-cache
    w_refetch = 1 if w_bytes <= cfg.w_cache_bytes else outer_spatial
    # activations refetched per K1 tile unless they fit A-cache
    a_refetch = 1 if a_bytes <= cfg.a_cache_bytes else _ceil(shape.K, ROWS)
    return Traffic(
        w_cache_reads=w_cache_reads,
        a_cache_reads=a_cache_reads,
        r_cache_writes=r_cache_writes,
        dram_weight_bytes=w_bytes * w_refetch,
        dram_act_bytes=a_bytes * a_refetch,
        dram_out_bytes=o_bytes,
    )


def network_mapping_report(layers: Iterable[LayerShape]):
    """Per-layer mapping decisions + aggregate utilization."""
    rows = []
    tot_macs = tot_steps = 0
    for layer in layers:
        m = choose_mapping(layer)
        rows.append((layer, m))
        tot_macs += layer.total_macs
        tot_steps += m.steps
    agg_util = tot_macs / (tot_steps * ROWS * COLS) if tot_steps else 0.0
    return rows, agg_util
