"""Trace-time tap that collects int8 activation sparsity stats (paper
Section IV-B3 measured on live operands instead of synthetic samples).

The serving executor wraps a jitted step function's body in ``probe_tap()``;
while the tap is active, the quantized-matmul call sites
(``models/layers.dense`` and ``core/bp_matmul.dense_apply``) call
``record_activation`` with the float activation just before it is quantized
and dispatched.  ``record_activation`` recomputes the identical per-row
quantization, reduces the int8 operand to a ``sparsity.N_STATS`` sum row,
and parks it on a thread-local frame.  The model's layer scan drains the
frame once per layer (``drain_layer`` inside the scan body, stacked by the
scan into an ``(L, N_STATS)`` array) and publishes the stack with
``emit_layers``; ``collect`` hands the executor one small array — the only
thing that leaves the device.

Everything here runs at *trace* time (the idiom of
``bp_matmul.use_matmul_backend``): with no active frame every hook is a
no-op, so untapped traces — the NULL_PROBE path — stage byte-identical
programs.
"""

from __future__ import annotations

import contextlib
import threading

import jax.numpy as jnp

from repro.core import quant
from repro.core.sparsity import N_STATS, sm_bit_stats


class _Frame:
    __slots__ = ("pending", "layers", "extra")

    def __init__(self):
        self.pending = []   # stat rows recorded since the last drain
        self.layers = None  # (L, N_STATS) published by emit_layers
        self.extra = []     # pre-/post-scan rows (embedding tail, lm head)


class _TapState(threading.local):
    def __init__(self):
        self.frames = []


_state = _TapState()


def tap_active() -> bool:
    return bool(_state.frames)


@contextlib.contextmanager
def probe_tap():
    """Activate the tap for the enclosed trace; nests safely."""
    frame = _Frame()
    _state.frames.append(frame)
    try:
        yield frame
    finally:
        _state.frames.pop()


def record_activation(x):
    """Record sparsity stats of ``x`` as the int8 operand the kernel sees.

    Recomputes the same per-row symmetric quantization
    ``quantized_matmul`` applies, so the stats are measured on exactly the
    operand values the MAC array would stream.  No-op without an active tap.
    """
    if not _state.frames:
        return
    x = jnp.asarray(x, jnp.float32)
    x_scale = quant.compute_scale(x, axis=(-1,))
    x_q = quant.quantize(x, x_scale)
    _state.frames[-1].pending.append(sm_bit_stats(x_q))


def drain_layer():
    """``(N_STATS,)`` sum of rows recorded since the last drain.

    Called inside the model's layer-scan body; the scan stacks the returned
    rows into the per-layer axis.  Returns zeros when the layer recorded
    nothing (e.g. bf16 mode slipped through) so shapes stay static.
    """
    if not _state.frames:
        return jnp.zeros((N_STATS,), jnp.float32)
    frame = _state.frames[-1]
    if not frame.pending:
        return jnp.zeros((N_STATS,), jnp.float32)
    row = sum(frame.pending[1:], frame.pending[0])
    frame.pending = []
    return row


def absorb_pending():
    """Move rows recorded *before* the layer scan into the extra bucket.

    Must run before entering ``lax.scan``: anything still pending would be
    a closure constant of the scan body and get re-drained once per layer.
    No-op without an active tap.
    """
    if not _state.frames:
        return
    frame = _state.frames[-1]
    if frame.pending:
        frame.extra.extend(frame.pending)
        frame.pending = []


def emit_layers(stacked):
    """Publish the scan-stacked ``(L, N_STATS)`` per-layer stats."""
    if not _state.frames:
        return
    _state.frames[-1].layers = stacked


def collect():
    """Final ``(L[+1], N_STATS)`` stats array for the executor, or None.

    The extra bucket (plus any still-pending rows, e.g. the lm head matmul
    after the scan) is summed into one trailing row.  Returns None — without
    touching ``pending`` — when no layers were emitted: for uninstrumented
    model families the pending rows may hold tracers from inner scopes that
    must not escape.
    """
    if not _state.frames:
        return None
    frame = _state.frames[-1]
    if frame.layers is None:
        return None
    rows = frame.extra + frame.pending
    frame.pending = []
    frame.extra = []
    if not rows:
        return frame.layers
    tail = sum(rows[1:], rows[0])
    return jnp.concatenate([frame.layers, tail[None, :]], axis=0)
