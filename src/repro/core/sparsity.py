"""Value- and bit-level sparsity statistics (paper Fig. 1 and Section IV-B3).

The paper measures bit-level sparsity of 8-bit quantized tensors in
sign-magnitude representation (7 magnitude bits per element) and contrasts it
with 2's-complement, which exhibits lower sparsity for negative values.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.bitparticle import _popcount7, to_sign_magnitude


def value_sparsity(q):
    """Fraction of exactly-zero elements."""
    q = jnp.asarray(q)
    return jnp.mean((q == 0).astype(jnp.float32))


def bit_sparsity_sign_magnitude(q, nonzero_only: bool = False):
    """Mean fraction of zero bits among the 7 magnitude bits.

    ``nonzero_only`` restricts the average to nonzero elements (the paper's
    "bit-level sparsity of non-zero elements", Section IV-B3).
    """
    _, mag = to_sign_magnitude(q)
    zeros = 7 - _popcount7(mag)
    frac = zeros.astype(jnp.float32) / 7.0
    if nonzero_only:
        m = (mag != 0).astype(jnp.float32)
        return jnp.sum(frac * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(frac)


def popcount8(u):
    """Set-bit count of the low 8 bits, via a broadcast bit expansion."""
    u = jnp.asarray(u, jnp.int32)
    return jnp.sum((u[..., None] >> jnp.arange(8)) & 1, axis=-1)


def bit_sparsity_twos_complement(q):
    """Mean fraction of zero bits among all 8 bits of the 2's-complement form."""
    q = jnp.asarray(q, jnp.int32)
    u = jnp.where(q < 0, q + 256, q)  # 8-bit two's complement pattern
    return jnp.mean((8 - popcount8(u)).astype(jnp.float32) / 8.0)


# Per-tensor stat rows used by the serving probe: a fixed-width float32
# vector whose entries are pure sums, so rows from different tensors (or
# different devices) add together exactly before being turned into rates.
N_STATS = 3  # [sum of zero magnitude bits, n elements, n zero values]


def sm_bit_stats(q):
    """``(N_STATS,)`` float32 sum-form sparsity stats of one int8 tensor.

    ``stats_to_rates`` recovers ``bit_sparsity_sign_magnitude`` /
    ``value_sparsity`` exactly: the bit sparsity here is the element-weighted
    mean, identical to ``mean((7 - popcount7(mag)) / 7)``.
    """
    _, mag = to_sign_magnitude(q)
    zero_bits = (7 - _popcount7(mag)).astype(jnp.float32)
    return jnp.stack([jnp.sum(zero_bits),
                      jnp.float32(mag.size),
                      jnp.sum((mag == 0).astype(jnp.float32))])


def per_layer_stats(q):
    """``(L, N_STATS)`` stats of a layer-stacked int8 tensor (leading axis L)."""
    q = jnp.asarray(q)
    _, mag = to_sign_magnitude(q.reshape(q.shape[0], -1))
    zero_bits = (7 - _popcount7(mag)).astype(jnp.float32)
    n = jnp.full((q.shape[0],), mag.shape[1], jnp.float32)
    return jnp.stack([jnp.sum(zero_bits, axis=1), n,
                      jnp.sum((mag == 0).astype(jnp.float32), axis=1)],
                     axis=-1)


def stats_to_rates(stats):
    """(bit_sparsity, value_sparsity) from summed ``sm_bit_stats`` rows.

    Works on a single ``(N_STATS,)`` row or a stacked ``(..., N_STATS)``
    array; zero-element rows yield 0.0 rather than NaN.
    """
    stats = jnp.asarray(stats, jnp.float32)
    n = jnp.maximum(stats[..., 1], 1.0)
    return stats[..., 0] / (7.0 * n), stats[..., 2] / n


def sample_with_bit_sparsity(key, shape, bit_sparsity: float, value_sparsity_p: float = 0.0):
    """Generate sign-magnitude int operands matching the paper's generator.

    Each of the 7 magnitude bits is independently 0 with probability
    ``bit_sparsity``; sign is uniform; optionally a fraction
    ``value_sparsity_p`` of elements is forced to exact zero.
    (Section IV-B3: "assigns each bit a probability of bs to be 0".)
    """
    import jax

    kb, ks, kz = jax.random.split(key, 3)
    bits = jax.random.bernoulli(kb, 1.0 - bit_sparsity, shape + (7,))
    mag = jnp.sum(bits.astype(jnp.int32) << jnp.arange(7), axis=-1)
    sign = jax.random.bernoulli(ks, 0.5, shape)
    val = jnp.where(sign, -mag, mag)
    if value_sparsity_p > 0.0:
        zero = jax.random.bernoulli(kz, value_sparsity_p, shape)
        val = jnp.where(zero, 0, val)
    return val
