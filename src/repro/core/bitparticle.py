"""BitParticle core numerics: particlization-based dual-factor bit-sparse MAC.

Faithful, bit-exact emulation of the MAC unit of

    "BitParticle: Partializing Sparse Dual-Factors to Build Quasi-Synchronizing
     MAC Arrays for Energy-efficient DNNs" (cs.AR 2025), Section III.

Operands are 8-bit **sign-magnitude**: 1 sign bit + 7 magnitude bits, range
[-127, 127].  Each 7-bit magnitude is split into four *particles* with bit
widths (2, 2, 2, 1) and LSB weights (0, 2, 4, 6):

    p0 = m[1:0]   p1 = m[3:2]   p2 = m[5:4]   p3 = m[6]

Cross-multiplying the particles of the two operands yields a 4x4 matrix of
*intermediate results* (IRs); IR(i, j) = pa_i * pw_j has LSB weight 2*(i+j)
and position ID 4*i + j.  IRs on the same anti-diagonal (i + j = k) share an
LSB weight and form the seven *groups* (k = 0..6).  The groups are split into

    Group Set 0:  k in {0, 2, 4, 6}   -> IDs {0}, {2,5,8}, {7,10,13}, {15}
    Group Set 1:  k in {1, 3, 5}      -> IDs {1,4}, {3,6,9,12}, {11,14}

Within a set, one selected IR per group never overlaps another group's field,
so the selections *concatenate* (zero-overhead wiring) into one partial
product of <= 13 bits per set.  One IR per group is consumed per cycle, hence

    cycles(a, w) = max(1, max_k #nonzero IRs in group k)  in  [1, 4]

and at most 3 (set 0) + 4 (set 1) = 7 partial products are ever produced --
matching a conventional 7-bit multiplier's worst case.

The *approximate* variant (Section III-B4) unconditionally discards group
{0} (k=0) and group {1,4} (k=1):

    approx(|a|, |w|) = |a|*|w| - a0*w0 - 4*(a0*w1 + a1*w0)

with a0 = |a| & 3, a1 = (|a| >> 2) & 3 (same for w), sign applied afterwards.

Everything here is vectorized jnp over arbitrary-shaped integer arrays and is
the single source of truth ("oracle") for the Pallas kernels, the cycle/energy
cost models, and the benchmark suite.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Static structure of the particlization (Section III-A, Fig. 4).
# ---------------------------------------------------------------------------

PARTICLE_WIDTHS = (2, 2, 2, 1)          # widths of p0..p3 (LSB..MSB order)
PARTICLE_LSB_WEIGHTS = (0, 2, 4, 6)     # LSB weight of p0..p3
NUM_PARTICLES = 4
NUM_GROUPS = 7                           # anti-diagonals k = i + j in 0..6

#: group k -> tuple of position IDs (ID = 4*i + j) lying on anti-diagonal k.
GROUP_IDS = tuple(
    tuple(4 * i + j for i in range(4) for j in range(4) if i + j == k)
    for k in range(NUM_GROUPS)
)
# GROUP_IDS == ((0,), (1, 4), (2, 5, 8), (3, 6, 9, 12), (7, 10, 13), (11, 14), (15,))

#: the paper's two group sets (by anti-diagonal index k).
GROUP_SET0 = (0, 2, 4, 6)   # LSB weights 0, 4, 8, 12  -> one 13-bit PP
GROUP_SET1 = (1, 3, 5)      # LSB weights 2, 6, 10     -> one 13-bit PP

#: groups discarded by the approximate variant: group "0" and group "1-4".
APPROX_DROPPED_GROUPS = (0, 1)

#: the seven representable IR values (2-bit x 2-bit products).
IR_VALUE_SET = (0, 1, 2, 3, 4, 6, 9)

MAX_MAGNITUDE = 127          # sign-magnitude 8-bit range is [-127, 127]
MAX_CYCLES = 4               # largest group has 4 IRs
MAX_PARTIAL_PRODUCTS = 7     # 3 from set 0 + 4 from set 1


# ---------------------------------------------------------------------------
# Sign-magnitude helpers.
# ---------------------------------------------------------------------------

def to_sign_magnitude(x):
    """Split signed ints in [-127, 127] into (sign, magnitude).

    sign is 1 for negative, 0 otherwise (int32); magnitude is |x| (int32).
    """
    x = jnp.asarray(x, jnp.int32)
    return (x < 0).astype(jnp.int32), jnp.abs(x)


def from_sign_magnitude(sign, mag):
    sign = jnp.asarray(sign, jnp.int32)
    mag = jnp.asarray(mag, jnp.int32)
    return jnp.where(sign != 0, -mag, mag)


# ---------------------------------------------------------------------------
# Step 1-2: particlization and the IR matrix.
# ---------------------------------------------------------------------------

def particlize(mag):
    """Split 7-bit magnitudes into particles.  Returns (..., 4) int32.

    Particle order is LSB-first: [m&3, (m>>2)&3, (m>>4)&3, (m>>6)&1].
    """
    mag = jnp.asarray(mag, jnp.int32)
    p0 = mag & 3
    p1 = (mag >> 2) & 3
    p2 = (mag >> 4) & 3
    p3 = (mag >> 6) & 1
    return jnp.stack([p0, p1, p2, p3], axis=-1)


def unparticlize(particles):
    """Inverse of :func:`particlize` (for round-trip tests)."""
    p = jnp.asarray(particles, jnp.int32)
    return p[..., 0] + (p[..., 1] << 2) + (p[..., 2] << 4) + (p[..., 3] << 6)


def ir_matrix(mag_a, mag_w):
    """The 4x4 intermediate-result matrix.  Returns (..., 4, 4) int32.

    IR[..., i, j] = particle_i(|a|) * particle_j(|w|); LSB weight 2*(i+j).
    """
    pa = particlize(mag_a)[..., :, None]
    pw = particlize(mag_w)[..., None, :]
    return pa * pw


# i + j for the (4, 4) IR matrix — anti-diagonal (= group) index per position.
_DIAG_INDEX = np.add.outer(np.arange(4), np.arange(4))  # (4, 4) ints 0..6


# ---------------------------------------------------------------------------
# Step 3-5: grouping, selection, concatenation, accumulation.
# ---------------------------------------------------------------------------

def group_nonzero_counts(mag_a, mag_w):
    """#nonzero IRs per anti-diagonal group.  Returns (..., 7) int32."""
    irs = ir_matrix(mag_a, mag_w)
    nz = (irs != 0).astype(jnp.int32)
    counts = []
    for k in range(NUM_GROUPS):
        mask = jnp.asarray(_DIAG_INDEX == k)
        counts.append(jnp.sum(nz * mask, axis=(-2, -1)))
    return jnp.stack(counts, axis=-1)


def mac_cycles(a, w, approx: bool = False):
    """Initiation interval (cycles) of one BitParticle MAC, elementwise.

    cycles = max(1, max_k nnz_k) over the groups the variant evaluates.
    Zero-valued products still cost one cycle here; zero-value *filtering*
    (cost 0) is an array-level mechanism handled by the scheduler/simulator.
    """
    _, mag_a = to_sign_magnitude(a)
    _, mag_w = to_sign_magnitude(w)
    counts = group_nonzero_counts(mag_a, mag_w)
    if approx:
        keep = np.array([k not in APPROX_DROPPED_GROUPS for k in range(NUM_GROUPS)])
        counts = counts * jnp.asarray(keep, jnp.int32)
    return jnp.maximum(1, jnp.max(counts, axis=-1))


def magnitude_product_from_irs(mag_a, mag_w, dropped_groups=()):
    """Reconstruct |a|*|w| as the weighted IR sum (the hardware's math).

    ``dropped_groups`` lists anti-diagonal indices whose IRs are discarded
    (the approximate variant uses ``APPROX_DROPPED_GROUPS``).
    """
    irs = ir_matrix(mag_a, mag_w)
    weights = np.left_shift(1, 2 * _DIAG_INDEX).astype(np.int64)
    for k in dropped_groups:
        weights = np.where(_DIAG_INDEX == k, 0, weights)
    return jnp.sum(irs * jnp.asarray(weights, jnp.int32), axis=(-2, -1))


def multiply_exact(a, w):
    """Signed exact BitParticle product (== a * w, verified exhaustively)."""
    sa, ma = to_sign_magnitude(a)
    sw, mw = to_sign_magnitude(w)
    mag = magnitude_product_from_irs(ma, mw)
    return from_sign_magnitude(sa ^ sw, mag)


def multiply_approx(a, w):
    """Signed approximate BitParticle product (groups {0} and {1,4} dropped)."""
    sa, ma = to_sign_magnitude(a)
    sw, mw = to_sign_magnitude(w)
    mag = magnitude_product_from_irs(ma, mw, APPROX_DROPPED_GROUPS)
    return from_sign_magnitude(sa ^ sw, mag)


def approx_correction(a, w):
    """The signed term subtracted by the approximate variant.

    multiply_approx(a, w) == a*w - approx_correction(a, w), with

        correction = s * (a0*w0 + 4*(a0*w1 + a1*w0)),   s = sign(a)*sign(w)

    This *algebraic* form is what the Pallas matmul kernel uses: defining the
    signed low particles A0 = sign(a)*(|a| & 3), A1 = sign(a)*((|a|>>2) & 3)
    (same for W), the correction of a dot product factorizes into three small
    matmuls:  A0@W0 + 4*(A0@W1 + A1@W0).
    """
    sa, ma = to_sign_magnitude(a)
    sw, mw = to_sign_magnitude(w)
    a0, a1 = ma & 3, (ma >> 2) & 3
    w0, w1 = mw & 3, (mw >> 2) & 3
    mag = a0 * w0 + 4 * (a0 * w1 + a1 * w0)
    return from_sign_magnitude(sa ^ sw, mag)


# ---------------------------------------------------------------------------
# Cycle-by-cycle partial-product assembly (Section III-B1).
#
# This mirrors the datapath literally: per cycle, one nonzero IR is selected
# from every group by priority (lowest position ID first, matching the
# priority-selection logic), the set-0 and set-1 selections are concatenated
# into two partial products, added by the 13-bit adder and accumulated.
# It exists to *prove* the <=7-PP claim and the concatenation-overlap-freedom
# claim in tests; bulk numerics use the closed forms above.
# ---------------------------------------------------------------------------

def assemble_partial_products(a: int, w: int):
    """Scalar, python-level datapath emulation.

    Returns (product, pps, cycles) where ``pps`` is the list of (set0_pp,
    set1_pp) pairs produced per cycle, as signed-magnitude integers before
    sign application.
    """
    a, w = int(a), int(w)
    assert abs(a) <= MAX_MAGNITUDE and abs(w) <= MAX_MAGNITUDE
    sign = (a < 0) != (w < 0)
    ma, mw = abs(a), abs(w)
    pa = [(ma >> s) & (2 ** wd - 1) for s, wd in zip(PARTICLE_LSB_WEIGHTS, PARTICLE_WIDTHS)]
    pw = [(mw >> s) & (2 ** wd - 1) for s, wd in zip(PARTICLE_LSB_WEIGHTS, PARTICLE_WIDTHS)]
    # nonzero register: ID -> IR value (only nonzero entries retained)
    pending = {}
    for i in range(4):
        for j in range(4):
            v = pa[i] * pw[j]
            if v:
                pending[4 * i + j] = v
    pps = []
    acc = 0
    cycles = 0
    while True:
        cycles += 1
        set_pps = []
        for group_set in (GROUP_SET0, GROUP_SET1):
            pp = 0
            for k in group_set:
                for pos in GROUP_IDS[k]:          # priority: lowest ID first
                    if pos in pending:
                        ir = pending.pop(pos)
                        field = ir << (2 * k)
                        assert pp & field == 0, "concatenation fields overlap"
                        pp |= field                # concatenation, not addition
                        break
            set_pps.append(pp)
        pps.append(tuple(set_pps))
        acc += set_pps[0] + set_pps[1]             # the 13-bit adder + accumulate
        if not pending:
            break
        assert cycles < MAX_CYCLES + 1
    return (-acc if sign else acc), pps, max(1, cycles)


# ---------------------------------------------------------------------------
# 3-bit IR encoding (Section III-B3): values {0,1,2,3,4,6,9}, 9 -> 0b111.
# ---------------------------------------------------------------------------

def ir_encode3(ir):
    """Encode a 4-bit IR value in {0,1,2,3,4,6,9} into 3 bits (9 -> 7)."""
    ir = jnp.asarray(ir, jnp.int32)
    return jnp.where(ir == 9, 7, ir)


def ir_decode3(code):
    """Inverse of :func:`ir_encode3` (7 -> 9)."""
    code = jnp.asarray(code, jnp.int32)
    return jnp.where(code == 7, 9, code)


# ---------------------------------------------------------------------------
# Skipped-calculations metric (Section V-C, Fig. 11).
#
# A 7x7 grid of single-bit multiplications per MAC; a bitwise product with a
# zero operand bit is "skippable".  Metric = skipped / 49, averaged.
# ---------------------------------------------------------------------------

def _popcount7(mag):
    mag = jnp.asarray(mag, jnp.int32)
    c = jnp.zeros_like(mag)
    for b in range(7):
        c = c + ((mag >> b) & 1)
    return c


def skipped_calculations(a, w, method: str):
    """Fraction of the 49 single-bit products skipped, elementwise.

    methods:
      ``ideal``      skip every product with a zero bit on either side.
      ``bit_serial`` skip zero bits of operand ``a`` only (7 products each).
      ``bp_exact``   skip products inside all-zero 2-bit particles (both sides).
      ``bp_approx``  bp_exact plus the unconditionally dropped groups k in {0,1}.
    """
    _, ma = to_sign_magnitude(a)
    _, mw = to_sign_magnitude(w)
    if method == "ideal":
        computed = _popcount7(ma) * _popcount7(mw)
    elif method == "bit_serial":
        computed = _popcount7(ma) * 7
    elif method in ("bp_exact", "bp_approx"):
        pa = (particlize(ma) != 0).astype(jnp.int32)      # (..., 4)
        pw = (particlize(mw) != 0).astype(jnp.int32)
        widths = jnp.asarray(PARTICLE_WIDTHS, jnp.int32)
        wa = pa * widths                                   # bits evaluated per particle
        ww = pw * widths
        pair = wa[..., :, None] * ww[..., None, :]          # (..., 4, 4) bit products
        if method == "bp_approx":
            keep = jnp.asarray(_DIAG_INDEX >= 2, jnp.int32)
            pair = pair * keep
        computed = jnp.sum(pair, axis=(-2, -1))
    else:
        raise ValueError(f"unknown method: {method}")
    return 1.0 - computed.astype(jnp.float32) / 49.0
