"""Area / power / energy / cycle cost models (paper Tables II-III, Figs 12-13).

Two kinds of numbers live here, kept strictly apart:

  * **Cited constants** — the paper's RTL-synthesis results (45 nm, 500 MHz,
    Synopsys DC): per-unit area and power, and Table III's measured average
    cycles.  No RTL toolchain exists offline, so these are inputs, exactly as
    CACTI/DC outputs were inputs to the paper's own system model.
  * **First-principles models** — average-cycle models for BitParticle (from
    the bit-exact emulation), an ideal bit-serial unit, and BitWave's
    column-skip scheme, Monte-Carlo'd over the paper's data generator.  The
    benchmark suite reports modeled-vs-cited deltas.

Memory energies follow Horowitz, "Computing's energy problem" (ISSCC 2014),
45 nm: ~10 pJ per 32-bit access for an 8 KiB SRAM, scaling ~sqrt(capacity);
DRAM ~1.3 nJ per 32-bit access.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitparticle as bp
from repro.core.sparsity import sample_with_bit_sparsity

CLOCK_HZ = 500e6
SPARSITY_LEVELS = (0.5, 0.6, 0.7, 0.8, 0.9)

# --- Table III (cited) ------------------------------------------------------

PAPER_AVG_CYCLES: Dict[str, tuple] = {
    "adas":      (3.22, 2.46, 1.80, 1.29, 1.04),
    "bitwave":   (0.91, 0.85, 0.76, 0.62, 0.42),
    "bp_exact":  (2.14, 1.71, 1.34, 1.10, 1.01),
    "bp_approx": (2.12, 1.69, 1.33, 1.10, 1.01),
}

AREA_UM2: Dict[str, float] = {
    "adas": 462.04, "bitwave": 1504.76, "bp_exact": 544.50, "bp_approx": 443.42,
}

POWER_UW: Dict[str, tuple] = {
    "adas":      (439.81, 434.80, 420.49, 368.47, 285.83),
    "bitwave":   (1054.50, 1008.10, 923.44, 867.41, 728.43),
    "bp_exact":  (509.38, 481.01, 451.49, 392.54, 318.13),
    "bp_approx": (432.20, 409.94, 386.40, 339.17, 273.24),
}

# --- Table II (cited) -------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AcceleratorConfig:
    name: str
    pe_count: int
    w_cache_bytes: int
    a_cache_bytes: int
    r_cache_bytes: int
    metadata_bytes: int = 0


ACCEL_CONFIGS = {
    "bitparticle": AcceleratorConfig("bitparticle", 512, 64 << 10, 128 << 10, 128 << 10),
    "bitwave": AcceleratorConfig("bitwave", 512, 256 << 10, 256 << 10, 0),
    "adas": AcceleratorConfig("adas", 256, 128 << 10, 128 << 10, 0, 64 << 10),
}

# --- Memory energy / area (Horowitz ISSCC'14-derived, 45 nm) ---------------

DRAM_PJ_PER_BYTE = 1300.0 / 4.0          # ~1.3 nJ / 32-bit access
SRAM_MM2_PER_KB = 0.0007 * 2.0           # ~1.4e-3 mm^2 per KB at 45 nm


def sram_pj_per_byte(capacity_bytes: int) -> float:
    """~10 pJ / 32-bit at 8 KiB, scaling with sqrt(capacity)."""
    return (10.0 / 4.0) * math.sqrt(max(capacity_bytes, 1024) / 8192.0)


# --- First-principles average-cycle models ----------------------------------

def _mc_operands(bit_sparsity: float, n: int, seed: int,
                 w_bit_sparsity=None):
    ka, kw = jax.random.split(jax.random.PRNGKey(seed))
    a = sample_with_bit_sparsity(ka, (n,), bit_sparsity)
    w = sample_with_bit_sparsity(
        kw, (n,),
        bit_sparsity if w_bit_sparsity is None else w_bit_sparsity)
    return a, w


def _avg_cycles(method: str, a, w, n: int) -> float:
    if method in ("bp_exact", "bp_approx"):
        c = bp.mac_cycles(a, w, approx=(method == "bp_approx"))
        return float(jnp.mean(c.astype(jnp.float32)))
    if method == "bit_serial":
        _, mag = bp.to_sign_magnitude(a)
        nnz = bp._popcount7(mag)
        return float(jnp.mean(jnp.maximum(1, nnz).astype(jnp.float32)))
    if method == "bitwave":
        _, mag = bp.to_sign_magnitude(a)
        groups = mag[: n // 8 * 8].reshape(-1, 8)
        cols = jnp.zeros((groups.shape[0],), jnp.int32)
        for b in range(7):
            cols = cols + (jnp.any((groups >> b) & 1, axis=1)).astype(jnp.int32)
        return float(jnp.mean(cols.astype(jnp.float32))) / 8.0
    raise ValueError(method)


def modeled_avg_cycles(method: str, bit_sparsity: float, n: int = 200_000,
                       seed: int = 0) -> float:
    """Monte-Carlo average cycles per MAC under the paper's data generator.

    methods: ``bp_exact`` / ``bp_approx`` — the emulated BitParticle unit;
    ``bit_serial`` — idealized single-factor bit-serial (AdaS-class):
    cycles = max(1, #nonzero magnitude bits of one operand);
    ``bitwave`` — 8-lane column skipping: a bit column is processed iff any
    of 8 grouped operands has a 1 there; cycles/op = surviving columns / 8.
    """
    a, w = _mc_operands(bit_sparsity, n, seed)
    return _avg_cycles(method, a, w, n)


def modeled_avg_cycles_dual(method: str, a_bit_sparsity: float,
                            w_bit_sparsity: float, n: int = 200_000,
                            seed: int = 0) -> float:
    """`modeled_avg_cycles` with separate activation / weight sparsities.

    The serving probe measures the two factors at different rates (live
    activations vs frozen weights); the single-sparsity model above is the
    diagonal of this one.  For the single-factor methods (``bit_serial``,
    ``bitwave``) only ``a_bit_sparsity`` matters.
    """
    a, w = _mc_operands(a_bit_sparsity, n, seed,
                        w_bit_sparsity=w_bit_sparsity)
    return _avg_cycles(method, a, w, n)


# --- Efficiency metrics (Table III derivations) ------------------------------

def tops(avg_cycles: float, n_units: int = 1) -> float:
    """Tera-ops/s: one MAC = 2 ops, at CLOCK_HZ, initiation interval = cycles."""
    return 2.0 * CLOCK_HZ * n_units / avg_cycles / 1e12


def area_efficiency(avg_cycles: float, area_um2: float) -> float:
    """TOPS / mm^2 for a single unit."""
    return tops(avg_cycles) / (area_um2 * 1e-6)


def energy_efficiency(avg_cycles: float, power_uw: float) -> float:
    """TOPS / W for a single unit."""
    return tops(avg_cycles) / (power_uw * 1e-6)


def table3(cycles_source: str = "paper") -> Dict[str, Dict[str, list]]:
    """Reproduce Table III's normalized efficiency rows.

    ``cycles_source``: "paper" uses the cited cycle measurements, "model"
    uses our first-principles Monte-Carlo models (adas -> bit_serial model).
    """
    methods = ("adas", "bitwave", "bp_exact", "bp_approx")
    out = {m: {"avg_cycles": [], "area_eff": [], "energy_eff": []} for m in methods}
    for i, bs in enumerate(SPARSITY_LEVELS):
        for m in methods:
            if cycles_source == "paper":
                c = PAPER_AVG_CYCLES[m][i]
            else:
                c = modeled_avg_cycles("bit_serial" if m == "adas" else m, bs)
            out[m]["avg_cycles"].append(c)
            out[m]["area_eff"].append(area_efficiency(c, AREA_UM2[m]))
            out[m]["energy_eff"].append(energy_efficiency(c, POWER_UW[m][i]))
    # normalize to AdaS, per sparsity level (the paper's presentation)
    for key in ("area_eff", "energy_eff"):
        base = list(out["adas"][key])
        for m in methods:
            out[m][key] = [v / b for v, b in zip(out[m][key], base)]
    return out


# --- Per-tensor deployment pricing (framework integration) -------------------

def avg_cycles_for_tensors(w_q, a_q, approx: bool = False,
                           zero_filter: bool = True) -> float:
    """Expected BitParticle cycles/MAC if these quantized tensors were run on
    the modeled array — prices real model layers (examples/estimate)."""
    w = jnp.asarray(w_q, jnp.int32).reshape(-1)
    a = jnp.asarray(a_q, jnp.int32).reshape(-1)
    n = min(w.shape[0], a.shape[0], 200_000)
    w = w[:n]
    a = jax.random.permutation(jax.random.PRNGKey(0), a)[:n]
    c = bp.mac_cycles(w, a, approx=approx).astype(jnp.float32)
    if zero_filter:
        c = jnp.where((w == 0) | (a == 0), 0.0, c)
    return float(jnp.mean(c))


def mac_energy_pj(unit: str, bit_sparsity: float) -> float:
    """Per-MAC energy: (power / clock) x avg cycles, interpolating Table III."""
    bs = float(np.clip(bit_sparsity, SPARSITY_LEVELS[0], SPARSITY_LEVELS[-1]))
    xs = np.asarray(SPARSITY_LEVELS)
    p = float(np.interp(bs, xs, np.asarray(POWER_UW[unit])))
    c = float(np.interp(bs, xs, np.asarray(PAPER_AVG_CYCLES[unit])))
    return (p * 1e-6 / CLOCK_HZ) * c * 1e12
