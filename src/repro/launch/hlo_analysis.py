"""Trip-count-aware analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
undercounts layer-scanned models by ~num_layers.  This module re-derives
per-device costs from ``compiled.as_text()`` honestly:

  1. parse every computation and instruction (name -> shape),
  2. build the call graph (while bodies, fusions, calls, conditionals) and
     propagate execution multipliers — a while body's multiplier is its trip
     count (recovered from the loop-condition's comparison constant) times
     the multiplier of the enclosing computation,
  3. count dot FLOPs (2 x numel(result) x contracted size) and collective
     wire bytes (all-gather: result bytes; others: operand bytes) with those
     multipliers applied.

Validated in tests against closed-form FLOP counts of scanned models.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"^\(?\s*([a-z]+[0-9]+|pred|token)\[([0-9,]*)\]")
_OPCODE = re.compile(r"\}?\s*([\w\-]+)\(")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_CALL_ATTR = re.compile(r"(?:body|calls|to_apply|branch_computations)="
                        r"\{?%?([\w\.\-,%\s]+?)\}?[,\s)]")
_COND_ATTR = re.compile(r"condition=%?([\w\.\-]+)")
_CONSTANT = re.compile(r"=\s*[su]\d+\[\]\s*constant\((\d+)\)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_tuple(type_str: str) -> Tuple[Optional[str], Tuple[int, ...]]:
    m = _SHAPE.match(type_str.strip())
    if not m:
        return None, ()
    dims = tuple(int(d) for d in m.group(2).split(",") if d)
    return m.group(1), dims


def _numel(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


@dataclasses.dataclass
class Instruction:
    name: str
    dtype: Optional[str]
    dims: Tuple[int, ...]
    opcode: str
    text: str

    @property
    def result_bytes(self) -> int:
        return _numel(self.dims) * _DTYPE_BYTES.get(self.dtype or "", 4)


@dataclasses.dataclass
class HloModule:
    computations: Dict[str, List[Instruction]]
    entry: str
    instr_index: Dict[str, Instruction]      # global name -> instruction


def parse_module(text: str) -> HloModule:
    computations: Dict[str, List[Instruction]] = {}
    entry = ""
    current: Optional[str] = None
    for line in text.splitlines():
        h = _COMP_HEADER.match(line)
        if h:
            current = h.group(2)
            computations[current] = []
            if h.group(1):
                entry = current
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        dtype, dims = _shape_tuple(rest)
        # opcode = first word followed by '(' after the type (skip tuple types)
        after_type = rest
        # drop the leading type expression (possibly a tuple) conservatively
        op = ""
        om = re.search(r"\)?\s([\w\-]+)\(", " " + after_type)
        if om:
            op = om.group(1)
        computations[current].append(
            Instruction(name, dtype, dims, op, line.strip()))
    index = {}
    for comp, instrs in computations.items():
        for ins in instrs:
            index[ins.name] = ins
    return HloModule(computations, entry, index)


def _trip_count(module: HloModule, cond_name: str) -> int:
    """Largest scalar integer constant in the loop condition computation."""
    best = 1
    for ins in module.computations.get(cond_name, []):
        for m in _CONSTANT.finditer(ins.text):
            best = max(best, int(m.group(1)))
    return best


def computation_multipliers(module: HloModule) -> Dict[str, float]:
    """Execution count of each computation relative to one entry execution."""
    mult: Dict[str, float] = defaultdict(float)
    mult[module.entry] = 1.0
    # iterate to fixpoint over the call DAG (computations are defined before
    # use in text order is not guaranteed, so sweep until stable)
    for _ in range(64):
        changed = False
        for comp, instrs in module.computations.items():
            m_parent = mult.get(comp, 0.0)
            if m_parent == 0.0:
                continue
            for ins in instrs:
                if " while(" in ins.text:
                    body = re.search(r"body=%?([\w\.\-]+)", ins.text)
                    cond = _COND_ATTR.search(ins.text)
                    if body:
                        trips = _trip_count(module, cond.group(1)) if cond else 1
                        tgt = body.group(1)
                        new = m_parent * trips
                        if mult[tgt] < new:
                            mult[tgt] = new
                            changed = True
                    if cond:
                        new = m_parent * (_trip_count(module, cond.group(1)) + 1)
                        if mult[cond.group(1)] < new:
                            mult[cond.group(1)] = new
                            changed = True
                    continue
                for attr in ("calls", "to_apply", "branch_computations"):
                    mm = re.search(attr + r"=\{?%?([\w\.\-]+)", ins.text)
                    if mm:
                        tgt = mm.group(1)
                        if tgt in module.computations and mult[tgt] < m_parent:
                            mult[tgt] = m_parent
                            changed = True
        if not changed:
            break
    return dict(mult)


def _dot_flops(module: HloModule, ins: Instruction) -> float:
    """2 x numel(result) x contracted-dims size (batch dims cancel)."""
    ops = _OPERANDS.findall(ins.text.split("dot(", 1)[1].split(")", 1)[0])
    lhs = module.instr_index.get(ops[0]) if ops else None
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.text)
    k = 1
    if lhs is not None and cdims:
        for d in cdims.group(1).split(","):
            if d:
                k *= lhs.dims[int(d)] if int(d) < len(lhs.dims) else 1
    return 2.0 * _numel(ins.dims) * k


def _conv_flops(module: HloModule, ins: Instruction) -> float:
    # rare here (no convolutions in the LM stack); approximate by result
    return 2.0 * _numel(ins.dims)


_OPNAME = re.compile(r'op_name="([^"]*)"')


def analyze(text: str, top_k: int = 12) -> Dict[str, object]:
    module = parse_module(text)
    mult = computation_multipliers(module)
    dot_flops = 0.0
    dot_flops_int = 0.0     # int8 x int8 -> s32 contractions (2x MXU rate)
    coll_bytes = {k: 0.0 for k in COLLECTIVES}
    coll_counts = {k: 0.0 for k in COLLECTIVES}
    contributors: Dict[str, float] = defaultdict(float)
    loops = []
    for comp, instrs in module.computations.items():
        m = mult.get(comp, 0.0)
        if m == 0.0:
            continue
        for ins in instrs:
            if " dot(" in ins.text:
                f = m * _dot_flops(module, ins)
                if ins.dtype in ("s32", "s16", "s8"):
                    dot_flops_int += f
                else:
                    dot_flops += f
            elif " convolution(" in ins.text:
                dot_flops += m * _conv_flops(module, ins)
            elif " while(" in ins.text:
                cond = _COND_ATTR.search(ins.text)
                loops.append({"computation": comp,
                              "trips": _trip_count(module, cond.group(1))
                              if cond else 1, "multiplier": m})
            else:
                for kind in COLLECTIVES:
                    if f" {kind}(" in ins.text or f" {kind}-start(" in ins.text:
                        if kind == "all-gather":
                            nbytes = ins.result_bytes
                        else:
                            ops = _OPERANDS.findall(
                                ins.text.split("(", 1)[1].split(")", 1)[0])
                            nbytes = sum(
                                module.instr_index[o].result_bytes
                                for o in ops if o in module.instr_index)
                            nbytes = nbytes or ins.result_bytes
                        coll_bytes[kind] += m * nbytes
                        coll_counts[kind] += m
                        op = _OPNAME.search(ins.text)
                        label = (op.group(1)[:160] if op else ins.name)
                        contributors[f"{kind} | {label}"] += m * nbytes
                        break
    top = sorted(contributors.items(), key=lambda kv: -kv[1])[:top_k]
    return {
        "dot_flops_int_per_device": dot_flops_int,
        "dot_flops_per_device": dot_flops,
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "top_collectives": [{"op": k, "bytes": v} for k, v in top],
        "while_loops": loops,
        "n_computations": len(module.computations),
    }
