"""Production mesh construction (single-pod 16x16, multi-pod 2x16x16).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first backend init, so the
dry-run must set XLA_FLAGS before anything else — see dryrun.py).
"""

from __future__ import annotations

import numpy as np
import jax


def _make_mesh(shape, axes):
    """Version-portable mesh construction: ``axis_types`` where the new
    API exists (jax >= 0.5), a plain device-grid ``Mesh`` otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    n = int(np.prod(shape))
    return jax.sharding.Mesh(
        np.asarray(jax.devices()[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (virtual) devices exist — tests/examples."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return _make_mesh((data, model), ("data", "model"))


# TPU v5e-class hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
PEAK_FLOPS_INT8 = 394e12        # per chip (2x MXU throughput for int8)
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
HBM_PER_CHIP = 16 * 2**30       # bytes
