import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x shape) cell on
the production mesh and extract roofline inputs from the compiled artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]

Per cell this produces experiments/dryrun/<arch>__<shape>__<mesh>.json with
  * memory_analysis (bytes per device: argument/output/temp/peak) — fits?
  * cost_analysis   (per-device HLO FLOPs + bytes accessed)
  * collective_bytes by op kind, parsed from the optimized HLO
  * MODEL_FLOPS and useful-FLOPs ratio
which benchmarks/roofline.py turns into the three roofline terms.

The two os.environ lines above MUST precede any jax import: jax locks the
device count at first backend initialization.
"""

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ARCH_IDS, SHAPES, get_arch, shape_applicable)
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis
from repro.launch import mesh as mesh_lib
from repro.models import api
from repro.train import optimizer as opt_lib

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Cell construction: the function to lower + its input shardings
# ---------------------------------------------------------------------------

def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _batch_shardings(cfg, shape, mesh, recipe):
    """NamedShardings for the input_specs pytree."""
    dp = _dp_axes(mesh)
    dp = dp if len(dp) > 1 else (dp[0] if dp else None)
    seq_ax = "model" if recipe == "train" else None

    def spec_for(path, leaf):
        name = path[-1] if path else ""
        nd = len(leaf.shape)
        B = shape.global_batch
        bdim = dp if (B % _prod(mesh, dp) == 0) else None
        if name == "tokens":
            return P(bdim, None) if nd == 2 else P(bdim)
        if name == "positions":
            return P(None, bdim, seq_ax)
        if name in ("vision_embeds", "src_embeds"):
            return P(bdim, seq_ax, None)
        if name == "vision_mask":
            return P(bdim, seq_ax)
        if name == "cache_len":
            return P()
        return P(*([None] * nd))

    def rec(tree, path=()):
        if isinstance(tree, dict):
            return {k: rec(v, path + (k,)) for k, v in tree.items()}
        return NamedSharding(mesh, spec_for(path, tree))

    return rec


def _prod(mesh, axes):
    if axes is None:
        return 1
    axes = (axes,) if isinstance(axes, str) else axes
    shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in axes:
        n *= shape[a]
    return n


def _cache_shardings(cfg, shape, mesh, recipe):
    """Shardings for the decode cache pytree by family."""
    rules = shd.ACTIVATION_RULES[recipe]
    dp = _dp_axes(mesh)

    def resolve(logical, dim):
        axes = tuple(a for a in rules.get(logical, ()) if a in mesh.axis_names)
        if not axes or dim % _prod(mesh, axes) != 0:
            return None
        return axes if len(axes) > 1 else axes[0]

    def leaf_spec(key, leaf):
        nd = len(leaf.shape)
        if key in ("k_scale", "v_scale"):   # (L, B, T, KH)
            return P(None, resolve("batch", leaf.shape[1]),
                     resolve("cache_seq", leaf.shape[2]), None)
        if key in ("k", "v", "cross_k", "cross_v"):
            # (L, B, T, KH, Dh)
            return P(None, resolve("batch", leaf.shape[1]),
                     resolve("cache_seq", leaf.shape[2]), None, None)
        if key == "wkv":      # (L, B, H, N, N)
            return P(None, resolve("batch", leaf.shape[1]),
                     resolve("heads", leaf.shape[2]), None, None)
        if key in ("x_tm", "x_cm"):   # (L, B, D)
            return P(None, resolve("batch", leaf.shape[1]),
                     resolve("ffn", leaf.shape[2]))
        if key == "ssm":      # (n_sup, ae, B, H, P, N)
            return P(None, None, resolve("batch", leaf.shape[2]),
                     resolve("heads", leaf.shape[3]), None, None)
        if key == "conv":     # (n_sup, ae, B, W-1, conv_dim)
            return P(None, None, resolve("batch", leaf.shape[2]), None,
                     resolve("ffn", leaf.shape[4]))
        return P(*([None] * nd))

    return {k: NamedSharding(mesh, leaf_spec(k, v))
            for k, v in cache_specs_of(cfg, shape).items()}


def cache_specs_of(cfg, shape):
    return api.cache_specs(cfg, shape.global_batch, shape.seq_len)


def build_cell(arch_id: str, shape_name: str, mesh, variant: str = ""):
    """Returns (fn, arg_specs, recipe).

    Variants (EXPERIMENTS.md §Perf):
      ``int8``      serve with pre-quantized int8 dense weights (W8A8,
                    BitParticle-exact numerics) — memory + compute terms.
      ``q8gather``  train with int8-quantized FSDP weight gathers (STE) —
                    collective term.
    """
    cfg = get_arch(arch_id)
    if variant == "q8gather":
        cfg = cfg.replace(matmul_mode=cfg.matmul_mode + "+q8gather")
    if variant == "int8kv":
        cfg = cfg.replace(kv_cache_int8=True)
    shape = SHAPES[shape_name]
    specs = api.input_specs(cfg, shape)
    key = jax.random.PRNGKey(0)
    param_specs = jax.eval_shape(partial(api.init, cfg=cfg), key)
    if variant in ("int8", "int8kv") and shape.kind != "train":
        from repro.models.layers import quantize_dense_params
        param_specs = quantize_dense_params(param_specs)
        cfg = cfg.replace(matmul_mode="bp_exact")

    if shape.kind == "train":
        recipe = "train"
        opt_specs = jax.eval_shape(opt_lib.init_state, param_specs)
        p_sh = shd.named_shardings(param_specs, "train", mesh)
        o_sh = shd.named_shardings(opt_specs, "train", mesh)
        b_sh = jax.tree.map(lambda *_: None, specs)   # placeholder
        b_sh = _batch_shardings(cfg, shape, mesh, recipe)(specs)
        opt_cfg = opt_lib.OptimizerConfig()

        def train_step(params, opt_state, batch):
            with shd.recipe("train"):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: api.loss_fn(p, cfg, batch), has_aux=True)(params)
                params, opt_state, om = opt_lib.apply_updates(
                    opt_cfg, params, opt_state, grads)
                return params, opt_state, {"loss": loss, **om}

        args = (param_specs, opt_specs, specs)
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh,
                  {"loss": NamedSharding(mesh, P()),
                   "lr": NamedSharding(mesh, P()),
                   "grad_norm": NamedSharding(mesh, P())})
        fn = jax.jit(train_step, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
        return fn, args, recipe

    if shape.kind == "prefill":
        recipe = "train"  # prefill shares the sequence-parallel recipe
        p_sh = shd.named_shardings(param_specs, "serve", mesh)
        b_sh = _batch_shardings(cfg, shape, mesh, recipe)(specs)
        cache_sh = _cache_shardings(cfg, shape, mesh, "decode")

        def prefill_step(params, batch):
            with shd.recipe("train"):
                return api.prefill(params, cfg, batch, shape.seq_len)

        args = (param_specs, specs)
        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        return fn, args, recipe

    # decode
    recipe = "decode_long" if shape.global_batch == 1 else "decode"
    p_sh = shd.named_shardings(param_specs, "serve", mesh)
    b_sh = dict(_batch_shardings(cfg, shape, mesh, recipe)(
        {"tokens": specs["tokens"], "cache_len": specs["cache_len"]}))
    b_sh["cache"] = _cache_shardings(cfg, shape, mesh, recipe)

    def serve_step(params, batch):
        with shd.recipe(recipe):
            return api.decode_step(params, cfg, batch)

    args = (param_specs, specs)
    # donate the batch (i.e. the KV/state cache): the updated cache aliases
    # the input buffers instead of materializing a second full cache
    fn = jax.jit(serve_step, in_shardings=(p_sh, b_sh),
                 donate_argnums=(1,))
    return fn, args, recipe


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: str,
             verbose: bool = True, variant: str = ""):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    arch_tag = f"{arch_id}@{variant}" if variant else arch_id
    tag = f"{arch_tag}__{shape_name}__{mesh_name}"
    path = os.path.join(out_dir, tag + ".json")
    os.makedirs(out_dir, exist_ok=True)
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    record = {"arch": arch_tag, "shape": shape_name, "mesh": mesh_name,
              "base_arch": arch_id, "variant": variant, "ok": False}
    t0 = time.time()
    try:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
        from repro.distributed.sharding import activate_mesh
        with activate_mesh(mesh):
            fn, args, recipe = build_cell(arch_id, shape_name, mesh, variant)
            lowered = fn.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = hlo_analysis.analyze(compiled.as_text())
        record.update({
            "ok": True,
            "recipe": recipe,
            "lower_s": round(t_lower - t0, 2),
            "compile_s": round(t_compile - t_lower, 2),
            "n_devices": 512 if multi_pod else 256,
            # cost_analysis counts while bodies once — kept for reference;
            # the roofline uses the trip-count-aware HLO-derived numbers
            "xla_cost_flops_per_device": cost.get("flops", -1.0),
            "xla_cost_bytes_per_device": cost.get("bytes accessed", -1.0),
            "dot_flops_per_device": hlo["dot_flops_per_device"],
            "dot_flops_int_per_device": hlo["dot_flops_int_per_device"],
            "while_loops": hlo["while_loops"],
            "memory_analysis": {
                "argument_size": getattr(mem, "argument_size_in_bytes", None),
                "output_size": getattr(mem, "output_size_in_bytes", None),
                "temp_size": getattr(mem, "temp_size_in_bytes", None),
                "generated_code_size": getattr(
                    mem, "generated_code_size_in_bytes", None),
                "peak_memory": getattr(mem, "peak_memory_in_bytes", None),
            },
            "collective_bytes": hlo["collective_bytes"],
            "collective_counts": hlo["collective_counts"],
            "top_collectives": hlo["top_collectives"],
            "model_flops_global": api.model_flops(cfg, shape),
        })
    except Exception as e:  # noqa: BLE001 — record failures as artifacts
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    record["total_s"] = round(time.time() - t0, 2)
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    if verbose:
        status = "OK " if record["ok"] else "FAIL"
        print(f"[{status}] {tag}  ({record['total_s']}s)", flush=True)
        if record["ok"]:
            ma = record["memory_analysis"]
            peak = (ma.get("peak_memory") or 0) / 2**30
            print(f"       dot_flops/dev={record['dot_flops_per_device']:.3e}  "
                  f"peak_mem/dev={peak:.2f}GiB  "
                  f"coll_bytes={sum(record['collective_bytes'].values()):.3e}",
                  flush=True)
        else:
            print("       " + record["error"].splitlines()[0], flush=True)
    return record


def all_cells():
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for sname in SHAPES:
            if shape_applicable(arch, SHAPES[sname]):
                yield aid, sname


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--out", default=os.path.abspath(DEFAULT_OUT))
    args = ap.parse_args()

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]
    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    n_fail = 0
    for aid, sname in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            path = os.path.join(args.out, f"{aid}__{sname}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("ok"):
                        print(f"[SKIP] {aid}__{sname}__{mesh_name}", flush=True)
                        continue
            rec = run_cell(aid, sname, mp, args.out, variant=args.variant)
            n_fail += 0 if rec["ok"] else 1
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
