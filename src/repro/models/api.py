"""Unified model API: family dispatch + dry-run input specs.

Every architecture exposes:
    init(key, cfg) -> params
    loss_fn(params, cfg, batch) -> (loss, metrics)          [train_step]
    prefill(params, cfg, batch, cache_T) -> (logits, cache) [prefill_step]
    decode_step(params, cfg, batch) -> (logits, cache)      [serve_step]

``input_specs(cfg, shape)`` returns jax.ShapeDtypeStruct pytrees for every
model input of that workload shape — the dry-run lowers against these, so no
host allocation ever happens for the full-size configs.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import causal_lm, encdec, rwkv_model, zamba_model
from repro.models.layers import DTYPE

_FAMILY_MODULES = {
    "dense": causal_lm,
    "moe": causal_lm,
    "vlm": causal_lm,
    "ssm": rwkv_model,
    "hybrid": zamba_model,
    "audio": encdec,
}


def module_for(cfg: ArchConfig):
    return _FAMILY_MODULES[cfg.family]


def init(key, cfg: ArchConfig):
    return module_for(cfg).init(key, cfg)


def loss_fn(params, cfg: ArchConfig, batch):
    return module_for(cfg).loss_fn(params, cfg, batch)


def prefill(params, cfg: ArchConfig, batch, cache_T: int, prompt_lens=None):
    """``prompt_lens`` (B,) enables ragged right-padded prompt batches for
    families whose prefill is position-independent of right padding
    (attention KV families); recurrent families (ssm/hybrid) integrate every
    token into their state and do not support it."""
    if prompt_lens is None:
        return module_for(cfg).prefill(params, cfg, batch, cache_T)
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError(
            f"family {cfg.family!r} has recurrent state: right-padded "
            f"ragged prefill would corrupt it (use exact-length groups)")
    return module_for(cfg).prefill(params, cfg, batch, cache_T,
                                   prompt_lens=prompt_lens)


def decode_step(params, cfg: ArchConfig, batch):
    return module_for(cfg).decode_step(params, cfg, batch)


def decode_step_paged(params, cfg: ArchConfig, batch):
    """Block-paged decode (``batch`` carries ``block_tables`` + per-slot
    ``cache_len``); position-indexed KV families only."""
    mod = module_for(cfg)
    if not hasattr(mod, "decode_step_paged"):
        raise ValueError(
            f"family {cfg.family!r} has no paged decode path; "
            f"use the slab cache backend")
    return mod.decode_step_paged(params, cfg, batch)


def supports_verify(cfg: ArchConfig) -> bool:
    """Can this family run the speculative multi-token verify step?
    (Position-indexed KV that can be rewound on rejection — dense/moe/vlm;
    recurrent state integrates every token irreversibly.)"""
    return hasattr(module_for(cfg), "verify_step")


def _verify_module(cfg: ArchConfig, name: str):
    mod = module_for(cfg)
    if not hasattr(mod, name):
        raise ValueError(
            f"family {cfg.family!r} has no multi-token verify path: "
            f"speculative decoding needs position-indexed KV that can be "
            f"rewound on rejection (recurrent state integrates every token "
            f"irreversibly); serve with draft='none'")
    return getattr(mod, name)


def verify_step(params, cfg: ArchConfig, batch):
    """Speculative multi-token verify (slab cache): append the (B, S)
    tokens of ``batch`` at per-slot ``cache_len`` in one forward pass and
    return per-position logits (B, S, V) for greedy accept/reject.
    Position-indexed KV families only (dense/moe/vlm)."""
    return _verify_module(cfg, "verify_step")(params, cfg, batch)


def verify_step_paged(params, cfg: ArchConfig, batch):
    """Block-paged speculative verify (``batch`` carries ``block_tables``);
    see :func:`verify_step`."""
    return _verify_module(cfg, "verify_step_paged")(params, cfg, batch)


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins, shardable, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(d) for d in shape), dtype)


def _tokens_spec(B, S):
    return _sds((B, S), jnp.int32)


def _vlm_extras(cfg, B, S):
    return {
        "vision_embeds": _sds((B, S, cfg.d_model), DTYPE),
        "vision_mask": _sds((B, S), jnp.bool_),
        "positions": _sds((3, B, S), jnp.int32),
    }


def cache_specs(cfg: ArchConfig, B: int, cache_T: int):
    """ShapeDtypeStruct pytree of the decode cache for this family."""
    hd = cfg.resolved_head_dim
    if cfg.family in ("dense", "moe", "vlm"):
        kv = (cfg.num_layers, B, cache_T, cfg.num_kv_heads, hd)
        if cfg.kv_cache_int8:
            sc = (cfg.num_layers, B, cache_T, cfg.num_kv_heads)
            return {"k": _sds(kv, jnp.int8), "k_scale": _sds(sc, jnp.float32),
                    "v": _sds(kv, jnp.int8), "v_scale": _sds(sc, jnp.float32)}
        return {"k": _sds(kv, DTYPE), "v": _sds(kv, DTYPE)}
    if cfg.family == "ssm":
        d = cfg.d_model
        n = cfg.rwkv_head_dim
        h = d // n
        L = cfg.num_layers
        return {"wkv": _sds((L, B, h, n, n), jnp.float32),
                "x_tm": _sds((L, B, d), DTYPE),
                "x_cm": _sds((L, B, d), DTYPE)}
    if cfg.family == "hybrid":
        from repro.models import mamba2
        n_sup = cfg.num_layers // cfg.attn_every
        di = mamba2.d_inner(cfg)
        conv_dim = di + 2 * cfg.ssm_state
        h = mamba2.n_ssm_heads(cfg)
        return {
            "conv": _sds((n_sup, cfg.attn_every, B, cfg.ssm_conv_width - 1,
                          conv_dim), DTYPE),
            "ssm": _sds((n_sup, cfg.attn_every, B, h, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32),
            "k": _sds((n_sup, B, cache_T, cfg.num_kv_heads, hd), DTYPE),
            "v": _sds((n_sup, B, cache_T, cfg.num_kv_heads, hd), DTYPE),
        }
    if cfg.family == "audio":
        L = cfg.num_layers
        src_T = max(cache_T // 4, 128)
        kv = (L, B, cache_T, cfg.num_kv_heads, hd)
        ckv = (L, B, src_T, cfg.num_kv_heads, hd)
        return {"k": _sds(kv, DTYPE), "v": _sds(kv, DTYPE),
                "cross_k": _sds(ckv, DTYPE), "cross_v": _sds(ckv, DTYPE)}
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Sharding specs (mesh-parallel serving): logical axes -> PartitionSpec
# ---------------------------------------------------------------------------

def cache_logical_axes(cfg: ArchConfig):
    """Pytree (same structure as ``cache_specs``) of logical-axis name
    tuples for every decode-cache leaf, resolvable against the
    ``distributed.sharding`` recipes.  The serving executor turns these into
    ``PartitionSpec``s (``cache_pspecs``) for device placement, and the
    decode step re-applies them as sharding constraints so the pooled cache
    keeps one resident layout across steps."""
    kv = (None, "batch", "cache_seq", "heads", None)
    sc = (None, "batch", "cache_seq", "heads")
    if cfg.family in ("dense", "moe", "vlm"):
        if cfg.kv_cache_int8:
            return {"k": kv, "k_scale": sc, "v": kv, "v_scale": sc}
        return {"k": kv, "v": kv}
    if cfg.family == "ssm":
        return {"wkv": (None, "batch", "heads", None, None),
                "x_tm": (None, "batch", None),
                "x_cm": (None, "batch", None)}
    if cfg.family == "hybrid":
        return {"conv": (None, None, "batch", None, None),
                "ssm": (None, None, "batch", "heads", None, None),
                "k": kv, "v": kv}
    if cfg.family == "audio":
        return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv}
    raise ValueError(cfg.family)


def paged_cache_logical_axes(cfg: ArchConfig):
    """Logical axes of the block-paged cache: every leaf fully replicated.
    The page pool has no batch/sequence axis to lay on a mesh — physical
    pages are gathered through block tables, which stay replicated too."""
    specs = paged_cache_specs(cfg, 2, 1)
    return jax.tree.map(lambda s: (None,) * len(s.shape), specs)


def cache_pspec_tree(cfg: ArchConfig, cache_like, mesh_axes,
                     recipe_name: str = "decode", *, paged: bool = False):
    """PartitionSpec pytree matching ``cache_like`` (concrete arrays or
    ShapeDtypeStructs), resolved from the logical-axis rules.  This is THE
    resolution — the mesh executor places caches with it and
    ``cache_pspecs``/``paged_cache_pspecs`` are shape-spec facades over
    it, so placement and the spec helpers cannot drift apart."""
    from repro.distributed import sharding as shd
    if not isinstance(mesh_axes, dict):
        mesh_axes = shd.mesh_axes_dict(mesh_axes)
    axes = (paged_cache_logical_axes(cfg) if paged
            else cache_logical_axes(cfg))
    return jax.tree.map(
        lambda l, la: shd.logical_pspec(l.shape, la, recipe_name, mesh_axes),
        cache_like, axes)


def cache_pspecs(cfg: ArchConfig, n_slots: int, cache_T: int, mesh_axes,
                 recipe_name: str = "decode"):
    """PartitionSpec pytree for the pooled decode cache of this family,
    resolved from the logical-axis rules (``decode``: slot/batch axis over
    "data", KV sequence axis over "model"; non-divisible dims stay
    replicated).  ``mesh_axes``: {axis name: size} or a concrete Mesh."""
    return cache_pspec_tree(cfg, cache_specs(cfg, n_slots, cache_T),
                            mesh_axes, recipe_name)


def paged_cache_pspecs(cfg: ArchConfig, num_blocks: int, block_size: int,
                       mesh_axes=None, recipe_name: str = "decode"):
    """PartitionSpec pytree for the block-paged cache: fully replicated
    (see ``paged_cache_logical_axes``)."""
    return cache_pspec_tree(cfg,
                            paged_cache_specs(cfg, num_blocks, block_size),
                            mesh_axes or {}, recipe_name, paged=True)


def param_pspecs(params, mesh_axes, recipe_name: str = "decode"):
    """PartitionSpec pytree for the model params under a serving recipe —
    weight-stationary TP: last dims over "model" (``decode``/``serve``), 2D
    FSDP x TP under ``train``.  Thin facade over
    ``distributed.sharding.param_specs`` so serving code only needs the
    model API surface."""
    from repro.distributed import sharding as shd
    return shd.param_specs(params, recipe_name, mesh_axes)


def shard_cache(cfg: ArchConfig, cache, *, paged: bool = False):
    """Re-apply the decode-cache sharding constraints to ``cache`` inside a
    trace (no-op without an active mesh/recipe).  The executor calls this on
    the cache a jitted step returns, pinning the output layout to the input
    layout so donated cache buffers alias instead of resharding."""
    from repro.distributed.sharding import shard
    axes = (paged_cache_logical_axes(cfg) if paged
            else cache_logical_axes(cfg))
    return jax.tree.map(lambda leaf, la: shard(leaf, *la), cache, axes)


# ---------------------------------------------------------------------------
# Block-paged decode caches (paged cache backend)
# ---------------------------------------------------------------------------

def paged_cache_specs(cfg: ArchConfig, num_blocks: int, block_size: int):
    """ShapeDtypeStruct pytree of the block-paged decode cache: every KV
    leaf becomes (L, num_blocks, block_size, heads...).  Position-indexed
    KV families only — recurrent state has no sequence axis to page."""
    if cfg.family not in ("dense", "moe", "vlm"):
        raise ValueError(f"no paged cache layout for family {cfg.family!r}")
    hd = cfg.resolved_head_dim
    kv = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads, hd)
    if cfg.kv_cache_int8:
        sc = (cfg.num_layers, num_blocks, block_size, cfg.num_kv_heads)
        return {"k": _sds(kv, jnp.int8), "k_scale": _sds(sc, jnp.float32),
                "v": _sds(kv, jnp.int8), "v_scale": _sds(sc, jnp.float32)}
    return {"k": _sds(kv, DTYPE), "v": _sds(kv, DTYPE)}


def zeros_paged_cache(cfg: ArchConfig, num_blocks: int, block_size: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        paged_cache_specs(cfg, num_blocks, block_size))


def paged_insert(cfg: ArchConfig, pages, src_cache, block_ids, src_index=0):
    """Scatter request ``src_index`` of a prefill cache (padded to
    ``len(block_ids) * block_size`` positions) into physical pages.

    ``block_ids``: (P,) int32 — logical block i of the sequence lands in
    physical page ``block_ids[i]``.  Blocks that must NOT be written
    (prefix-sharing hits) are redirected to the trash page (id 0) by the
    caller; ``block_ids``/``src_index`` may be traced (one jit covers every
    admission of a given prefill batch shape)."""
    block_ids = jnp.asarray(block_ids, jnp.int32)

    def put(page, src):
        # src (L, B, T, ...) -> row (L, T, ...) -> (L, P, bs, ...)
        row = jax.lax.dynamic_index_in_dim(src, src_index, axis=1,
                                           keepdims=False)
        L, T = row.shape[0], row.shape[1]
        P = block_ids.shape[0]
        blocked = row.reshape(L, P, T // P, *row.shape[2:])
        return page.at[:, block_ids].set(blocked.astype(page.dtype))

    return jax.tree.map(put, pages, src_cache)


# ---------------------------------------------------------------------------
# Slot-granular cache surgery (continuous-batching serving)
# ---------------------------------------------------------------------------

def cache_batch_axes(cfg: ArchConfig):
    """Pytree (same structure as ``cache_specs``) giving the slot/batch axis
    of every decode-cache leaf.  The hybrid family stacks mamba states as
    (n_super, attn_every, B, ...) so its batch axis differs per leaf."""
    if cfg.family == "hybrid":
        return {"conv": 2, "ssm": 2, "k": 1, "v": 1}
    return jax.tree.map(lambda _: 1, cache_specs(cfg, 1, 8))


def zeros_cache(cfg: ArchConfig, n_slots: int, cache_T: int):
    """Concrete all-zeros decode cache for an ``n_slots``-wide slot pool."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_specs(cfg, n_slots, cache_T))


def slot_insert(cfg: ArchConfig, pool_cache, src_cache, slot, src_index=0):
    """Write request ``src_index`` of ``src_cache`` (a prefill cache of batch
    size >= 1, padded to the pool's cache_T) into slot ``slot`` of the pooled
    cache.  ``slot``/``src_index`` may be traced (one jit covers all slots)."""
    axes = cache_batch_axes(cfg)

    def put(pool, src, ax):
        row = jax.lax.dynamic_index_in_dim(src, src_index, axis=ax,
                                           keepdims=False)
        return jax.lax.dynamic_update_index_in_dim(
            pool, row.astype(pool.dtype), slot, axis=ax)

    return jax.tree.map(put, pool_cache, src_cache, axes)


def slot_extract(cfg: ArchConfig, pool_cache, slot):
    """Pull slot ``slot`` out of the pooled cache as a batch-1 cache."""
    axes = cache_batch_axes(cfg)
    return jax.tree.map(
        lambda pool, ax: jax.lax.dynamic_slice_in_dim(pool, slot, 1, axis=ax),
        pool_cache, axes)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model-input ShapeDtypeStructs for one (arch x workload-shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {"tokens": _tokens_spec(B, S)}
        if cfg.family == "vlm":
            batch.update(_vlm_extras(cfg, B, S))
        if cfg.family == "audio":
            batch["src_embeds"] = _sds((B, S // 4, cfg.d_model), DTYPE)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": _tokens_spec(B, S)}
        if cfg.family == "vlm":
            batch.update(_vlm_extras(cfg, B, S))
        if cfg.family == "audio":
            batch["src_embeds"] = _sds((B, S // 4, cfg.d_model), DTYPE)
        return batch
    if shape.kind == "decode":
        batch = {"tokens": _tokens_spec(B, 1),
                 "cache": cache_specs(cfg, B, S),
                 "cache_len": _sds((), jnp.int32)}
        if cfg.family == "vlm":
            pass  # decode positions derive from cache_len (text continuation)
        return batch
    raise ValueError(shape.kind)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = params, active for MoE),
    2*N*D for single forward; decode counts one token + attention reads."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention over the cache
    tokens = shape.global_batch
    attn_read = 0.0
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        hd = cfg.resolved_head_dim
        attn_read = (4.0 * cfg.num_layers * cfg.num_heads * hd
                     * shape.seq_len * tokens)
    if cfg.family == "hybrid":
        hd = cfg.resolved_head_dim
        n_sup = cfg.num_layers // cfg.attn_every
        attn_read = 4.0 * n_sup * cfg.num_heads * hd * shape.seq_len * tokens
    return 2.0 * n_active * tokens + attn_read
