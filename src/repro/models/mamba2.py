"""Mamba-2 (SSD) blocks for the Zamba2 hybrid backbone.  [arXiv:2405.21060]

State-space duality form with scalar-per-head decay:

    h_t = a_t h_{t-1} + dt_t (B_t (x) x_t)        h: (heads, P, N)
    y_t = C_t . h_t + D x_t                        a_t = exp(-dt_t * A_head)

``ssd_chunked`` is the matmul-parallel chunked evaluation (train/prefill);
``ssd_step`` the O(1) recurrence (decode + oracle).  Short causal conv on
(x, B, C) as in the reference implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers


def d_inner(cfg):
    return 2 * cfg.d_model


def n_ssm_heads(cfg):
    return d_inner(cfg) // cfg.ssm_head_dim


def init_mamba2(key, cfg):
    d = cfg.d_model
    di = d_inner(cfg)
    n = cfg.ssm_state
    h = n_ssm_heads(cfg)
    ks = jax.random.split(key, 6)
    conv_dim = di + 2 * n
    return {
        # projects to [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": layers.init_dense(ks[0], d, 2 * di + 2 * n + h),
        "conv_w": layers.truncated_normal(ks[1], (cfg.ssm_conv_width, conv_dim),
                                          conv_dim ** -0.5, jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),       # A = exp(A_log) in (0, inf)
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "norm": layers.init_rmsnorm(di),
        "out_proj": layers.init_dense(ks[2], di, d),
    }


def _split(p, zxbcdt, cfg):
    di, n, h = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg)
    z, x, B, C, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + n, 2 * di + 2 * n],
                               axis=-1)
    return z, x, B, C, dt


def _causal_conv(x, w, b, state=None):
    """x (B,S,C); w (W,C) depthwise causal conv.  ``state``: (B,W-1,C) carry
    for streaming decode.  Returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
            for i in range(W))
    y = y + b.astype(x.dtype)
    new_state = xp[:, -(W - 1):, :]
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def ssd_step(xh, Bt, Ct, dt, A, state):
    """xh (B,H,P); Bt/Ct (B,N); dt (B,H); state (B,H,P,N)."""
    a = jnp.exp(-dt * A)                                     # (B,H)
    upd = (dt[..., None] * xh)[..., :, None] * Bt[:, None, None, :]
    state = a[..., None, None] * state + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Ct)
    return y, state


def ssd_sequential(xh, Bseq, Cseq, dt, A, state):
    """Step scan.  xh (B,S,H,P); Bseq/Cseq (B,S,N); dt (B,S,H)."""
    def body(s, inp):
        xt, bt, ct, dtt = inp
        y, s = ssd_step(xt, bt, ct, dtt, A, s)
        return s, y
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (xh, Bseq, Cseq, dt))
    state, ys = jax.lax.scan(body, state, xs)
    return jnp.moveaxis(ys, 0, 1), state


def ssd_chunked(xh, Bseq, Cseq, dt, A, state, chunk: int = 64):
    """Chunked-parallel SSD, equal to ``ssd_sequential``.

    Scalar-per-head log-decay lc makes the pairwise factor a (L, L) matrix
    per head (no per-channel blowup): y_intra = (M ⊙ (C B^T)) (dt*x)."""
    B, S, H, P = xh.shape
    N = Bseq.shape[-1]
    assert S % chunk == 0
    L, nc = chunk, S // chunk
    xs = (xh.astype(jnp.float32).reshape(B, nc, L, H, P),
          Bseq.astype(jnp.float32).reshape(B, nc, L, N),
          Cseq.astype(jnp.float32).reshape(B, nc, L, N),
          dt.reshape(B, nc, L, H))
    tri = jnp.tril(jnp.ones((L, L), bool))                  # j <= i

    def body(s, inp):
        xc, bc, cc, dtc = inp                               # (B,L,...)
        la = -dtc * A                                       # (B,L,H) log a_t
        lc = jnp.cumsum(la, axis=1)                         # lc_i = sum_{s<=i}
        # cross-chunk: y_i += exp(lc_i) C_i . S_prev
        y = jnp.einsum("bln,bhpn,blh->blhp", cc, s, jnp.exp(lc))
        # intra-chunk: decay from j to i is exp(lc_i - lc_j) for j <= i
        pair = jnp.exp(lc[:, :, None] - lc[:, None, :])     # (B,L,L,H)
        pair = jnp.where(tri[None, :, :, None], pair, 0.0)
        score = jnp.einsum("bln,bmn->blm", cc, bc)          # (B,L,L)
        xdt = xc * dtc[..., None]                           # (B,L,H,P)
        y = y + jnp.einsum("blm,blmh,bmhp->blhp", score, pair, xdt)
        # state update
        lc_end = lc[:, -1]                                  # (B,H)
        bdec = jnp.exp(lc_end[:, None] - lc)                # (B,L,H)
        s = (jnp.exp(lc_end)[..., None, None] * s
             + jnp.einsum("blh,bln,blhp->bhpn", bdec, bc, xdt))
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in xs)
    state, ys = jax.lax.scan(body, state, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P), state


def mamba2_block(p, x, cfg, mode, *, conv_state=None, ssm_state=None,
                 chunk: int = 64, single_step: bool = False):
    """Full Mamba-2 mixer.  Returns (y, conv_state, ssm_state)."""
    Bsz, S, _ = x.shape
    di, n, h = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg)
    P = cfg.ssm_head_dim
    z, xi, Bf, Cf, dt = _split(p, layers.dense(p["in_proj"], x, mode), cfg)
    conv_in = jnp.concatenate([xi, Bf, Cf], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                        conv_state)
    xi, Bf, Cf = jnp.split(conv_out, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = jnp.exp(p["A_log"])
    xh = xi.reshape(Bsz, S, h, P)
    if ssm_state is None:
        ssm_state = jnp.zeros((Bsz, h, P, n), jnp.float32)
    if single_step:
        y, ssm_state = ssd_step(xh[:, 0].astype(jnp.float32),
                                Bf[:, 0].astype(jnp.float32),
                                Cf[:, 0].astype(jnp.float32),
                                dt[:, 0], A, ssm_state)
        y = y[:, None]
    elif S % chunk == 0 and S > 1:
        y, ssm_state = ssd_chunked(xh, Bf, Cf, dt, A, ssm_state, chunk)
    else:
        y, ssm_state = ssd_sequential(xh.astype(jnp.float32),
                                      Bf.astype(jnp.float32),
                                      Cf.astype(jnp.float32), dt, A, ssm_state)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, S, di).astype(x.dtype)
    y = layers.rms_norm(p["norm"], y) * jax.nn.silu(
        z.astype(jnp.float32)).astype(x.dtype)
    return layers.dense(p["out_proj"], y, mode), conv_state, ssm_state
