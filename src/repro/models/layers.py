"""Shared model layers: norms, dense (BitParticle-backed), embeddings, RoPE.

All dense contractions route through ``repro.core.bp_matmul.dense_apply`` so
the BitParticle numerics mode (bf16 / qat / bp_exact / bp_approx) is a
per-config switch for every architecture (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.bp_matmul import dense_apply

DTYPE = jnp.bfloat16


def truncated_normal(key, shape, stddev, dtype=DTYPE):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


# --- norms -----------------------------------------------------------------

def init_rmsnorm(d):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rms_norm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    return out.astype(x.dtype)


def init_layernorm(d):
    return {"scale": jnp.ones((d,), jnp.float32),
            "bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return out.astype(x.dtype)


# --- dense -----------------------------------------------------------------

def init_dense(key, d_in, d_out, bias=False, stddev=None):
    stddev = stddev if stddev is not None else d_in ** -0.5
    p = {"w": truncated_normal(key, (d_in, d_out), stddev)}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
    return p


def dense(params, x, mode="bf16"):
    w = params["w"]
    if w.dtype == jnp.int8:
        # pre-quantized serving weights (int8 in HBM — the paper's W8 storage)
        from repro.core import probe
        from repro.core.bp_matmul import quantized_matmul
        int_mode = mode if mode in ("bp_exact", "bp_approx") else "bp_exact"
        probe.record_activation(x)
        y = quantized_matmul(x, w, params["w_scale"], int_mode)
    else:
        y = dense_apply(x, w.astype(x.dtype), mode)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def quantize_dense_params(params):
    """Convert every dense kernel ("w", ndim>=2, float) to int8 + per-channel
    scale for weight-resident serving.  Embedding tables (gather-consumed)
    and 1D params are untouched."""
    import jax

    def rec(node):
        if isinstance(node, dict):
            node = {k: rec(v) for k, v in node.items()}
            w = node.get("w")
            if (w is not None and hasattr(w, "ndim") and w.ndim >= 2
                    and jnp.issubdtype(w.dtype, jnp.floating)):
                # per-output-channel scales; leading dims (scan-stacked
                # layers) keep their own scales: (..., K, N) -> (..., N)
                scale_shape = w.shape[:-2] + (w.shape[-1],)
                if isinstance(w, jax.ShapeDtypeStruct):
                    node["w"] = jax.ShapeDtypeStruct(w.shape, jnp.int8)
                    node["w_scale"] = jax.ShapeDtypeStruct(scale_shape,
                                                           jnp.float32)
                else:
                    from repro.core import quant
                    scale = quant.compute_scale(w.astype(jnp.float32),
                                                axis=(w.ndim - 2,))
                    node["w"] = quant.quantize(w.astype(jnp.float32), scale)
                    node["w_scale"] = scale.reshape(scale_shape)
            return node
        return node

    return rec(params)


# --- embeddings ------------------------------------------------------------

def init_embedding(key, vocab, d):
    return {"table": truncated_normal(key, (vocab, d), d ** -0.5)}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x):
    """Logits against the (possibly tied) embedding table."""
    return jnp.einsum("...d,vd->...v", x, params["table"].astype(x.dtype))


# --- rotary position embeddings ---------------------------------------------

def rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) int -> cos/sin (..., S, head_dim//2) f32."""
    half = head_dim // 2
    inv = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions3, head_dim: int, theta: float,
                 sections: Tuple[int, ...]):
    """Qwen2-VL M-RoPE: positions3 (3, B, S); per-frequency-band section ids
    pick which of the (t, h, w) position rows drives that band."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    cos, sin = rope_angles(positions3, head_dim, theta)  # (3, B, S, half)
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=half)
    onehot = jax.nn.one_hot(sec_id, len(sections), dtype=jnp.float32)  # (half, 3)
    cos = jnp.einsum("nbsh,hn->bsh", cos, onehot)
    sin = jnp.einsum("nbsh,hn->bsh", sin, onehot)
    return cos, sin


def apply_rope(x, cos, sin):
    """x (B, S, H, D); cos/sin (B, S, D/2) — rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


# --- feed-forward ----------------------------------------------------------

def init_ffn(key, d, d_ff, ffn_type: str):
    k1, k2, k3 = jax.random.split(key, 3)
    if ffn_type == "swiglu":
        return {"w_gate": init_dense(k1, d, d_ff),
                "w_up": init_dense(k2, d, d_ff),
                "w_down": init_dense(k3, d_ff, d)}
    return {"w_up": init_dense(k1, d, d_ff),
            "w_down": init_dense(k2, d_ff, d)}


def ffn(params, x, ffn_type: str, mode="bf16"):
    if ffn_type == "swiglu":
        g = dense(params["w_gate"], x, mode)
        u = dense(params["w_up"], x, mode)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        u = dense(params["w_up"], x, mode)
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    return dense(params["w_down"], h, mode)
