"""Encoder-decoder (seamless-m4t-medium): bidirectional encoder over
precomputed modality-frontend embeddings (STUB per assignment) + causal
decoder with cross-attention.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention, layers


def _init_enc_layer(key, cfg):
    ka, kf = jax.random.split(key)
    return {
        "attn_norm": layers.init_rmsnorm(cfg.d_model),
        "attn": attention.init_attention(ka, cfg),
        "ffn_norm": layers.init_rmsnorm(cfg.d_model),
        "ffn": layers.init_ffn(kf, cfg.d_model, cfg.d_ff, cfg.ffn_type),
    }


def _init_dec_layer(key, cfg):
    ka, kc, kf = jax.random.split(key, 3)
    return {
        "attn_norm": layers.init_rmsnorm(cfg.d_model),
        "attn": attention.init_attention(ka, cfg),
        "cross_norm": layers.init_rmsnorm(cfg.d_model),
        "cross": attention.init_attention(kc, cfg),
        "ffn_norm": layers.init_rmsnorm(cfg.d_model),
        "ffn": layers.init_ffn(kf, cfg.d_model, cfg.d_ff, cfg.ffn_type),
    }


def init(key, cfg):
    ke, kenc, kdec, kh = jax.random.split(key, 4)
    return {
        "embed": layers.init_embedding(ke, cfg.vocab_padded, cfg.d_model),
        "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg))(
            jax.random.split(kenc, cfg.encoder_layers)),
        "enc_norm": layers.init_rmsnorm(cfg.d_model),
        "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg))(
            jax.random.split(kdec, cfg.num_layers)),
        "final_norm": layers.init_rmsnorm(cfg.d_model),
        "lm_head": layers.init_dense(kh, cfg.d_model, cfg.vocab_padded),
    }


def encode(params, cfg, src_embeds):
    """src_embeds (B, Se, D): precomputed frame embeddings (frontend stub)."""
    mode = cfg.matmul_mode
    B, Se, _ = src_embeds.shape
    x = shard(src_embeds.astype(layers.DTYPE), "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))
    cos, sin = layers.rope_angles(positions, cfg.resolved_head_dim,
                                  cfg.rope_theta)

    def body(x, lp):
        h = layers.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
        out, _ = attention.attention_block(lp["attn"], h, cfg, mode,
                                           cos=cos, sin=sin, causal=False)
        x = x + out
        h = layers.rms_norm(lp["ffn_norm"], x, cfg.norm_eps)
        x = x + layers.ffn(lp["ffn"], h, cfg.ffn_type, mode)
        return shard(x, "batch", "seq", None), None

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layers.rms_norm(params["enc_norm"], x, cfg.norm_eps)


def cross_kv(params, cfg, enc_out):
    """Precompute per-decoder-layer cross K/V: (L, B, Se, KH, Dh)."""
    mode = cfg.matmul_mode
    B, Se, _ = enc_out.shape
    hd = cfg.resolved_head_dim

    def body(_, lp):
        k = layers.dense(lp["cross"]["wk"], enc_out, mode).reshape(
            B, Se, cfg.num_kv_heads, hd)
        v = layers.dense(lp["cross"]["wv"], enc_out, mode).reshape(
            B, Se, cfg.num_kv_heads, hd)
        return (), (k, v)

    _, (ks, vs) = jax.lax.scan(body, (), params["decoder"])
    return ks, vs


def _decode_stack(params, cfg, x, cos, sin, cross_ks, cross_vs, *,
                  return_cache=False, cache_T=0):
    mode = cfg.matmul_mode
    B = x.shape[0]
    hd = cfg.resolved_head_dim

    def body(x, lin):
        lp, ck, cv = lin
        h = layers.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
        out, (k, v) = attention.attention_block(lp["attn"], h, cfg, mode,
                                                cos=cos, sin=sin)
        x = x + out
        h = layers.rms_norm(lp["cross_norm"], x, cfg.norm_eps)
        q = layers.dense(lp["cross"]["wq"], h, mode).reshape(
            B, -1, cfg.num_heads, hd)
        cout = attention.flash_attention(q, ck, cv, causal=False)
        cout = cout.reshape(B, -1, cfg.num_heads * hd)
        x = x + layers.dense(lp["cross"]["wo"], cout, mode)
        h = layers.rms_norm(lp["ffn_norm"], x, cfg.norm_eps)
        x = x + layers.ffn(lp["ffn"], h, cfg.ffn_type, mode)
        x = shard(x, "batch", "seq", None)
        if return_cache:
            if cache_T > k.shape[1]:
                pad = [(0, 0), (0, cache_T - k.shape[1]), (0, 0), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            return x, (k, v)
        return x, None

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    x, ys = jax.lax.scan(body, x, (params["decoder"], cross_ks, cross_vs))
    return layers.rms_norm(params["final_norm"], x, cfg.norm_eps), ys


def loss_fn(params, cfg, batch):
    from repro.models.causal_lm import logits_from_hidden
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = encode(params, cfg, batch["src_embeds"])
    cks, cvs = cross_kv(params, cfg, enc_out)
    x = layers.embed(params["embed"], tokens)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = layers.rope_angles(positions, cfg.resolved_head_dim,
                                  cfg.rope_theta)
    x, _ = _decode_stack(params, cfg, x, cos, sin, cks, cvs)
    x2 = shard(x.reshape(B * S, -1), "tokens_flat", None)
    logits = logits_from_hidden(params, cfg, x2).astype(jnp.float32)
    vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
    logits = jnp.where(vmask[None, :], logits, -1e9)
    targets = jnp.roll(tokens, -1, axis=1).reshape(B * S)
    valid = jnp.ones((B, S), bool).at[:, -1].set(False).reshape(B * S)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=1)[:, 0]
    loss = ((lse - tgt) * valid).sum() / jnp.maximum(valid.sum(), 1)
    return loss, {"ce_loss": loss, "valid_tokens": valid.sum()}


def prefill(params, cfg, batch, cache_T: int, prompt_lens=None):
    """Encode source + run decoder prompt; cache = self KV + cross KV.
    ``prompt_lens`` (B,) supports ragged right-padded decoder prompts
    (causal self-attention keeps valid rows independent of the padding)."""
    from repro.models.causal_lm import logits_from_hidden
    tokens = batch["tokens"]
    B, S = tokens.shape
    enc_out = encode(params, cfg, batch["src_embeds"])
    cks, cvs = cross_kv(params, cfg, enc_out)
    x = layers.embed(params["embed"], tokens)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = layers.rope_angles(positions, cfg.resolved_head_dim,
                                  cfg.rope_theta)
    x, ys = _decode_stack(params, cfg, x, cos, sin, cks, cvs,
                          return_cache=True, cache_T=cache_T)
    ks, vs = ys
    if prompt_lens is None:
        last = x[:, -1:, :]
    else:
        idx = (jnp.asarray(prompt_lens, jnp.int32) - 1)[:, None, None]
        last = jnp.take_along_axis(x, idx, axis=1)
    logits = logits_from_hidden(params, cfg, last)[:, 0]
    return logits, {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs}


def decode_step(params, cfg, batch):
    from repro.models.causal_lm import logits_from_hidden
    mode = cfg.matmul_mode
    tokens, cache = batch["tokens"], batch["cache"]
    cache_len = jnp.asarray(batch["cache_len"])
    B = tokens.shape[0]
    hd = cfg.resolved_head_dim
    x = layers.embed(params["embed"], tokens)
    pos = attention.decode_positions(cache_len, B)
    cos, sin = layers.rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)

    def body(x, lin):
        lp, kc, vc, ck, cv = lin
        h = layers.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
        q, k, v = attention.qkv_proj(lp["attn"], h, cfg, mode)
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
        kc = attention.write_kv(kc, k, cache_len)
        vc = attention.write_kv(vc, v, cache_len)
        kc = shard(kc, "batch", "cache_seq", "heads", None)
        vc = shard(vc, "batch", "cache_seq", "heads", None)
        out = attention.decode_attention(q, kc, vc, cache_len)
        x = x + layers.dense(lp["attn"]["wo"],
                             out.reshape(B, 1, cfg.num_heads * hd), mode)
        h = layers.rms_norm(lp["cross_norm"], x, cfg.norm_eps)
        q = layers.dense(lp["cross"]["wq"], h, mode).reshape(
            B, 1, cfg.num_heads, hd)
        cout = attention.decode_attention(q, ck, cv, ck.shape[1] - 1)
        x = x + layers.dense(lp["cross"]["wo"],
                             cout.reshape(B, 1, cfg.num_heads * hd), mode)
        h = layers.rms_norm(lp["ffn_norm"], x, cfg.norm_eps)
        x = x + layers.ffn(lp["ffn"], h, cfg.ffn_type, mode)
        return x, (kc, vc)

    xs = (params["decoder"], cache["k"], cache["v"],
          cache["cross_k"], cache["cross_v"])
    x, (ks, vs) = jax.lax.scan(body, x, xs)
    x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x)[:, 0]
    return logits, {"k": ks, "v": vs,
                    "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}
