"""RWKV-6 "Finch": attention-free blocks with data-dependent decay.

Per head (k-dim = v-dim = N):                        [arXiv:2404.05892]

    out_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(wx_t))

with per-channel, *data-dependent* decay wx_t (the Finch contribution) and
data-dependent token-shift interpolation (ddlerp with low-rank maa).

Two equivalent evaluation paths:

  * ``wkv_step`` — the O(1)-state recurrence: decode + oracle.
  * ``wkv_chunked`` — chunked-parallel training/prefill form.  Within a
    chunk the pairwise decay factor exp(lc_i - lc_{j+1}) (<= 1, numerically
    safe) is materialized per (i, j, channel) tile; across chunks only the
    (N x N) state is carried.  MACs live in einsums (MXU-friendly), the
    chunk dim is scanned.

BitParticle applicability: the r/k/v/g/o and channel-mix projections are
quantizable dense layers; the state recurrence itself is fp elementwise
mul-add, not an int8 GEMM (DESIGN.md §5 — priced as unquantized).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers

MAA_RANK = 32
DECAY_RANK = 64


def init_time_mix(key, cfg):
    d = cfg.d_model
    n_heads = d // cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    p = {
        "mu": layers.truncated_normal(ks[0], (5, d), 0.02, jnp.float32),
        "maa_w1": layers.truncated_normal(ks[1], (d, 5 * MAA_RANK), 0.02),
        "maa_w2": layers.truncated_normal(ks[2], (5, MAA_RANK, d), 0.02),
        "decay_base": jnp.zeros((d,), jnp.float32) - 1.0,
        "decay_w1": layers.truncated_normal(ks[3], (d, DECAY_RANK), 0.02),
        "decay_w2": layers.truncated_normal(ks[4], (DECAY_RANK, d), 0.02),
        "bonus_u": layers.truncated_normal(ks[5], (n_heads, cfg.rwkv_head_dim),
                                           0.02, jnp.float32),
        "wr": layers.init_dense(ks[6], d, d),
        "wk": layers.init_dense(ks[7], d, d),
        "wv": layers.init_dense(ks[8], d, d),
        "wg": layers.init_dense(ks[9], d, d),
        "wo": layers.init_dense(ks[10], d, d),
        "ln_x": layers.init_layernorm(d),   # per-head group-norm on output
    }
    return p


def init_channel_mix(key, cfg):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": layers.truncated_normal(k1, (d,), 0.02, jnp.float32),
        "wk": layers.init_dense(k2, d, f),
        "wv": layers.init_dense(k3, f, d),
    }


def _token_shift(x, x_prev):
    """shift right by one along seq; position 0 sees x_prev (B, D)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p, x, xx):
    """data-dependent lerp producing the 5 mixed inputs (r, k, v, w, g)."""
    B, S, D = x.shape
    base = x[None] + (xx - x)[None] * p["mu"][:, None, None, :]
    dx = (xx - x)
    low = jnp.tanh(jnp.einsum("bsd,dr->bsr", dx, p["maa_w1"].astype(x.dtype)))
    low = low.reshape(B, S, 5, MAA_RANK)
    delta = jnp.einsum("bsnr,nrd->nbsd", low, p["maa_w2"].astype(x.dtype))
    return base.astype(x.dtype) + ((xx - x)[None] * delta).astype(x.dtype)


def _decay_logits(p, xw):
    """per-channel decay exponent wx (f32): w = exp(-exp(wx)), clipped for
    numerical safety."""
    low = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, p["decay_w1"].astype(xw.dtype)))
    wx = p["decay_base"] + jnp.einsum(
        "bsr,rd->bsd", low.astype(jnp.float32), p["decay_w2"].astype(jnp.float32))
    return jnp.clip(wx, -8.0, 2.0)


def time_mix_inputs(p, x, x_prev, cfg, mode):
    """shared preamble: projections r,k,v,g + per-channel log-decay."""
    B, S, D = x.shape
    N = cfg.rwkv_head_dim
    H = D // N
    xx = _token_shift(x, x_prev)
    mr, mk, mv, mw, mg = _ddlerp(p, x, xx)
    r = layers.dense(p["wr"], mr, mode).reshape(B, S, H, N)
    k = layers.dense(p["wk"], mk, mode).reshape(B, S, H, N)
    v = layers.dense(p["wv"], mv, mode).reshape(B, S, H, N)
    g = layers.dense(p["wg"], mg, mode)
    log_w = -jnp.exp(_decay_logits(p, mw))          # (B,S,D) f32, <= 0
    log_w = log_w.reshape(B, S, H, N)
    return r, k, v, g, log_w, x[:, -1, :]


def _finalize(p, out, g, cfg, mode):
    B, S, H, N = out.shape
    y = layers.layer_norm(p["ln_x"], out.reshape(B, S, H * N))
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(y.dtype)
    return layers.dense(p["wo"], y, mode)


def wkv_step(r, k, v, log_w, u, state):
    """One-token recurrence.  r,k,v (B,H,N); log_w (B,H,N); state (B,H,N,N).
    Returns (out (B,H,N), new_state)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = kf[..., :, None] * vf[..., None, :]             # (B,H,N,N)
    out = jnp.einsum("bhk,bhkv->bhv", rf, state + u[..., :, None] * kv)
    new_state = jnp.exp(log_w)[..., :, None] * state + kv
    return out, new_state


def wkv_sequential(r, k, v, log_w, u, state):
    """Step-scan over the sequence (oracle / decode path).
    r,k,v,log_w (B,S,H,N); state (B,H,N,N)."""
    def body(s, inputs):
        rt, kt, vt, wt = inputs
        out, s = wkv_step(rt, kt, vt, wt, u, s)
        return s, out
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, log_w))
    state, outs = jax.lax.scan(body, state, xs)
    return jnp.moveaxis(outs, 0, 1), state           # (B,S,H,N)


def wkv_chunked(r, k, v, log_w, u, state, chunk: int = 64):
    """Chunk-parallel evaluation, exactly equal to ``wkv_sequential``.

    Within a chunk: lc_i = sum_{s<i} log_w_s (per channel).  The intra-chunk
    pair term uses exp(lc_i - lc_{j+1}) for j < i (exponent <= 0: safe); the
    cross-chunk term and state update factorize into einsums.
    """
    B, S, H, N = r.shape
    assert S % chunk == 0, (S, chunk)
    L = chunk
    nc = S // L
    rs = (r.astype(jnp.float32).reshape(B, nc, L, H, N),
          k.astype(jnp.float32).reshape(B, nc, L, H, N),
          v.astype(jnp.float32).reshape(B, nc, L, H, N),
          log_w.reshape(B, nc, L, H, N))

    tri = jnp.tril(jnp.ones((L, L), bool), k=-1)      # strict lower: j < i

    def body(s, inputs):
        rc, kc, vc, wc = inputs                       # (B,L,H,N)
        lc = jnp.cumsum(wc, axis=1) - wc              # lc_i = sum_{s<i}
        lc_end = lc[:, -1] + wc[:, -1]                # (B,H,N) full-chunk sum
        # cross-chunk: out_i += (r_i * exp(lc_i)) . S_prev
        r_dec = rc * jnp.exp(lc)
        out = jnp.einsum("blhk,bhkv->blhv", r_dec, s)
        # intra-chunk pairs: A[i,j] = sum_d r_i k_j exp(lc_i - lc_{j+1})
        lcs = lc + wc                                  # lc_{j+1}
        pair = jnp.exp(lc[:, :, None] - lcs[:, None, :, :, :])  # (B,L,L,H,N)
        pair = jnp.where(tri[None, :, :, None, None], pair, 0.0)
        A = jnp.einsum("blhd,bmhd,blmhd->blmh", rc, kc, pair)
        out = out + jnp.einsum("blmh,bmhv->blhv", A, vc)
        # current-token bonus: (r_i . u*k_i) v_i
        bonus = jnp.einsum("blhd,hd,blhd->blh", rc, u, kc)
        out = out + bonus[..., None] * vc
        # state update: S = diag(exp(lc_end)) S + sum_j (k_j exp(lc_end-lc_{j+1})) v_j^T
        k_dec = kc * jnp.exp(lc_end[:, None] - lcs)
        s_new = jnp.exp(lc_end)[..., None] * s + jnp.einsum(
            "blhk,blhv->bhkv", k_dec, vc)
        return s_new, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in rs)
    state, outs = jax.lax.scan(body, state, xs)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, S, H, N)
    return out, state


#: route the WKV recurrence through the Pallas kernel
#: (repro.kernels.wkv6) instead of the jnp chunked form.  "interpret"
#: validates on CPU; "tpu" for real hardware.  Module-level switch so the
#: whole arch flips without touching configs.
WKV_IMPL = "jnp"   # "jnp" | "interpret" | "tpu"


def time_mix(p, x, x_prev, wkv_state, cfg, mode, chunk: int = 64):
    """Full time-mix sub-block over a sequence (train/prefill)."""
    r, k, v, g, log_w, x_last = time_mix_inputs(p, x, x_prev, cfg, mode)
    u = p["bonus_u"]
    if WKV_IMPL != "jnp" and x.shape[1] % chunk == 0 and x.shape[1] > 1:
        from repro.kernels.wkv6 import wkv6 as wkv6_pallas
        out, new_state = wkv6_pallas(r, k, v, log_w, u, wkv_state,
                                     chunk=chunk,
                                     interpret=(WKV_IMPL == "interpret"))
        out = out.astype(jnp.float32)
    elif x.shape[1] % chunk == 0 and x.shape[1] > 1:
        out, new_state = wkv_chunked(r, k, v, log_w, u, wkv_state, chunk)
    else:
        out, new_state = wkv_sequential(r, k, v, log_w, u, wkv_state)
    y = _finalize(p, out.astype(x.dtype), g, cfg, mode)
    return y, x_last, new_state


def channel_mix(p, x, x_prev, mode):
    xx = _token_shift(x, x_prev)
    xk = x + (xx - x) * p["mu_k"].astype(x.dtype)
    h = layers.dense(p["wk"], xk, mode)
    h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
    return layers.dense(p["wv"], h, mode), x[:, -1, :]
