"""RWKV-6 causal LM (attention-free) — the assigned ``rwkv6-7b``.

State cache (decode) per layer: WKV state (B, H, N, N) f32 plus the two
token-shift carries (B, D).  Constant-size state => the natural long_500k
architecture (DESIGN.md §5).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers, rwkv6


def init_layer(key, cfg):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": layers.init_layernorm(cfg.d_model),
        "tm": rwkv6.init_time_mix(k1, cfg),
        "ln2": layers.init_layernorm(cfg.d_model),
        "cm": rwkv6.init_channel_mix(k2, cfg),
    }


def init(key, cfg):
    ke, kl, kh = jax.random.split(key, 3)
    return {
        "embed": layers.init_embedding(ke, cfg.vocab_padded, cfg.d_model),
        "ln0": layers.init_layernorm(cfg.d_model),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(
            jax.random.split(kl, cfg.num_layers)),
        "final_norm": layers.init_layernorm(cfg.d_model),
        "lm_head": layers.init_dense(kh, cfg.d_model, cfg.vocab_padded),
    }


def empty_state(cfg, batch_size: int):
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    L = cfg.num_layers
    return {
        "wkv": jnp.zeros((L, batch_size, h, n, n), jnp.float32),
        "x_tm": jnp.zeros((L, batch_size, d), layers.DTYPE),
        "x_cm": jnp.zeros((L, batch_size, d), layers.DTYPE),
    }


def _shard_state(state):
    state["wkv"] = shard(state["wkv"], None, "batch", "heads", None, None)
    return state


def forward(params, cfg, batch, state=None, *, return_state: bool = False):
    mode = cfg.matmul_mode
    tokens = batch["tokens"]
    B, S = tokens.shape
    if state is None:
        state = _shard_state(empty_state(cfg, B))
    x = layers.embed(params["embed"], tokens)
    x = layers.layer_norm(params["ln0"], x)
    x = shard(x, "batch", "seq", None)

    def body(x, layer_in):
        lp, wkv, x_tm, x_cm = layer_in
        h = layers.layer_norm(lp["ln1"], x)
        y, x_tm_new, wkv_new = rwkv6.time_mix(lp["tm"], h, x_tm, wkv, cfg, mode)
        x = x + y
        h = layers.layer_norm(lp["ln2"], x)
        y, x_cm_new = rwkv6.channel_mix(lp["cm"], h, x_cm, mode)
        x = x + y
        x = shard(x, "batch", "seq", None)
        return x, (wkv_new, x_tm_new, x_cm_new)

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    x, (wkv, x_tm, x_cm) = jax.lax.scan(
        body, x, (params["layers"], state["wkv"], state["x_tm"], state["x_cm"]))
    x = layers.layer_norm(params["final_norm"], x)
    new_state = {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm} if return_state else None
    return x, jnp.float32(0.0), new_state


def loss_fn(params, cfg, batch):
    from repro.models.causal_lm import logits_from_hidden  # shared CE path
    x, _, _ = forward(params, cfg, batch)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x2 = shard(x.reshape(B * S, -1), "tokens_flat", None)
    logits = logits_from_hidden(params, cfg, x2).astype(jnp.float32)
    vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
    logits = jnp.where(vmask[None, :], logits, -1e9)
    targets = jnp.roll(tokens, -1, axis=1).reshape(B * S)
    valid = jnp.ones((B, S), bool).at[:, -1].set(False).reshape(B * S)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=1)[:, 0]
    loss = ((lse - tgt) * valid).sum() / jnp.maximum(valid.sum(), 1)
    return loss, {"ce_loss": loss, "valid_tokens": valid.sum()}


def prefill(params, cfg, batch, cache_T: int = 0):
    from repro.models.causal_lm import logits_from_hidden
    x, _, state = forward(params, cfg, batch, return_state=True)
    logits = logits_from_hidden(params, cfg, x[:, -1:, :])[:, 0]
    return logits, state


def decode_step(params, cfg, batch):
    """batch: tokens (B,1), cache = rwkv state, cache_len unused (O(1) state)."""
    from repro.models.causal_lm import logits_from_hidden
    x, _, state = forward(params, cfg, {"tokens": batch["tokens"]},
                          state=batch["cache"], return_state=True)
    logits = logits_from_hidden(params, cfg, x)[:, 0]
    return logits, state
