"""Attention: GQA projections, scan-based flash attention, split-KV decode.

Two compute paths (DESIGN.md §4):

  * ``flash_attention`` — train/prefill.  Online-softmax over KV chunks via
    ``lax.scan``; peak memory is O(S x chunk) per head instead of O(S^2).
    With the sequence-parallel recipe, Q stays sequence-sharded while K/V are
    gathered (the ``kv_seq`` logical axis), giving context parallelism that
    is agnostic to head counts.
  * ``decode_attention`` — single-token decode against a (possibly
    seq-sharded) KV cache; the softmax reductions over the sharded cache axis
    lower to XLA partial reductions + cross-replica combines (split-KV /
    flash-decoding on the mesh).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def init_attention(key, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": layers.init_dense(kq, d, cfg.num_heads * hd, bias=cfg.qkv_bias),
        "wk": layers.init_dense(kk, d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wv": layers.init_dense(kv, d, cfg.num_kv_heads * hd, bias=cfg.qkv_bias),
        "wo": layers.init_dense(ko, cfg.num_heads * hd, d),
    }


def qkv_proj(params, x, cfg, mode):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = layers.dense(params["wq"], x, mode).reshape(B, S, cfg.num_heads, hd)
    k = layers.dense(params["wk"], x, mode).reshape(B, S, cfg.num_kv_heads, hd)
    v = layers.dense(params["wv"], x, mode).reshape(B, S, cfg.num_kv_heads, hd)
    return q, k, v


def flash_attention(q, k, v, *, causal: bool = True, q_offset=0,
                    chunk: int = 1024, kv_len: Optional[jax.Array] = None):
    """q (B,S,H,D); k/v (B,T,KH,D).  Returns (B,S,H,D).

    ``q_offset``: global position of q[0] (for chunked prefill continuation).
    ``kv_len``: optional valid-length mask over T (padded caches).
    """
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    G = H // KH
    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    scale = D ** -0.5
    qr = (q.astype(jnp.float32) * scale).reshape(B, S, KH, G, D)

    def body(carry, idx):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, idx * chunk, chunk, 1)
        vs = jax.lax.dynamic_slice_in_dim(v, idx * chunk, chunk, 1)
        s = jnp.einsum("bskgd,bckd->bskgc", qr, ks.astype(jnp.float32))
        kpos = idx * chunk + jnp.arange(chunk)
        mask = jnp.ones((S, chunk), bool)
        if causal:
            qpos = q_offset + jnp.arange(S)
            mask &= qpos[:, None] >= kpos[None, :]
        if kv_len is not None:
            mask &= kpos[None, :] < kv_len
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bskgc,bckd->bskgd", p, vs.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    init = (jnp.full((B, S, KH, G), NEG_INF, jnp.float32),
            jnp.zeros((B, S, KH, G), jnp.float32),
            jnp.zeros((B, S, KH, G, D), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.reshape(B, S, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *, k_scale=None,
                     v_scale=None):
    """q (B,S,H,D) against cache (B,T,KH,D).  Query row j sits at global
    position ``cache_len + j`` and attends to cache positions
    ``<= cache_len + j`` (its own K/V was already written there).  S = 1 is
    the classic one-token decode; S > 1 is the speculative multi-token
    verify step (the S rows form a tiny causal wedge over the cache).

    ``cache_len`` may be a scalar (whole batch at one position — static
    serving) or a (B,) vector of per-slot positions (continuous batching,
    where each slot decodes at its own depth).

    int8 KV cache support (per-token-per-head scales, EXACT factorization):
        score[b,kh,g,t] = (q . k_q[t]) * k_scale[b,t,kh]
        out = sum_t p[t] * v_scale[b,t,kh] * v_q[t]
    """
    B, S, H, D = q.shape
    T, KH = k_cache.shape[1], k_cache.shape[2]
    G = H // KH
    scale = D ** -0.5
    qr = (q.astype(jnp.float32) * scale).reshape(B, S, KH, G, D)
    s = jnp.einsum("bskgd,btkd->bskgt", qr, k_cache.astype(jnp.float32))
    if k_scale is not None:
        s = s * jnp.transpose(k_scale, (0, 2, 1))[:, None, :, None, :]
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        lim = cache_len + jnp.arange(S)                       # (S,)
        valid = (jnp.arange(T)[None, :] <= lim[:, None])[None, :, None,
                                                         None, :]
    else:
        lim = cache_len[:, None] + jnp.arange(S)[None, :]     # (B, S)
        valid = (jnp.arange(T)[None, None, :]
                 <= lim[:, :, None])[:, :, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        p = p * jnp.transpose(v_scale, (0, 2, 1))[:, None, :, None, :]
    out = jnp.einsum("bskgt,btkd->bskgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def decode_positions(cache_len, B, S: int = 1):
    """(B, S) RoPE positions for a decode/verify step: row j of slot b sits
    at ``cache_len[b] + j`` (scalar ``cache_len`` = whole batch at one
    depth)."""
    cache_len = jnp.asarray(cache_len)
    if cache_len.ndim == 0:
        return jnp.broadcast_to((cache_len + jnp.arange(S))[None], (B, S))
    return cache_len[:, None] + jnp.arange(S)[None, :]


def write_kv(cache, new, cache_len):
    """Write ``new`` (B, S, ...) into ``cache`` (B, T, ...) at positions
    ``cache_len .. cache_len + S - 1`` — scalar ``cache_len`` (one
    dynamic_update_slice for the whole batch) or (B,) vector (per-slot
    scatter, continuous batching / speculative verify).  Vector scatters
    whose positions fall outside T are dropped (jax OOB-scatter semantics):
    a speculative tail past the slab capacity lands nowhere and is never
    read back (the accept rule stops at the committed budget)."""
    cache_len = jnp.asarray(cache_len)
    S = new.shape[1]
    if cache_len.ndim == 0:
        if S == 1:
            idx = (0, cache_len) + (0,) * (cache.ndim - 2)
            return jax.lax.dynamic_update_slice(cache,
                                                new.astype(cache.dtype), idx)
        # multi-row: scatter per position so an overrunning tail DROPS
        # (dynamic_update_slice would clamp the start index and shift the
        # whole window backward over valid entries)
        pos = cache_len + jnp.arange(S)
        return cache.at[:, pos].set(new.astype(cache.dtype), mode="drop")
    B = new.shape[0]
    pos = cache_len[:, None] + jnp.arange(S)[None, :]
    return cache.at[jnp.arange(B)[:, None], pos].set(
        new.astype(cache.dtype), mode="drop")


def paged_write_kv(pages, new, block_ids, offsets):
    """Write ``new`` (B, S, ...) into block-paged ``pages`` (N, bs, ...) at
    per-(sequence, row) (physical block, in-block offset) positions, both
    (B, S).  Inactive rows and speculative overhang past a slot's block
    table target the trash block (id 0) — written, never read."""
    return pages.at[block_ids, offsets].set(new.astype(pages.dtype))


def paged_verify_attention(q, k_pages, v_pages, block_tables, cache_len, *,
                           k_scale=None, v_scale=None):
    """Multi-token verify attention over block-paged KV: gather each slot's
    pages dense through its block table, then run the same causal-wedge
    masking as :func:`decode_attention`.  The Pallas decode kernel is a
    one-query-row program, so the S > 1 verify path always takes the XLA
    gather formulation (it partitions under GSPMD on a mesh, like the
    paged-attention oracle)."""
    def lin(p):
        g = p[block_tables]                       # (B, P, bs, ...)
        return g.reshape(g.shape[0], g.shape[1] * g.shape[2], *g.shape[3:])

    return decode_attention(
        q, lin(k_pages), lin(v_pages), cache_len,
        k_scale=lin(k_scale) if k_scale is not None else None,
        v_scale=lin(v_scale) if v_scale is not None else None)


def paged_decode_attention(q, k_pages, v_pages, block_tables, cache_len, *,
                           k_scale=None, v_scale=None):
    """q (B,1,H,D) against block-paged K/V (N, bs, KH, D) through per-slot
    block tables (B, P); positions <= cache_len valid, exactly as
    :func:`decode_attention`.  Dispatches to the Pallas paged-attention
    kernel / XLA gather oracle per the active matmul backend (under a mesh
    trace the dispatch itself resolves to the oracle — pages are
    replicated and the gather partitions under GSPMD)."""
    from repro.kernels.paged_attention.ops import paged_attention
    B, _, H, D = q.shape
    out = paged_attention(q[:, 0], k_pages, v_pages, block_tables,
                          jnp.asarray(cache_len),
                          k_scale_pages=k_scale, v_scale_pages=v_scale)
    return out[:, None]


def quantize_kv(k, v):
    """Per (batch, position, head) symmetric int8 quantization of K/V.

    k/v (B, S, KH, D) -> (k_q int8, k_scale f32 (B,S,KH), v_q, v_scale)."""
    def one(t):
        amax = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1)
        s = jnp.maximum(amax, 1e-8) / 127.0
        q = jnp.clip(jnp.round(t.astype(jnp.float32) / s[..., None]),
                     -127, 127).astype(jnp.int8)
        return q, s
    kq, ks = one(k)
    vq, vs = one(v)
    return kq, ks, vq, vs


def attention_block(params, x, cfg, mode, *, cos, sin, causal=True,
                    cross_kv=None, cross_len=None):
    """Full attention sub-block for train/prefill (returns out, (k, v)).

    ``cross_kv``: (k, v) from an encoder — cross-attention (no RoPE on q? we
    follow standard enc-dec: RoPE is not applied for cross attention)."""
    B, S, _ = x.shape
    q, k, v = qkv_proj(params, x, cfg, mode)
    if cross_kv is not None:
        k, v = cross_kv
        out = flash_attention(q, k, v, causal=False, kv_len=cross_len)
    else:
        if cos is not None:
            q = layers.apply_rope(q, cos, sin)
            k = layers.apply_rope(k, cos, sin)
        k = shard(k, "batch", "kv_seq", "heads", None)
        v = shard(v, "batch", "kv_seq", "heads", None)
        out = flash_attention(q, k, v, causal=causal)
    out = out.reshape(B, S, cfg.num_heads * cfg.resolved_head_dim)
    return layers.dense(params["wo"], out, mode), (k, v)
