"""Decoder-only causal LM covering the dense / GQA / MoE / VLM families.

Layer stack is a ``lax.scan`` over stacked layer params (compact HLO — a
512-device SPMD compile sees one layer body) with activation checkpointing.
The BitParticle matmul mode is plumbed through every dense contraction.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import probe as _probe
from repro.distributed.sharding import shard
from repro.models import attention, layers
from repro.models.moe import init_moe, moe_ffn


def init_layer(key, cfg):
    ka, kf, kn1, kn2 = jax.random.split(key, 4)
    p = {
        "attn_norm": layers.init_rmsnorm(cfg.d_model),
        "attn": attention.init_attention(ka, cfg),
        "ffn_norm": layers.init_rmsnorm(cfg.d_model),
    }
    if cfg.num_experts:
        p["moe"] = init_moe(kf, cfg)
    else:
        p["ffn"] = layers.init_ffn(kf, cfg.d_model, cfg.d_ff, cfg.ffn_type)
    return p


def init(key, cfg):
    ke, kl, kh = jax.random.split(key, 3)
    params = {
        "embed": layers.init_embedding(ke, cfg.vocab_padded, cfg.d_model),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(
            jax.random.split(kl, cfg.num_layers)),
        "final_norm": layers.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.init_dense(kh, cfg.d_model,
                                              cfg.vocab_padded)
    return params


def _angles(cfg, positions):
    """positions: (B, S) or (3, B, S) for M-RoPE."""
    hd = cfg.resolved_head_dim
    if cfg.mrope_sections:
        assert positions.ndim == 3
        return layers.mrope_angles(positions, hd, cfg.rope_theta,
                                   cfg.mrope_sections)
    return layers.rope_angles(positions, hd, cfg.rope_theta)


def _embed_inputs(params, cfg, batch):
    tokens = batch["tokens"]
    x = layers.embed(params["embed"], tokens)
    if cfg.family == "vlm" and "vision_embeds" in batch:
        x = jnp.where(batch["vision_mask"][..., None],
                      batch["vision_embeds"].astype(x.dtype), x)
    B, S = tokens.shape
    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if cfg.mrope_sections:
            positions = jnp.broadcast_to(positions[None], (3, B, S))
    return x, positions


def _block(lp, x, cfg, mode, cos, sin):
    h = layers.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
    attn_out, kv = attention.attention_block(lp["attn"], h, cfg, mode,
                                             cos=cos, sin=sin)
    x = x + attn_out
    h = layers.rms_norm(lp["ffn_norm"], x, cfg.norm_eps)
    if cfg.num_experts:
        f, aux = moe_ffn(lp["moe"], h, cfg, mode)
    else:
        f, aux = layers.ffn(lp["ffn"], h, cfg.ffn_type, mode), jnp.float32(0)
    x = x + f
    x = shard(x, "batch", "seq", None)
    return x, kv, aux


def forward(params, cfg, batch, *, return_cache: bool = False,
            cache_T: Optional[int] = None):
    """Returns (hidden (B,S,D), aux_loss, cache|None)."""
    mode = cfg.matmul_mode
    probing = _probe.tap_active()
    x, positions = _embed_inputs(params, cfg, batch)
    x = shard(x, "batch", "seq", None)
    cos, sin = _angles(cfg, positions)
    # pre-scan taps (e.g. the VLM projector) must not become per-layer
    # closure constants of the scan body
    _probe.absorb_pending()

    def body(carry, lp):
        y, kv, aux = _block(lp, carry, cfg, mode, cos, sin)
        if return_cache:
            k, v = kv
            if cfg.kv_cache_int8:
                k, ks_, v, vs_ = attention.quantize_kv(k, v)
            if cache_T is not None and cache_T > k.shape[1]:
                pad_t = cache_T - k.shape[1]
                pad = [(0, 0), (0, pad_t), (0, 0), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
                if cfg.kv_cache_int8:
                    spad = [(0, 0), (0, pad_t), (0, 0)]
                    ks_, vs_ = jnp.pad(ks_, spad), jnp.pad(vs_, spad)
            k = shard(k, "batch", "cache_seq", "heads", None)
            v = shard(v, "batch", "cache_seq", "heads", None)
            if cfg.kv_cache_int8:
                ys = (k, ks_, v, vs_, aux)
            else:
                ys = (k, v, aux)
        else:
            ys = (aux,)
        if probing:
            ys = ys + (_probe.drain_layer(),)
        return y, ys

    body = jax.checkpoint(body,
                          policy=jax.checkpoint_policies.nothing_saveable)
    if return_cache:
        if cfg.kv_cache_int8:
            x, ys = jax.lax.scan(body, x, params["layers"])
            ks, kss, vs, vss, auxs = ys[:5]
            cache = {"k": ks, "k_scale": kss, "v": vs, "v_scale": vss}
        else:
            x, ys = jax.lax.scan(body, x, params["layers"])
            ks, vs, auxs = ys[:3]
            cache = {"k": ks, "v": vs}
    else:
        x, ys = jax.lax.scan(body, x, params["layers"])
        auxs = ys[0]
        cache = None
    if probing:
        _probe.emit_layers(ys[-1])
    x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, jnp.sum(auxs), cache


def logits_from_hidden(params, cfg, x):
    if cfg.tie_embeddings:
        return layers.unembed(params["embed"], x)
    return layers.dense(params["lm_head"], x, cfg.matmul_mode)


def loss_fn(params, cfg, batch):
    """Causal LM loss (next-token prediction; final position masked)."""
    x, aux, _ = forward(params, cfg, batch)
    tokens = batch["tokens"]
    B, S = tokens.shape
    T = B * S
    x2 = shard(x.reshape(T, -1), "tokens_flat", None)
    logits = logits_from_hidden(params, cfg, x2).astype(jnp.float32)
    logits = shard(logits, "tokens_flat", None)
    # mask padded vocab region out of the softmax
    vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
    logits = jnp.where(vmask[None, :], logits, -1e9)
    targets = jnp.roll(tokens, -1, axis=1).reshape(T)
    valid = jnp.ones((B, S), bool).at[:, -1].set(False)
    if "loss_mask" in batch:
        valid &= batch["loss_mask"]
    valid = valid.reshape(T)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=1)[:, 0]
    nll = (lse - tgt) * valid
    loss = nll.sum() / jnp.maximum(valid.sum(), 1)
    metrics = {"ce_loss": loss, "aux_loss": aux,
               "valid_tokens": valid.sum()}
    return loss + 0.01 * aux, metrics


def prefill(params, cfg, batch, cache_T: int, prompt_lens=None):
    """Run the prompt, return (last-position logits, KV cache padded to
    cache_T).

    ``prompt_lens`` (B,) enables ragged right-padded batches (the
    scheduler's power-of-two prefill buckets): logits are gathered at each
    row's own last valid position.  Causal masking makes valid positions
    independent of the right padding, and padded cache positions sit beyond
    ``cache_len`` — masked in decode until overwritten."""
    x, _, cache = forward(params, cfg, batch, return_cache=True,
                          cache_T=cache_T)
    if prompt_lens is None:
        last = x[:, -1:, :]
    else:
        idx = (jnp.asarray(prompt_lens, jnp.int32) - 1)[:, None, None]
        last = jnp.take_along_axis(x, idx, axis=1)
    logits = logits_from_hidden(params, cfg, last)[:, 0]
    return logits, cache


def _decode_common(params, cfg, batch, *, write_fn, attend_fn):
    """Shared decode/verify body over S >= 1 appended tokens; the cache
    layout enters only through ``write_fn(cache_leaf, new)`` (install the
    new tokens' K/V/scales) and ``attend_fn(q, kc, vc, ksc, vsc)``
    (attention over that layout).  Returns (logits (B, S, V), cache)."""
    mode = cfg.matmul_mode
    probing = _probe.tap_active()
    tokens, cache = batch["tokens"], batch["cache"]
    cache_len = jnp.asarray(batch["cache_len"])
    B, S = tokens.shape
    x = layers.embed(params["embed"], tokens)
    x = shard(x, "batch", None, None)
    _probe.absorb_pending()
    pos = attention.decode_positions(cache_len, B, S)
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[None], (3, B, S))
    cos, sin = _angles(cfg, pos)
    hd = cfg.resolved_head_dim

    int8kv = cfg.kv_cache_int8

    def body(x, layer_in):
        if int8kv:
            lp, kc, ksc, vc, vsc = layer_in
        else:
            lp, kc, vc = layer_in
        h = layers.rms_norm(lp["attn_norm"], x, cfg.norm_eps)
        q, k, v = attention.qkv_proj(lp["attn"], h, cfg, mode)
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
        if int8kv:
            k, ks_, v, vs_ = attention.quantize_kv(k, v)
            ksc = write_fn(ksc, ks_)
            vsc = write_fn(vsc, vs_)
        kc = write_fn(kc, k)
        vc = write_fn(vc, v)
        out = attend_fn(q, kc, vc,
                        ksc if int8kv else None, vsc if int8kv else None)
        out = out.reshape(B, S, cfg.num_heads * hd)
        x = x + layers.dense(lp["attn"]["wo"], out, mode)
        h = layers.rms_norm(lp["ffn_norm"], x, cfg.norm_eps)
        if cfg.num_experts:
            f, _ = moe_ffn(lp["moe"], h, cfg, mode)
        else:
            f = layers.ffn(lp["ffn"], h, cfg.ffn_type, mode)
        x = x + f
        ys = (kc, ksc, vc, vsc) if int8kv else (kc, vc)
        if probing:
            ys = ys + (_probe.drain_layer(),)
        return x, ys

    if int8kv:
        xs = (params["layers"], cache["k"], cache["k_scale"],
              cache["v"], cache["v_scale"])
        x, ys = jax.lax.scan(body, x, xs)
        ks, kss, vs, vss = ys[:4]
        new_cache = {"k": ks, "k_scale": kss, "v": vs, "v_scale": vss}
    else:
        x, ys = jax.lax.scan(body, x, (params["layers"],
                                       cache["k"], cache["v"]))
        ks, vs = ys[:2]
        new_cache = {"k": ks, "v": vs}
    if probing:
        _probe.emit_layers(ys[-1])
    x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x)
    return logits, new_cache


def _slab_fns(batch):
    """(write_fn, attend_fn) over the slab cache layout for the S tokens of
    ``batch`` (S = 1: decode; S > 1: speculative verify)."""
    cache_len = jnp.asarray(batch["cache_len"])

    def write_fn(c, new):
        c = attention.write_kv(c, new, cache_len)
        if c.ndim == 4:   # KV leaves (B, T, KH, hd)
            c = shard(c, "batch", "cache_seq", "heads", None)
        else:             # int8 KV scale leaves (B, T, KH): same layout, so
            c = shard(c, "batch", "cache_seq", "heads")
        # the resident cache keeps ONE mesh placement across decode steps
        # (the executor donates the buffer — layout drift would force a
        # reshard copy instead of aliasing)
        return c

    def attend_fn(q, kc, vc, ksc, vsc):
        return attention.decode_attention(q, kc, vc, cache_len,
                                          k_scale=ksc, v_scale=vsc)

    return write_fn, attend_fn


def _paged_fns(batch):
    """(write_fn, attend_fn) over the block-paged layout.  Write targets
    past a slot's table span are redirected to the trash block (speculative
    overhang lands nowhere); the S = 1 attend dispatches to the Pallas
    kernel / XLA oracle, S > 1 takes the dense-gather verify formulation."""
    cache_len = jnp.asarray(batch["cache_len"])
    tables = jnp.asarray(batch["block_tables"], jnp.int32)
    bs = batch["cache"]["k"].shape[2]
    S = batch["tokens"].shape[1]
    P = tables.shape[1]
    # physical write target per (slot, row): table entry at pos // bs
    pos = cache_len[:, None] + jnp.arange(S)[None, :]
    bi = pos // bs
    blk = jnp.take_along_axis(tables, jnp.minimum(bi, P - 1), axis=1)
    blk = jnp.where(bi < P, blk, 0)      # overhang -> trash block
    off = pos % bs

    def write_fn(c, new):
        return attention.paged_write_kv(c, new, blk, off)

    def attend_fn(q, kc, vc, ksc, vsc):
        if S == 1:
            return attention.paged_decode_attention(
                q, kc, vc, tables, cache_len, k_scale=ksc, v_scale=vsc)
        return attention.paged_verify_attention(
            q, kc, vc, tables, cache_len, k_scale=ksc, v_scale=vsc)

    return write_fn, attend_fn


def decode_step(params, cfg, batch):
    """One-token decode.  batch: tokens (B,1), cache {k,v}: (L,B,T,KH,Dh),
    cache_len: scalar int32 (whole batch at one depth) or (B,) int32
    (per-slot depths, continuous batching).  Returns (logits (B,V), cache)."""
    write_fn, attend_fn = _slab_fns(batch)
    logits, cache = _decode_common(params, cfg, batch,
                                   write_fn=write_fn, attend_fn=attend_fn)
    return logits[:, 0], cache


def verify_step(params, cfg, batch):
    """Speculative multi-token verify against the slab cache.

    batch: tokens (B, S) — the last committed token followed by S-1 draft
    tokens per slot, appended in ONE forward pass at per-slot positions
    ``cache_len .. cache_len + S - 1`` (row j attends causally through the
    cache up to its own position).  Returns (logits (B, S, V), cache):
    ``logits[:, j]`` is the target distribution AFTER consuming fed token
    j — greedy accept compares ``argmax(logits[:, j-1])`` with draft j.
    Rows past a slot's real draft length are padding: their K/V land beyond
    the committed region (masked, rolled back by the cache manager)."""
    write_fn, attend_fn = _slab_fns(batch)
    return _decode_common(params, cfg, batch,
                          write_fn=write_fn, attend_fn=attend_fn)


def decode_step_paged(params, cfg, batch):
    """One-token decode against a block-paged KV cache.

    batch: tokens (B,1); cache {k,v[,k_scale,v_scale]} with KV paged as
    (L, num_blocks, block_size, KH, Dh); block_tables (B, P) int32 physical
    page ids; cache_len (B,) int32 per-slot positions.  The new token's K/V
    is scattered to (table[pos // bs], pos % bs) per slot, and attention
    gathers through the block table (Pallas kernel / XLA oracle per the
    active backend).  The page pool has no batch/cache_seq axes to lay on
    the mesh, so paged leaves stay replicated.  Returns (logits, cache)."""
    write_fn, attend_fn = _paged_fns(batch)
    logits, cache = _decode_common(params, cfg, batch,
                                   write_fn=write_fn, attend_fn=attend_fn)
    return logits[:, 0], cache


def verify_step_paged(params, cfg, batch):
    """Speculative multi-token verify against the block-paged cache — the
    :func:`verify_step` contract with ``block_tables`` routing the writes
    and the dense-gather verify attention.  The cache manager must have
    prepared writable blocks for each slot's committed span
    (``prepare_append`` allocates/CoWs); overhang rows write to the trash
    block."""
    write_fn, attend_fn = _paged_fns(batch)
    return _decode_common(params, cfg, batch,
                          write_fn=write_fn, attend_fn=attend_fn)
