"""Zamba2 hybrid: Mamba-2 backbone + one SHARED attention(+FFN) block applied
every ``attn_every`` layers with per-invocation input norm (DESIGN.md §7).

Scan structure: outer scan over super-blocks (attn_every mamba layers + one
shared-attn invocation); mamba params stacked (n_super, attn_every, ...),
shared-attn params unstacked (closure), per-invocation norms stacked
(n_super, ...).  Decode cache: conv + SSM states per mamba layer and a KV
cache per shared-attn invocation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import attention, layers, mamba2


def _n_super(cfg):
    assert cfg.num_layers % cfg.attn_every == 0
    return cfg.num_layers // cfg.attn_every


def init(key, cfg):
    ke, km, ka, kf, kn, kh = jax.random.split(key, 6)
    n_sup, ae = _n_super(cfg), cfg.attn_every

    def init_mamba_layer(k):
        return {"norm": layers.init_rmsnorm(cfg.d_model),
                "mixer": mamba2.init_mamba2(k, cfg)}

    mamba_keys = jax.random.split(km, n_sup * ae).reshape(n_sup, ae, 2)
    return {
        "embed": layers.init_embedding(ke, cfg.vocab_padded, cfg.d_model),
        "mamba": jax.vmap(jax.vmap(init_mamba_layer))(mamba_keys),
        "shared_attn": attention.init_attention(ka, cfg),
        "shared_ffn": layers.init_ffn(kf, cfg.d_model, cfg.d_ff, cfg.ffn_type),
        "inv_norm": jax.vmap(lambda k: layers.init_rmsnorm(cfg.d_model))(
            jax.random.split(kn, n_sup)),
        "inv_ffn_norm": jax.vmap(lambda k: layers.init_rmsnorm(cfg.d_model))(
            jax.random.split(kn, n_sup)),
        "final_norm": layers.init_rmsnorm(cfg.d_model),
        "lm_head": layers.init_dense(kh, cfg.d_model, cfg.vocab_padded),
    }


def empty_cache(cfg, batch_size: int, cache_T: int):
    n_sup, ae = _n_super(cfg), cfg.attn_every
    di = mamba2.d_inner(cfg)
    conv_dim = di + 2 * cfg.ssm_state
    h = mamba2.n_ssm_heads(cfg)
    return {
        "conv": jnp.zeros((n_sup, ae, batch_size, cfg.ssm_conv_width - 1,
                           conv_dim), layers.DTYPE),
        "ssm": jnp.zeros((n_sup, ae, batch_size, h, cfg.ssm_head_dim,
                          cfg.ssm_state), jnp.float32),
        "k": jnp.zeros((n_sup, batch_size, cache_T, cfg.num_kv_heads,
                        cfg.resolved_head_dim), layers.DTYPE),
        "v": jnp.zeros((n_sup, batch_size, cache_T, cfg.num_kv_heads,
                        cfg.resolved_head_dim), layers.DTYPE),
    }


def forward(params, cfg, batch, *, return_cache: bool = False,
            cache_T: int = 0):
    mode = cfg.matmul_mode
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = layers.embed(params["embed"], tokens)
    x = shard(x, "batch", "seq", None)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    cos, sin = layers.rope_angles(positions, cfg.resolved_head_dim,
                                  cfg.rope_theta)

    def mamba_body(x, lp):
        h = layers.rms_norm(lp["norm"], x, cfg.norm_eps)
        y, conv_s, ssm_s = mamba2.mamba2_block(lp["mixer"], h, cfg, mode)
        x = x + y
        x = shard(x, "batch", "seq", None)
        return x, (conv_s, ssm_s)

    def super_body(x, sp):
        mp, inv_norm, inv_ffn_norm = sp
        x, (conv_s, ssm_s) = jax.lax.scan(mamba_body, x, mp)
        h = layers.rms_norm(inv_norm, x, cfg.norm_eps)
        attn_out, (k, v) = attention.attention_block(
            params["shared_attn"], h, cfg, mode, cos=cos, sin=sin)
        x = x + attn_out
        h = layers.rms_norm(inv_ffn_norm, x, cfg.norm_eps)
        x = x + layers.ffn(params["shared_ffn"], h, cfg.ffn_type, mode)
        x = shard(x, "batch", "seq", None)
        if return_cache:
            if cache_T > k.shape[1]:
                pad = [(0, 0), (0, cache_T - k.shape[1]), (0, 0), (0, 0)]
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            k = shard(k, "batch", "cache_seq", "heads", None)
            v = shard(v, "batch", "cache_seq", "heads", None)
            return x, (conv_s, ssm_s, k, v)
        return x, None

    super_body = jax.checkpoint(
        super_body, policy=jax.checkpoint_policies.nothing_saveable)
    xs = (params["mamba"], params["inv_norm"], params["inv_ffn_norm"])
    x, ys = jax.lax.scan(super_body, x, xs)
    x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
    cache = None
    if return_cache:
        conv_s, ssm_s, ks, vs = ys
        cache = {"conv": conv_s, "ssm": ssm_s, "k": ks, "v": vs}
    return x, jnp.float32(0.0), cache


def loss_fn(params, cfg, batch):
    from repro.models.causal_lm import logits_from_hidden
    x, _, _ = forward(params, cfg, batch)
    tokens = batch["tokens"]
    B, S = tokens.shape
    x2 = shard(x.reshape(B * S, -1), "tokens_flat", None)
    logits = logits_from_hidden(params, cfg, x2).astype(jnp.float32)
    vmask = jnp.arange(cfg.vocab_padded) < cfg.vocab_size
    logits = jnp.where(vmask[None, :], logits, -1e9)
    targets = jnp.roll(tokens, -1, axis=1).reshape(B * S)
    valid = jnp.ones((B, S), bool).at[:, -1].set(False).reshape(B * S)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[:, None], axis=1)[:, 0]
    loss = ((lse - tgt) * valid).sum() / jnp.maximum(valid.sum(), 1)
    return loss, {"ce_loss": loss, "valid_tokens": valid.sum()}


def prefill(params, cfg, batch, cache_T: int):
    from repro.models.causal_lm import logits_from_hidden
    x, _, cache = forward(params, cfg, batch, return_cache=True,
                          cache_T=cache_T)
    logits = logits_from_hidden(params, cfg, x[:, -1:, :])[:, 0]
    return logits, cache


def decode_step(params, cfg, batch):
    from repro.models.causal_lm import logits_from_hidden
    mode = cfg.matmul_mode
    tokens, cache = batch["tokens"], batch["cache"]
    cache_len = jnp.asarray(batch["cache_len"])
    B = tokens.shape[0]
    x = layers.embed(params["embed"], tokens)
    pos = attention.decode_positions(cache_len, B)
    cos, sin = layers.rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)

    def mamba_body(x, lin):
        lp, conv_s, ssm_s = lin
        h = layers.rms_norm(lp["norm"], x, cfg.norm_eps)
        y, conv_s, ssm_s = mamba2.mamba2_block(
            lp["mixer"], h, cfg, mode, conv_state=conv_s, ssm_state=ssm_s,
            single_step=True)
        return x + y, (conv_s, ssm_s)

    def super_body(x, sin_):
        mp, inv_norm, inv_ffn_norm, conv_s, ssm_s, kc, vc = sin_
        x, (conv_s, ssm_s) = jax.lax.scan(mamba_body, x, (mp, conv_s, ssm_s))
        h = layers.rms_norm(inv_norm, x, cfg.norm_eps)
        q, k, v = attention.qkv_proj(params["shared_attn"], h, cfg, mode)
        q = layers.apply_rope(q, cos, sin)
        k = layers.apply_rope(k, cos, sin)
        kc = attention.write_kv(kc, k, cache_len)
        vc = attention.write_kv(vc, v, cache_len)
        kc = shard(kc, "batch", "cache_seq", "heads", None)
        vc = shard(vc, "batch", "cache_seq", "heads", None)
        out = attention.decode_attention(q, kc, vc, cache_len)
        out = out.reshape(B, 1, cfg.num_heads * cfg.resolved_head_dim)
        x = x + layers.dense(params["shared_attn"]["wo"], out, mode)
        h = layers.rms_norm(inv_ffn_norm, x, cfg.norm_eps)
        x = x + layers.ffn(params["shared_ffn"], h, cfg.ffn_type, mode)
        return x, (conv_s, ssm_s, kc, vc)

    xs = (params["mamba"], params["inv_norm"], params["inv_ffn_norm"],
          cache["conv"], cache["ssm"], cache["k"], cache["v"])
    x, (conv_s, ssm_s, ks, vs) = jax.lax.scan(super_body, x, xs)
    x = layers.rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = logits_from_hidden(params, cfg, x)[:, 0]
    return logits, {"conv": conv_s, "ssm": ssm_s, "k": ks, "v": vs}
