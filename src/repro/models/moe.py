"""Mixture-of-Experts FFN: grouped, sort-based, capacity-bounded dispatch.

Perf iteration #A (EXPERIMENTS.md §Perf): the original global sort-based
dispatch scattered token rows into an expert-major buffer ACROSS the
expert-parallel axis; GSPMD lowers cross-shard data-dependent scatter/gather
as replicate+all-reduce (measured 1.1e13 B/device/step on moonshot train).

The fix is GShard-style grouping: tokens reshape to (G, T_g, D) with the
group axis sharded over the data axes, so top-k / sort / capacity / scatter
are *batched per group* and therefore shard-local.  The only cross-shard
movement is the (G, E, C_g, D) dispatch buffer resharding from G-sharded to
E-sharded — exactly the expert-parallel all-to-all (T*k*cf*D bytes global,
the information-theoretic minimum for capacity-based routing) — and back.

Token-choice top-k with per-group capacity drops (GShard semantics); the
grouped einsum's HLO FLOPs track active-expert FLOPs x capacity_factor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers


def init_moe(key, cfg):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    p = {"router": layers.init_dense(kr, d, e, stddev=0.02)}
    std_in, std_out = d ** -0.5, f ** -0.5
    if cfg.ffn_type == "swiglu":
        p["experts_gate"] = layers.truncated_normal(kg, (e, d, f), std_in)
        p["experts_up"] = layers.truncated_normal(ku, (e, d, f), std_in)
    else:
        p["experts_up"] = layers.truncated_normal(ku, (e, d, f), std_in)
    p["experts_down"] = layers.truncated_normal(kd, (e, f, d), std_out)
    return p


def _num_groups(n_tokens: int) -> int:
    """Largest G in {512..1} dividing T with T/G >= 64, falling back to
    T/G >= 8 for small token counts (decode steps) so groups still align
    with the data axes.

    512 = the full production device count: groups shard over
    (pod, data, model) during dispatch, so the G-major -> E-major reshard is
    a pure all-to-all (each device trades its G-shards for E-shards)."""
    for g in (512, 256, 128, 64, 32, 16, 8, 4, 2):
        if n_tokens % g == 0 and n_tokens // g >= 8:
            return g
    return 1


def _capacity(tokens_per_group: int, cfg) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # pad to 8 for TPU-friendly shapes


def _dispatch_indices(top_e, C, E):
    """Per-group dispatch. top_e: (Tg, K) expert ids.

    Returns (slot (Tg*K,) in [0, E*C] with E*C = dropped, token_of (Tg*K,)).
    """
    Tg, K = top_e.shape
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.zeros((E,), jnp.int32).at[flat_e].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(Tg * K, dtype=jnp.int32) - starts[sorted_e]
    keep = pos < C
    slot = jnp.where(keep, sorted_e * C + pos, E * C)
    return slot, order, keep


def moe_ffn(params, x, cfg, mode="bf16"):
    """x (B, S, D) -> (B, S, D), plus the load-balancing aux loss."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    T = B * S
    G = _num_groups(T)
    Tg = T // G
    C = _capacity(Tg, cfg)
    xg = x.reshape(G, Tg, D)
    xg = shard(xg, "tokens_flat", None, None)        # groups over (pod, data)

    router_logits = layers.dense(params["router"], xg,
                                 "bf16").astype(jnp.float32)  # (G, Tg, E)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)           # (G, Tg, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- load-balancing aux loss (Switch Transformer), over all tokens ----
    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros((E,), jnp.float32).at[top_e.reshape(-1)].add(1.0 / (T * K))
    aux_loss = E * jnp.sum(me * ce)

    # --- per-group (shard-local) sort-based dispatch -----------------------
    slot, order, keep = jax.vmap(
        lambda te: _dispatch_indices(te, C, E))(top_e)        # (G, Tg*K)
    token_of = order // K

    def scatter_group(xt, sl, tok):
        buf = jnp.zeros((E * C + 1, D), x.dtype).at[sl].set(xt[tok])
        return buf[:-1]

    buf = jax.vmap(scatter_group)(xg, slot, token_of)         # (G, E*C, D)
    buf = buf.reshape(G, E, C, D)
    buf = shard(buf, "tokens_flat", None, None, None)
    # ---- the expert-parallel all-to-all: G stays sharded over the batch
    # axes while E picks up the "model" axis — GSPMD lowers this exact
    # split/concat signature as all-to-all, not all-gather
    buf = shard(buf, "batch", "experts", None, None)

    # --- grouped expert FFN (E sharded over "model") -----------------------
    def emm(t, w):   # (G, E, C, a) x (E, a, b) -> (G, E, C, b)
        return jnp.einsum("geca,eab->gecb", t, w.astype(t.dtype))

    if cfg.ffn_type == "swiglu":
        g = emm(buf, params["experts_gate"])
        u = emm(buf, params["experts_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(emm(buf, params["experts_up"]).astype(jnp.float32)
                        ).astype(x.dtype)
    out_buf = emm(h, params["experts_down"])                  # (G, E, C, D)
    out_buf = shard(out_buf, "batch", "experts", None, None)
    # ---- all-to-all back: E-major -> G-major ------------------------------
    out_buf = shard(out_buf, "tokens_flat", None, None, None)
    out_buf = out_buf.reshape(G, E * C, D)
    out_buf = jnp.concatenate(
        [out_buf, jnp.zeros((G, 1, D), x.dtype)], axis=1)     # drop slot

    # --- combine (shard-local gather per group) ----------------------------
    weight = (top_p.reshape(G, Tg * K)[
        jnp.arange(G)[:, None], order] * keep).astype(x.dtype)

    def combine_group(ob, sl, od, wt):
        gathered = ob[sl] * wt[:, None]                       # (Tg*K, D)
        contrib = jnp.zeros((Tg * K, D), x.dtype).at[od].set(gathered)
        return contrib.reshape(Tg, K, D).sum(axis=1)

    out = jax.vmap(combine_group)(out_buf, slot, order, weight)
    out = shard(out, "tokens_flat", None, None)
    return out.reshape(B, S, D), aux_loss
