"""AdamW with fp32 master params, global-norm clipping, cosine schedule.

Built from scratch (no optax offline): states are a pytree mirroring params
{m, v, master} so they shard with the same partition rules (FSDP).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(cfg: OptimizerConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = cfg.peak_lr * jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_state(params) -> Dict[str, Any]:
    # derive zeros from each param so every leaf owns a distinct buffer
    # (identical zero constants can alias, which breaks jit donation)
    zeros32 = lambda p: (p * 0).astype(jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        # +0.0 forces a fresh buffer: an already-f32 param would otherwise
        # ALIAS its master (astype is a no-op), breaking donation downstream
        "master": jax.tree.map(
            lambda p: p.astype(jnp.float32) + 0.0, params),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def _decay_mask(path) -> bool:
    """Decay 2D+ matrices; skip norms/biases/1D tables-of-scalars."""
    name = "/".join(str(getattr(k, "key", k)) for k in path)
    return not any(s in name for s in ("norm", "scale", "bias", "ln", "_b"))


def apply_updates(cfg: OptimizerConfig, params, opt_state, grads):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    flat_paths = [p for p, _ in
                  jax.tree_util.tree_flatten_with_path(params)[0]]
    decay_flags = [_decay_mask(p) for p in flat_paths]
    treedef = jax.tree.structure(params)
    decay_tree = jax.tree.unflatten(treedef, decay_flags)

    def upd(g, m, v, master, decay):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        if decay:
            u = u + cfg.weight_decay * master
        master = master - lr * u
        return m, v, master

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"],
                       opt_state["master"], decay_tree)
    m = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
    new_state = {"step": step, "m": m, "v": v, "master": master}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
