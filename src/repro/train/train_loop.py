"""Production training loop: jitted step (grad accumulation, donation,
optional compressed-gradient path), on-device NaN/spike step rejection,
checkpoint/resume, preemption-safe exit.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import CheckpointManager
from repro.data.pipeline import DataConfig, PrefetchingLoader
from repro.distributed import compression
from repro.models import api
from repro.runtime.fault_tolerance import PreemptionGuard, with_retries
from repro.train import optimizer as opt_lib

log = logging.getLogger("repro.train")


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    total_steps: int = 200
    grad_accum: int = 1
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_keep: int = 3
    compress_grads: bool = False     # int8 + error-feedback cross-pod model
    log_every: int = 10
    spike_factor: float = 4.0        # reject loss > factor x running median
    max_consecutive_skips: int = 8
    optimizer: opt_lib.OptimizerConfig = opt_lib.OptimizerConfig()


def make_train_step(arch_cfg, train_cfg: TrainConfig) -> Callable:
    """Jitted (params, opt_state, err_state, batch, loss_median) -> step.

    Step rejection happens ON DEVICE (jnp.where-select of old vs new state),
    so buffer donation stays valid even for rejected steps: a non-finite or
    spiking loss commits the ORIGINAL params/opt state.
    """
    accum = train_cfg.grad_accum
    opt_cfg = train_cfg.optimizer

    def loss_of(params, batch):
        return api.loss_fn(params, arch_cfg, batch)

    def compute_grads(params, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)
            return loss, metrics, grads
        def micro(carry, mb):
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, mb)
            acc_loss, acc_grads = carry
            return (acc_loss + loss,
                    jax.tree.map(jnp.add, acc_grads, grads)), metrics
        micro_batches = jax.tree.map(
            lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
            batch)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (loss, grads), metrics = jax.lax.scan(
            micro, (jnp.float32(0), zeros), micro_batches)
        grads = jax.tree.map(lambda g: g / accum, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss / accum, metrics, grads

    def step(params, opt_state, err_state, batch, loss_median):
        loss, metrics, grads = compute_grads(params, batch)
        new_err = err_state
        if train_cfg.compress_grads:
            # wire-format model of the cross-pod compressed all-reduce:
            # quantize + error-feedback the contribution being reduced
            grads, new_err = compression.compress_tree_with_feedback(
                grads, err_state)
        new_params, new_opt, opt_metrics = opt_lib.apply_updates(
            opt_cfg, params, opt_state, grads)
        commit = jnp.isfinite(loss)
        commit &= jnp.where(loss_median > 0,
                            loss <= train_cfg.spike_factor * loss_median,
                            True)
        sel = lambda new, old: jax.tree.map(
            lambda n, o: jnp.where(commit, n, o), new, old)
        params = sel(new_params, params)
        opt_state = sel(new_opt, opt_state)
        err_state = sel(new_err, err_state)
        metrics = {**metrics, **opt_metrics, "loss": loss,
                   "committed": commit.astype(jnp.float32)}
        return params, opt_state, err_state, metrics

    return jax.jit(step, donate_argnums=(0, 1, 2))


class Trainer:
    """Checkpointed, fault-tolerant driver around the jitted step."""

    def __init__(self, arch_cfg, train_cfg: TrainConfig, data_cfg: DataConfig,
                 init_key=None, install_signals: bool = False):
        self.arch_cfg = arch_cfg
        self.cfg = train_cfg
        self.data_cfg = data_cfg
        self.ckpt = CheckpointManager(train_cfg.ckpt_dir,
                                      keep=train_cfg.ckpt_keep)
        self.step_fn = make_train_step(arch_cfg, train_cfg)
        key = init_key if init_key is not None else jax.random.PRNGKey(0)
        self.params = api.init(key, arch_cfg)
        self.opt_state = opt_lib.init_state(self.params)
        self.err_state = (compression.init_error_state(self.params)
                          if train_cfg.compress_grads else jnp.zeros((1,)))
        self.start_step = 0
        self.guard = PreemptionGuard(install=install_signals)
        self.loss_history: list[float] = []
        self.total_skips = 0
        self._maybe_resume()

    # -- checkpoint plumbing -------------------------------------------------
    def _state_tree(self):
        return {"params": self.params, "opt": self.opt_state,
                "err": self.err_state}

    def _maybe_resume(self):
        latest = self.ckpt.latest_step()
        if latest is None:
            return
        restored = with_retries(
            lambda: self.ckpt.restore(latest, self._state_tree()))
        self.params = restored["params"]
        self.opt_state = restored["opt"]
        self.err_state = restored["err"]
        self.start_step = latest
        log.info("resumed from step %d", latest)

    def save(self, step: int, blocking: bool = False):
        with_retries(lambda: self.ckpt.save(step, self._state_tree(),
                                            blocking=blocking))

    # -- main loop -------------------------------------------------------------
    def run(self, on_metrics: Optional[Callable[[int, Dict], None]] = None):
        loader = PrefetchingLoader(self.data_cfg, start_step=self.start_step,
                                   q_depth=2)
        history = []
        consecutive_skips = 0
        try:
            step = self.start_step
            while step < self.cfg.total_steps and not self.guard.requested:
                batch = {k: jnp.asarray(v) for k, v in next(loader).items()}
                median = (float(np.median(self.loss_history[-32:]))
                          if len(self.loss_history) >= 16 else 0.0)
                t0 = time.perf_counter()
                self.params, self.opt_state, self.err_state, metrics = (
                    self.step_fn(self.params, self.opt_state, self.err_state,
                                 batch, jnp.float32(median)))
                loss = float(metrics["loss"])
                committed = bool(metrics["committed"] > 0)
                if committed:
                    self.loss_history.append(loss)
                    consecutive_skips = 0
                else:
                    self.total_skips += 1
                    consecutive_skips += 1
                    log.warning("step rejected (loss=%s)", loss)
                    if consecutive_skips > self.cfg.max_consecutive_skips:
                        raise RuntimeError(
                            "too many consecutive rejected steps; "
                            "restore from an earlier checkpoint")
                step += 1
                metrics["step_time_s"] = time.perf_counter() - t0
                history.append((step, loss))
                if on_metrics and step % self.cfg.log_every == 0:
                    on_metrics(step, {k: float(v) for k, v in metrics.items()
                                      if jnp.ndim(v) == 0})
                if step % self.cfg.ckpt_every == 0:
                    self.save(step)
            self.save(step, blocking=True)
            return step, history
        finally:
            loader.close()
            self.ckpt.wait()
