"""Sharded, atomic, async checkpointing with elastic resharding on restore.

Layout (one directory per step):

    <dir>/step_000123.tmp/          (written, fsync'd)
    <dir>/step_000123/              (atomic rename = commit)
        MANIFEST.json               {step, leaf index, shapes, dtypes, crc}
        arr_00000.npy ...           one file per pytree leaf

Restore is mesh-agnostic: leaves are loaded on host and ``device_put`` with
whatever shardings the *new* mesh prescribes — checkpoints written on one
topology restore onto another (elastic scaling / failure recovery).  Async
saves run in a daemon thread; ``wait()`` joins before the next save or exit.
Keeps the newest ``keep`` checkpoints; partial (``.tmp``) directories are
ignored by discovery, so a preempted save can never be resumed from.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- discovery ---------------------------------------------------------
    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "MANIFEST.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: Optional[bool] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if blocking is None:
            blocking = not self.async_save
        if blocking:
            self._write(step, host_tree)
        else:
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree), daemon=True)
            self._thread.start()

    def _write(self, step: int, host_tree):
        name = f"step_{step:09d}"
        tmp = os.path.join(self.directory, name + ".tmp")
        final = os.path.join(self.directory, name)
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(_leaf_paths(host_tree)):
            fname = f"arr_{i:05d}.npy"
            # numpy can't round-trip extension dtypes (bfloat16 etc.) through
            # .npy — store a same-width integer view + the logical dtype
            stored = leaf
            if leaf.dtype.kind not in "biufc":
                stored = leaf.view(f"u{leaf.dtype.itemsize}")
            elif str(leaf.dtype) == "bfloat16":
                stored = leaf.view(np.uint16)
            np.save(os.path.join(tmp, fname), stored)
            manifest["leaves"].append({
                "path": path, "file": fname, "shape": list(leaf.shape),
                "dtype": str(leaf.dtype), "stored_dtype": str(stored.dtype),
                "crc": zlib.crc32(np.ascontiguousarray(stored).tobytes()),
            })
        with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)                      # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ---------------------------------------------------------------
    def restore(self, step: int, like: Any, shardings: Any = None,
                verify_crc: bool = True) -> Any:
        """Restore into the structure of ``like``; optionally reshard.

        ``shardings``: optional pytree of jax.sharding.Sharding matching
        ``like`` (elastic restore onto a different mesh).
        """
        d = os.path.join(self.directory, f"step_{step:09d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            manifest = json.load(f)
        by_path = {e["path"]: e for e in manifest["leaves"]}
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        shard_flat = (jax.tree.leaves(shardings,
                                      is_leaf=lambda x: hasattr(x, "spec"))
                      if shardings is not None else [None] * len(flat))
        out = []
        for (path, leaf), shd in zip(flat, shard_flat):
            key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                           for k in path)
            entry = by_path[key]
            arr = np.load(os.path.join(d, entry["file"]))
            if verify_crc:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != entry["crc"]:
                    raise IOError(f"checkpoint corruption in {key}")
            if entry["dtype"] != entry.get("stored_dtype", entry["dtype"]):
                import ml_dtypes
                logical = np.dtype(getattr(ml_dtypes, entry["dtype"], None)
                                   or entry["dtype"])
                arr = arr.view(logical)
            assert list(arr.shape) == list(leaf.shape), (key, arr.shape, leaf.shape)
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
        return jax.tree.unflatten(treedef, [v for v in out])
