"""Synthetic, deterministic, restartable token pipeline.

Production properties that matter at 1000+ nodes, all present here:

  * **Deterministic addressing**: batch(step, host) is a pure function of
    (seed, step, host) — restart/resume replays identically, and elastic
    re-scaling re-partitions the same global stream.
  * **Host sharding**: each host draws only its slice of the global batch.
  * **Prefetch queue**: a background thread keeps ``Q`` batches ready — the
    cluster-level analogue of the paper's per-PE operand queue (intra-group
    elasticity): compute never waits on the host if the queue is non-empty.
  * **Zero/padding awareness**: a fraction of tokens is PAD (id 0) with a
    loss mask — the value-sparsity hook (zero-value filtering analogue).

The synthetic distribution is Zipf unigrams + copy/induction motifs, so small
models measurably learn (examples/train_lm.py shows loss going down).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import numpy as np

PAD_ID = 0


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    pad_fraction: float = 0.02
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


def _rng_for(cfg: DataConfig, step: int, host: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, host]))


def make_batch(cfg: DataConfig, step: int) -> dict:
    """The (step, host)-addressed batch: {"tokens", "loss_mask"}."""
    rng = _rng_for(cfg, step, cfg.host_id)
    b, s, v = cfg.host_batch, cfg.seq_len, cfg.vocab_size
    # Zipf unigrams over [2, v): ids 0 (pad) and 1 (bos) reserved
    toks = rng.zipf(cfg.zipf_a, size=(b, s))
    toks = 2 + (toks - 1) % (v - 2)
    # plant copy motifs: sequence repeats a short window later (induction)
    if s > 2 * cfg.motif_len + 1:
        n_motifs = max(1, s // (4 * cfg.motif_len))
        for i in range(b):
            for _ in range(n_motifs):
                src = rng.integers(0, s - 2 * cfg.motif_len)
                dst = rng.integers(src + cfg.motif_len, s - cfg.motif_len + 1)
                toks[i, dst:dst + cfg.motif_len] = toks[i, src:src + cfg.motif_len]
    mask = rng.random((b, s)) >= cfg.pad_fraction
    toks = np.where(mask, toks, PAD_ID)
    toks[:, 0] = 1  # bos
    return {"tokens": toks.astype(np.int32), "loss_mask": mask}


class PrefetchingLoader:
    """Background-thread prefetcher with bounded queue depth Q.

    ``loader.stats()`` exposes (produced, consumed, stall_events) so the
    quasi-sync trainer can report input-pipeline pressure.
    """

    def __init__(self, cfg: DataConfig, start_step: int = 0, q_depth: int = 2):
        self.cfg = cfg
        self._q: "queue.Queue[dict]" = queue.Queue(maxsize=max(q_depth, 1))
        self._step = start_step
        self._stop = threading.Event()
        self._stalls = 0
        self._consumed = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = make_batch(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self) -> dict:
        if self._q.empty():
            self._stalls += 1
        out = self._q.get()
        self._consumed += 1
        return out

    def __iter__(self) -> Iterator[dict]:
        return self

    def stats(self):
        return {"consumed": self._consumed, "stall_events": self._stalls,
                "queue_depth": self._q.qsize()}

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
