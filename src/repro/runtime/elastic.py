"""Elastic re-scaling: restore any checkpoint onto a different mesh.

Checkpoints are topology-free (host numpy per leaf); this module pairs them
with fresh partition specs for the *new* mesh so a job preempted on one pod
count resumes on another (growing or shrinking the fleet).
"""

from __future__ import annotations

from typing import Any

import jax

from repro.checkpoint.checkpoint import CheckpointManager
from repro.distributed.sharding import named_shardings


def restore_for_mesh(ckpt: CheckpointManager, step: int, like: Any,
                     mesh, recipe_name: str = "train"):
    """Restore ``like``-structured state, sharded for ``mesh``."""
    shardings = named_shardings(like, recipe_name, mesh)
    return ckpt.restore(step, like, shardings=shardings)


def reshard(tree: Any, mesh, recipe_name: str = "train"):
    """Live-reshard an in-memory state tree onto a new mesh (shrink/grow)."""
    shardings = named_shardings(tree, recipe_name, mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree, shardings,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))
