"""Fault tolerance: preemption-safe saves, NaN/spike step rejection,
bounded retry with exponential backoff, auto-resume.
"""

from __future__ import annotations

import dataclasses
import logging
import signal
import time
from typing import Callable, Optional

import numpy as np
import jax

log = logging.getLogger("repro.runtime")


class PreemptionGuard:
    """SIGTERM/SIGINT => finish the current step, checkpoint, exit cleanly."""

    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:      # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        log.warning("preemption signal %s received; draining", signum)
        self.requested = True

    def restore(self):
        for sig, h in self._prev.items():
            signal.signal(sig, h)


@dataclasses.dataclass
class SpikeGuardConfig:
    window: int = 32            # running-median window
    spike_factor: float = 4.0   # reject loss > factor x median
    max_consecutive_skips: int = 8


class SpikeGuard:
    """Rejects steps whose loss is NaN/Inf or a large spike vs the running
    median (skips the optimizer update — the params/opt state for a rejected
    step are simply not committed)."""

    def __init__(self, cfg: SpikeGuardConfig = SpikeGuardConfig()):
        self.cfg = cfg
        self.history: list[float] = []
        self.consecutive_skips = 0
        self.total_skips = 0

    def should_commit(self, loss: float) -> bool:
        ok = bool(np.isfinite(loss))
        if ok and len(self.history) >= self.cfg.window // 2:
            med = float(np.median(self.history[-self.cfg.window:]))
            ok = loss <= self.cfg.spike_factor * max(med, 1e-9)
        if ok:
            self.history.append(float(loss))
            self.consecutive_skips = 0
            return True
        self.consecutive_skips += 1
        self.total_skips += 1
        if self.consecutive_skips > self.cfg.max_consecutive_skips:
            raise RuntimeError(
                f"{self.consecutive_skips} consecutive rejected steps — "
                "training has diverged; restore from checkpoint")
        log.warning("rejecting step with loss=%s (skip #%d)", loss,
                    self.total_skips)
        return False


def with_retries(fn: Callable, *, max_attempts: int = 3, base_delay: float = 0.5,
                 retriable=(IOError, OSError), on_retry: Optional[Callable] = None):
    """Run ``fn`` with exponential backoff on transient (I/O-class) failures —
    wraps checkpoint writes / data fetches against flaky storage."""
    for attempt in range(max_attempts):
        try:
            return fn()
        except retriable as e:
            if attempt == max_attempts - 1:
                raise
            delay = base_delay * (2 ** attempt)
            log.warning("attempt %d failed (%s); retrying in %.1fs",
                        attempt + 1, e, delay)
            if on_retry is not None:
                on_retry(attempt, e)
            time.sleep(delay)
