"""Request lifecycle + bounded admission queue for the serving subsystem.

Each request walks a strict state machine

    WAITING -> PREFILL -> DECODE -> DONE

(PREFILL may jump straight to DONE when the first sampled token already
terminates the request).  Three extra terminal states are reachable from
every non-terminal state — CANCELLED (explicit ``engine.cancel`` or chaos
injection), TIMED_OUT (per-request ``deadline_s`` / ``ttft_deadline_s``
wall-clock budgets), FAILED (NaN guard or exhausted recovery) — see
``docs/robustness.md``.  The ``RequestQueue`` is the serving analogue of the
quasi-sync array's per-PE operand queue: a bounded FIFO that decouples
arrivals from the lock-step decode batch.  Submissions beyond ``max_waiting``
are rejected (admission control) rather than growing latency unboundedly.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import List, Optional

import numpy as np

_REQUEST_IDS = itertools.count()


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    DONE = "done"
    CANCELLED = "cancelled"
    TIMED_OUT = "timed_out"
    FAILED = "failed"


#: terminal states a request may be evicted into from any live state
_TERMINAL = {RequestState.DONE, RequestState.CANCELLED,
             RequestState.TIMED_OUT, RequestState.FAILED}

_ALLOWED = {
    RequestState.WAITING: {RequestState.PREFILL} | _TERMINAL,
    # PREFILL -> WAITING is the admission-failure rollback: a fault while
    # installing the group requeues the request for a token-exact replay
    RequestState.PREFILL: {RequestState.DECODE, RequestState.WAITING}
                          | _TERMINAL,
    # DECODE -> WAITING is preemption: the paged backend reclaims the
    # request's blocks and requeues it for a token-exact replay
    RequestState.DECODE: {RequestState.WAITING} | _TERMINAL,
    RequestState.DONE: set(),
    RequestState.CANCELLED: set(),
    RequestState.TIMED_OUT: set(),
    RequestState.FAILED: set(),
}

#: finish_reason -> terminal state (anything else, e.g. "eos" / "length"
#: / "rejected", lands in DONE)
_REASON_STATE = {
    "cancelled": RequestState.CANCELLED,
    "timeout": RequestState.TIMED_OUT,
    "failed": RequestState.FAILED,
}


@dataclasses.dataclass(eq=False)
class Request:
    """One generation request plus its lifecycle bookkeeping.

    ``eq=False``: requests compare (and hash) by IDENTITY.  The generated
    field-wise ``__eq__`` would compare numpy prompts elementwise and
    break every ``in`` / ``remove`` the queues and sweeps rely on.

    Times are in scheduler-clock units (decode steps) so that runs are
    deterministic and replayable; wall-clock throughput is measured by the
    engine separately.
    """

    prompt: np.ndarray                       # (S,) int32 prompt tokens
    max_new_tokens: int = 32
    arrival_time: float = 0.0
    request_id: int = dataclasses.field(
        default_factory=lambda: next(_REQUEST_IDS))
    state: RequestState = RequestState.WAITING
    slot: Optional[int] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    admitted_at: Optional[float] = None      # prefill (admission sync) time
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    # "eos" | "length" | "rejected" | "cancelled" | "timeout" | "failed"
    finish_reason: Optional[str] = None
    # wall-clock budgets, measured from wall_submitted_at (None = no
    # budget): total completion deadline, and a tighter first-token
    # deadline that only applies while the request is still waiting
    deadline_s: Optional[float] = None
    ttft_deadline_s: Optional[float] = None
    # SLO priority class (``scheduler.SLOClass`` name).  Under the
    # scheduler's "slo" policy higher-priority classes are admitted first
    # and their TTFT/ITL targets steer the lead window; the default FIFO
    # policy ignores it entirely.
    slo_class: str = "default"
    # tokens generated before a preemption, re-emitted verbatim on replay
    # (the engine forces them over the resampled ones, so a preempted
    # request finishes with exactly the tokens it would have produced)
    replay: List[int] = dataclasses.field(default_factory=list)
    n_preemptions: int = 0
    # wall-clock trace (time.perf_counter): when the request entered the
    # waiting queue and when each token was emitted — the step-clock fields
    # above stay the deterministic/replayable record, these feed the
    # ServeReport latency percentiles (TTFT / inter-token)
    wall_submitted_at: Optional[float] = None
    wall_admitted_at: Optional[float] = None
    wall_token_times: List[float] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.arrival_time

    @property
    def latency(self) -> Optional[float]:
        if self.finished_at is None:
            return None
        return self.finished_at - self.arrival_time

    def transition(self, new_state: RequestState):
        if new_state not in _ALLOWED[self.state]:
            raise ValueError(
                f"request {self.request_id}: illegal transition "
                f"{self.state.value} -> {new_state.value}")
        self.state = new_state

    @property
    def is_terminal(self) -> bool:
        return self.state in _TERMINAL

    def finish(self, now: float, reason: str):
        self.transition(_REASON_STATE.get(reason, RequestState.DONE))
        self.finished_at = now
        self.finish_reason = reason
        self.slot = None

    def preempt(self):
        """Back to WAITING with generated-so-far tokens queued for replay
        (prepended to any replay tail a double preemption left behind)."""
        self.transition(RequestState.WAITING)
        self.replay = self.tokens + self.replay
        self.tokens = []
        self.slot = None
        self.n_preemptions += 1


class RequestQueue:
    """Bounded FIFO of WAITING requests (admission control at submit).

    ``on_reject`` is an optional callback invoked with each rejected
    request — the serve loop uses it to emit a ``reject`` record into the
    telemetry stream from the ONE central rejection path (both the
    capacity rejection in ``submit`` and the engine's explicit
    cannot-ever-fit rejection funnel through :meth:`reject`)."""

    def __init__(self, max_waiting: Optional[int] = None, on_reject=None):
        if max_waiting is not None and max_waiting < 1:
            raise ValueError("max_waiting must be >= 1 (or None)")
        self.max_waiting = max_waiting
        self.on_reject = on_reject
        self._waiting: List[Request] = []
        self.n_rejected = 0

    def __len__(self) -> int:
        return len(self._waiting)

    def peek(self) -> List[Request]:
        """The waiting requests in FIFO order (not dequeued) — the
        scheduler sizes its admissible prefix against this."""
        return list(self._waiting)

    def push_front(self, request: Request):
        """Requeue a preempted request at the head (it was already admitted
        once; it does not count against ``max_waiting`` again)."""
        if request.state is not RequestState.WAITING:
            raise ValueError(
                f"cannot requeue request in state {request.state}")
        self._waiting.insert(0, request)

    def remove(self, request: Request) -> bool:
        """Drop one waiting request (cancellation / deadline sweep);
        returns False when it is not queued."""
        try:
            self._waiting.remove(request)
            return True
        except ValueError:
            return False

    def reject(self, request: Request, now: float):
        """Mark a request rejected (admission control) and count it."""
        self.n_rejected += 1
        request.finish(now, "rejected")
        if self.on_reject is not None:
            self.on_reject(request)

    def submit(self, request: Request, now: float = 0.0) -> bool:
        """Enqueue; returns False (and marks the request rejected) when the
        queue is at capacity."""
        if request.state is not RequestState.WAITING:
            raise ValueError(f"cannot submit request in state {request.state}")
        if self.max_waiting is not None and len(self._waiting) >= self.max_waiting:
            self.reject(request, now)
            return False
        self._waiting.append(request)
        return True

    def pop(self, k: int) -> List[Request]:
        """Dequeue up to ``k`` requests in FIFO order."""
        popped, self._waiting = self._waiting[:k], self._waiting[k:]
        return popped

    def pop_selected(self, requests: List[Request]) -> List[Request]:
        """Dequeue a specific set of waiting requests (identity match),
        preserving the caller's order — the SLO scheduler admits a
        priority-ordered subset instead of the FIFO prefix.  Requests not
        currently queued raise (a scheduling bug, not a race: the planner
        selects from ``peek()`` under the same loop iteration)."""
        for req in requests:
            if not self.remove(req):
                raise ValueError(
                    f"request {req.request_id} is not waiting; cannot "
                    f"admit it")
        return list(requests)
