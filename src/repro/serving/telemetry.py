"""Serving observability: per-step metrics stream, span tracing, reduction.

The serving stack's correctness story is token identity; its *performance*
story is the paper's quasi-synchronous occupancy claim — MAC/slot
utilization under fluctuating per-op work.  Until this module, that claim
was only visible as end-of-run aggregates (``ServeReport``); nobody could
see where one step's time went or whether a commit regressed it.  Three
pieces fix that:

  * :class:`MetricsLogger` — a dependency-free JSONL sink: ONE
    schema-versioned record per prefill / decode / verify step (wall time,
    per-phase durations, committed tokens, acceptance, active slots,
    occupancy/divergence, block-pool gauges, host<->device bytes) plus
    ``preempt`` / ``reject`` lifecycle records.  The stream is the raw
    material for any downstream dashboard — and for the CI regression gate
    (``benchmarks/compare.py``).
  * :class:`Tracer` — Chrome-trace-event JSON ("X" complete events) around
    admission, prefill, draft, verify, commit, preemption, and block-pool
    operations.  The file loads directly in https://ui.perfetto.dev (or
    ``chrome://tracing``).  With ``annotate_device=True`` every span also
    enters a ``jax.profiler.TraceAnnotation`` so host spans line up with
    device traces captured via ``profile_dir``.
  * :func:`reduce_stream` — the PURE reduction from step records to the
    ``ServeReport`` aggregates.  ``ServeLoop.report()`` calls exactly this
    over exactly the records it emitted, so the aggregate counters and the
    metrics stream can never disagree (pinned byte-equal by
    ``tests/test_telemetry.py``).

The :class:`Telemetry` handle bundles the sinks and rides
``ServeConfig.telemetry`` through the engine into the scheduler, cache
managers, block pool, drafters, and executors.  Disabled (the default —
no paths set) it is a strict no-op: spans are a shared null context
manager, ``emit`` writes nothing, and serve() outputs are token-identical
to a run without the handle.  The in-memory step stream lives in the
``ServeLoop`` (not here), so a ``Telemetry`` object can be shared across
serve calls; each run's records append to the same JSONL file.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

import numpy as np

SCHEMA_VERSION = 1

#: Required keys per record kind — the golden schema pinned by
#: ``tests/test_telemetry.py``.  Extending a record is fine (consumers
#: must ignore unknown keys); removing or renaming one of these is a
#: breaking change and must bump :data:`SCHEMA_VERSION`.
_STEP_KEYS = {"schema", "kind", "ts_s", "step", "wall_s", "phases",
              "active_slots", "committed_tokens", "h2d_bytes", "d2h_bytes",
              "blocks_in_use", "prefix_hit_blocks", "cow_blocks",
              "peak_blocks_in_use"}
STEP_SCHEMA: Dict[str, set] = {
    "run": {"schema", "kind", "ts_s", "cache_backend", "n_slots", "draft",
            "temperature", "mesh_shape", "block_size"},
    "prefill": _STEP_KEYS | {"group_size", "pad_to", "prompt_tokens",
                             "new_sync"},
    "decode": _STEP_KEYS | {"n_slots", "occupancy", "divergence"},
    # ``chunk_tokens`` (additive): prompt tokens a chunked prefill fed
    # through this verify step (0 on pure speculative steps)
    "verify": _STEP_KEYS | {"n_slots", "occupancy", "divergence",
                            "drafted_tokens", "accepted_tokens",
                            "chunk_tokens"},
    "preempt": {"schema", "kind", "ts_s", "step", "slot", "request_id",
                "discarded_tokens"},
    "reject": {"schema", "kind", "ts_s", "step", "request_id"},
    # robustness records (additive, schema stays v1): lifecycle evictions,
    # fault-injection / fault-detection events, and recovery transitions —
    # see docs/robustness.md for the taxonomy
    "cancel": {"schema", "kind", "ts_s", "step", "request_id", "where"},
    "timeout": {"schema", "kind", "ts_s", "step", "request_id", "where",
                "deadline"},
    "fault": {"schema", "kind", "ts_s", "step", "site"},
    "retry": {"schema", "kind", "ts_s", "step", "site", "attempt"},
    "degrade": {"schema", "kind", "ts_s", "step", "action"},
    "recover": {"schema", "kind", "ts_s", "step", "n_requeued"},
    # hardware-cost observability (additive, schema stays v1): per sampled
    # step the SparsityProbe prices measured activation/weight bit sparsity
    # through the paper's cost models — see docs/observability.md
    "hw_estimate": {"schema", "kind", "ts_s", "step", "phase", "n_layers",
                    "act_bit_sparsity", "act_value_sparsity",
                    "weight_bit_sparsity", "per_layer_act_bit_sparsity",
                    "per_layer_act_value_sparsity", "cycles",
                    "array_utilization", "array_cycles_per_step",
                    "mac_energy_pj"},
    # per-request lifecycle summary (additive, schema stays v1): one record
    # per submitted request, emitted as the loop drains.  ``queue_wait_s``
    # is wall time from queue entry to admission, ``ttft_wall_s`` from
    # queue entry to first token, ``itl_wall_s`` the request's pairwise
    # inter-token gaps — the raw samples behind the report's per-SLO-class
    # percentiles, so the file reduction reproduces them exactly
    "request": {"schema", "kind", "ts_s", "step", "request_id", "slo_class",
                "finish_reason", "n_tokens", "queue_wait_s", "ttft_wall_s",
                "itl_wall_s"},
}


def percentiles(samples, qs=(50, 90, 99)) -> Optional[Dict[str, float]]:
    """{p50, p90, p99} (or custom ``qs``) of a sample set, or None when no
    sample exists.  THE percentile rule for the whole repo: the engine's
    ttft/itl wall-clock report fields and every benchmark summary go
    through this one helper instead of hand-rolling the math."""
    xs = np.asarray([s for s in samples if s is not None], np.float64)
    if xs.size == 0:
        return None
    return {f"p{q}": float(np.percentile(xs, q)) for q in qs}


class _NullSpan:
    """Shared do-nothing context manager: the disabled-telemetry span.
    Identity-pinned by tests — the hot loop must not allocate per span
    when tracing is off."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class MetricsLogger:
    """Append-only JSONL sink: one line per record, flushed per write so a
    crashed run still leaves a readable stream.  Dependency-free by
    design (the ROADMAP's 'wandblog in spirit, local JSONL sink').

    Also a context manager: ``with MetricsLogger(p) as m: ...`` flushes
    and closes on exit — including on an exception mid-serve, so a crash
    never truncates the stream mid-record (each ``log`` writes one full
    line and flushes before returning)."""

    def __init__(self, path: str):
        self.path = path
        d = os.path.dirname(os.path.abspath(path))
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "a", encoding="utf-8")

    def log(self, record: dict):
        self._f.write(json.dumps(record, default=float) + "\n")
        self._f.flush()

    def close(self):
        if not self._f.closed:
            self._f.flush()
            self._f.close()

    def __enter__(self) -> "MetricsLogger":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class Tracer:
    """Chrome-trace-event recorder (complete "X" events, µs timestamps).

    Spans nest by construction: events are emitted on one host thread with
    monotonic ``time.perf_counter`` stamps, so a child span is always fully
    contained in its parent — the property ``tests/test_telemetry.py``
    checks on the written file.  ``write()`` dumps the standard
    ``{"traceEvents": [...]}`` wrapper that perfetto / chrome://tracing
    load directly.
    """

    def __init__(self, *, annotate_device: bool = False):
        self.events: List[dict] = []
        self.annotate_device = annotate_device
        self._t0 = time.perf_counter()
        self._pid = os.getpid()
        self.events.append({
            "name": "process_name", "ph": "M", "pid": self._pid, "tid": 0,
            "args": {"name": "repro.serving"},
        })

    def _now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, cat: str = "serving", **args):
        ann = None
        if self.annotate_device:
            try:
                import jax
                ann = jax.profiler.TraceAnnotation(name)
                ann.__enter__()
            except Exception:   # profiler unavailable: host span still works
                ann = None
        t0 = self._now_us()
        try:
            yield self
        finally:
            t1 = self._now_us()
            if ann is not None:
                ann.__exit__(None, None, None)
            self.events.append({
                "name": name, "cat": cat, "ph": "X", "ts": t0,
                "dur": t1 - t0, "pid": self._pid, "tid": 0,
                "args": {k: _jsonable(v) for k, v in args.items()},
            })

    def instant(self, name: str, cat: str = "serving", **args):
        self.events.append({
            "name": name, "cat": cat, "ph": "i", "s": "t",
            "ts": self._now_us(), "pid": self._pid, "tid": 0,
            "args": {k: _jsonable(v) for k, v in args.items()},
        })

    def counter(self, name: str, **values):
        """Chrome-trace counter ("C") sample: perfetto renders one stacked
        counter track named ``name`` with a series per kwarg."""
        self.events.append({
            "name": name, "ph": "C", "ts": self._now_us(),
            "pid": self._pid, "tid": 0,
            "args": {k: _jsonable(v) for k, v in values.items()},
        })

    def write(self, path: str):
        d = os.path.dirname(os.path.abspath(path))
        if d:
            os.makedirs(d, exist_ok=True)
        with open(path, "w", encoding="utf-8") as f:
            json.dump({"traceEvents": self.events,
                       "displayTimeUnit": "ms"}, f, default=float)


def _jsonable(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    return v


class Telemetry:
    """The serving observability handle: metrics + trace + profiler sinks.

    Construct with no arguments for the disabled no-op handle (what the
    engine builds when ``ServeConfig.telemetry`` is None).  ``span`` /
    ``instant`` / ``emit`` are safe to call unconditionally — disabled
    they cost a dict lookup, not an allocation.  ``counters`` tracks
    cumulative host<->device byte movement (the loop snapshots deltas per
    step record); counting stays on even when sinks are off so the step
    stream is identical either way.
    """

    def __init__(self, metrics_path: Optional[str] = None,
                 trace_path: Optional[str] = None, *,
                 profile_dir: Optional[str] = None,
                 annotate_device: bool = False):
        self.metrics = MetricsLogger(metrics_path) if metrics_path else None
        self.trace_path = trace_path
        self.tracer = (Tracer(annotate_device=annotate_device)
                       if (trace_path or annotate_device) else None)
        self.profile_dir = profile_dir
        self.counters: Dict[str, int] = {"h2d_bytes": 0, "d2h_bytes": 0}
        self._profiling = False

    @property
    def enabled(self) -> bool:
        return (self.metrics is not None or self.tracer is not None
                or self.profile_dir is not None)

    # -- sinks ---------------------------------------------------------------

    def span(self, name: str, **args):
        if self.tracer is None:
            return NULL_SPAN
        return self.tracer.span(name, **args)

    def instant(self, name: str, **args):
        if self.tracer is not None:
            self.tracer.instant(name, **args)

    def counter(self, name: str, **values):
        if self.tracer is not None:
            self.tracer.counter(name, **values)

    def emit(self, record: dict):
        if self.metrics is not None:
            self.metrics.log(record)

    def count(self, key: str, n) -> None:
        self.counters[key] = self.counters.get(key, 0) + int(n)

    # -- device profiler hooks ----------------------------------------------

    def start_profile(self):
        """Start a ``jax.profiler`` trace into ``profile_dir`` (no-op when
        unset or the profiler is unavailable)."""
        if self.profile_dir is None or self._profiling:
            return
        try:
            import jax
            jax.profiler.start_trace(self.profile_dir)
            self._profiling = True
        except Exception:
            self._profiling = False

    def stop_profile(self):
        if not self._profiling:
            return
        try:
            import jax
            jax.profiler.stop_trace()
        except Exception:
            pass
        self._profiling = False

    def flush(self):
        if self.tracer is not None and self.trace_path:
            self.tracer.write(self.trace_path)

    def close(self):
        self.stop_profile()
        self.flush()
        if self.metrics is not None:
            self.metrics.close()

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


#: Shared disabled handle for components constructed without one (direct
#: cache-manager / executor construction in tests).  Its counters are a
#: write-only sink nothing reads.
NULL_TELEMETRY = Telemetry()


# ---------------------------------------------------------------------------
# Stream reduction: step records -> ServeReport aggregates
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StreamSummary:
    """Aggregates of one serve call's step-record stream.  Every field maps
    1:1 onto a ``ServeReport`` counter; ``ServeLoop.report()`` is a pure
    function of this object plus the per-request results."""

    prefill_s: float = 0.0            # sum of prefill dispatch walls
    decode_s: float = 0.0             # sum of decode/verify dispatch walls
    steps: int = 0                    # decode + verify records
    n_syncs: int = 0                  # prefill records opening a sync
    total_new_tokens: int = 0         # emitted - discarded-at-preemption
    committed_decode_tokens: int = 0  # decode/verify commits only
    slot_utilization: float = 0.0
    committed_tokens_per_step: float = 0.0
    max_divergence: int = 0
    n_preemptions: int = 0
    n_rejected: int = 0
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    peak_active_slots: int = 0
    prefix_hit_blocks: int = 0
    cow_blocks: int = 0
    peak_blocks_in_use: int = 0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    # robustness counters (cancel / timeout / fault / retry / degrade /
    # recover records)
    n_cancelled: int = 0
    n_timed_out: int = 0
    n_faults: int = 0                 # fault records (injected + detected)
    n_injected_faults: int = 0        # fault records with injected=True
    n_retries: int = 0
    n_degrades: int = 0
    n_recoveries: int = 0
    # hw_estimate records (sparsity-probe samples): order-preserving sums;
    # the report divides by n_hw_samples for the measured-traffic means
    n_hw_samples: int = 0
    hw_act_bit_sparsity: float = 0.0
    hw_act_value_sparsity: float = 0.0
    hw_weight_bit_sparsity: float = 0.0
    hw_array_utilization: float = 0.0
    hw_cycles: Dict[str, float] = dataclasses.field(default_factory=dict)
    hw_mac_energy_pj: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # chunked prefill: prompt tokens ingested through multi-token chunk
    # steps (distinct from committed/generated tokens)
    chunk_tokens: int = 0
    # per-request lifecycle records: the wall-clock samples behind the
    # report's queue-wait and per-SLO-class latency percentiles (floats
    # round-trip JSON exactly, so file and live reductions agree)
    n_requests: int = 0
    queue_wait_samples: List[float] = dataclasses.field(default_factory=list)
    slo_ttft_samples: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict)
    slo_itl_samples: Dict[str, List[float]] = dataclasses.field(
        default_factory=dict)


def reduce_stream(records) -> StreamSummary:
    """Fold a step-record stream (dicts, in emission order) into the
    ``ServeReport`` aggregates.  Accepts both live records and records
    parsed back from the JSONL file — the float math is order-preserving
    sums of the recorded values, so the two reductions are byte-equal
    (JSON round-trips binary64 exactly)."""
    s = StreamSummary()
    occupancy_sum = 0.0
    emitted = 0
    discarded = 0
    for r in records:
        kind = r.get("kind")
        if kind == "prefill":
            s.prefill_s += r["phases"]["dispatch_s"]
            if r["new_sync"]:
                s.n_syncs += 1
            emitted += r["committed_tokens"]
        elif kind in ("decode", "verify"):
            s.steps += 1
            s.decode_s += r["phases"]["dispatch_s"]
            occupancy_sum += r["occupancy"]
            s.committed_decode_tokens += r["committed_tokens"]
            emitted += r["committed_tokens"]
            s.max_divergence = max(s.max_divergence, int(r["divergence"]))
            s.peak_active_slots = max(s.peak_active_slots,
                                      int(r["active_slots"]))
            if kind == "verify":
                s.drafted_tokens += int(r["drafted_tokens"])
                s.accepted_tokens += int(r["accepted_tokens"])
                s.chunk_tokens += int(r.get("chunk_tokens", 0))
        elif kind == "preempt":
            s.n_preemptions += 1
            discarded += int(r["discarded_tokens"])
            continue
        elif kind == "reject":
            s.n_rejected += 1
            continue
        elif kind == "cancel":
            s.n_cancelled += 1
            continue
        elif kind == "timeout":
            s.n_timed_out += 1
            continue
        elif kind == "fault":
            s.n_faults += 1
            if r.get("injected"):
                s.n_injected_faults += 1
            continue
        elif kind == "retry":
            s.n_retries += 1
            continue
        elif kind == "degrade":
            s.n_degrades += 1
            continue
        elif kind == "recover":
            s.n_recoveries += 1
            continue
        elif kind == "request":
            s.n_requests += 1
            cls = str(r["slo_class"])
            if r["queue_wait_s"] is not None:
                s.queue_wait_samples.append(float(r["queue_wait_s"]))
            if r["ttft_wall_s"] is not None:
                s.slo_ttft_samples.setdefault(cls, []).append(
                    float(r["ttft_wall_s"]))
            if r["itl_wall_s"]:
                s.slo_itl_samples.setdefault(cls, []).extend(
                    float(v) for v in r["itl_wall_s"])
            continue
        elif kind == "hw_estimate":
            s.n_hw_samples += 1
            s.hw_act_bit_sparsity += r["act_bit_sparsity"]
            s.hw_act_value_sparsity += r["act_value_sparsity"]
            s.hw_weight_bit_sparsity += r["weight_bit_sparsity"]
            s.hw_array_utilization += r["array_utilization"]
            for k, v in r["cycles"].items():
                s.hw_cycles[k] = s.hw_cycles.get(k, 0.0) + v
            for k, v in r["mac_energy_pj"].items():
                s.hw_mac_energy_pj[k] = s.hw_mac_energy_pj.get(k, 0.0) + v
            continue
        else:
            continue
        # pool gauges are cumulative snapshots; max == final (monotone)
        s.prefix_hit_blocks = max(s.prefix_hit_blocks,
                                  int(r["prefix_hit_blocks"]))
        s.cow_blocks = max(s.cow_blocks, int(r["cow_blocks"]))
        s.peak_blocks_in_use = max(s.peak_blocks_in_use,
                                   int(r["peak_blocks_in_use"]))
        s.h2d_bytes += int(r["h2d_bytes"])
        s.d2h_bytes += int(r["d2h_bytes"])
    s.total_new_tokens = emitted - discarded
    if s.steps:
        s.slot_utilization = occupancy_sum / s.steps
        s.committed_tokens_per_step = s.committed_decode_tokens / s.steps
    return s


def read_jsonl(path: str) -> List[dict]:
    """Parse a metrics JSONL file back into the record stream."""
    out = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
