"""Minimal blocking HTTP/SSE client for the front door (stdlib sockets).

Tests and benchmarks drive the server through real TCP connections with
this client instead of mocking the transport, so the disconnect path —
``disconnect_after=k`` hard-closes the socket after the k-th token event
— exercises exactly what a flaky client does to the server.
"""

from __future__ import annotations

import json
import socket
import struct
from typing import Callable, List, Optional


class FrontDoorClient:
    def __init__(self, host: str, port: int, timeout_s: float = 60.0):
        self.host = host
        self.port = port
        self.timeout_s = timeout_s

    # -- transport ----------------------------------------------------------

    def _connect(self) -> socket.socket:
        sock = socket.create_connection((self.host, self.port),
                                        timeout=self.timeout_s)
        return sock

    def _send(self, sock: socket.socket, method: str, path: str,
              body: bytes = b""):
        head = (f"{method} {path} HTTP/1.1\r\n"
                f"Host: {self.host}:{self.port}\r\n"
                f"Content-Length: {len(body)}\r\n"
                f"Connection: close\r\n\r\n").encode()
        sock.sendall(head + body)

    @staticmethod
    def _read_head(sock: socket.socket):
        """Read up to the end of the header block; returns (status_line,
        leftover-bytes-already-read-past-the-headers)."""
        buf = b""
        while b"\r\n\r\n" not in buf:
            chunk = sock.recv(4096)
            if not chunk:
                raise ConnectionError("server closed during headers")
            buf += chunk
        head, rest = buf.split(b"\r\n\r\n", 1)
        status = head.split(b"\r\n", 1)[0].decode("latin-1")
        return status, rest

    @staticmethod
    def _read_all(sock: socket.socket, rest: bytes) -> bytes:
        chunks = [rest]
        while True:
            chunk = sock.recv(4096)
            if not chunk:
                return b"".join(chunks)
            chunks.append(chunk)

    def _request_json(self, method: str, path: str, obj=None) -> dict:
        body = b"" if obj is None else json.dumps(obj).encode()
        with self._connect() as sock:
            self._send(sock, method, path, body)
            status, rest = self._read_head(sock)
            payload = self._read_all(sock, rest)
        out = json.loads(payload.decode()) if payload else {}
        if " 200 " not in status + " ":
            detail = out.get("error", repr(payload))
            raise RuntimeError(f"{status}: {detail}")
        return out

    # -- API ----------------------------------------------------------------

    def healthz(self) -> dict:
        return self._request_json("GET", "/healthz")

    def stats(self) -> dict:
        return self._request_json("GET", "/v1/stats")

    def generate(self, prompt, *, max_new_tokens: int = 16,
                 slo_class: str = "default", stream: bool = False,
                 deadline_s: Optional[float] = None,
                 ttft_deadline_s: Optional[float] = None,
                 disconnect_after: Optional[int] = None,
                 on_token: Optional[Callable[[int, int], None]] = None
                 ) -> dict:
        """One generation round trip.

        Returns ``{"tokens": [...], "finish_reason": ..., "request_id":
        ..., "replica": ..., "disconnected": bool}``.  With ``stream``
        the tokens arrive as SSE events (``on_token`` observes each);
        ``disconnect_after=k`` (implies ``stream``) hard-closes the
        socket after the k-th token event — the returned dict then holds
        the partial stream and ``disconnected=True``."""
        stream = stream or disconnect_after is not None
        body = {"prompt": [int(t) for t in prompt],
                "max_new_tokens": int(max_new_tokens),
                "slo_class": slo_class, "stream": stream,
                "deadline_s": deadline_s,
                "ttft_deadline_s": ttft_deadline_s}
        if not stream:
            out = self._request_json("POST", "/v1/generate", body)
            out["disconnected"] = False
            return out

        tokens: List[int] = []
        result = {"tokens": tokens, "finish_reason": None,
                  "request_id": None, "replica": None,
                  "disconnected": False}
        sock = self._connect()
        try:
            self._send(sock, "POST", "/v1/generate",
                       json.dumps(body).encode())
            status, buf = self._read_head(sock)
            if " 200 " not in status + " ":
                payload = self._read_all(sock, buf)
                raise RuntimeError(f"{status}: {payload!r}")
            while True:
                while b"\n\n" in buf:
                    raw, buf = buf.split(b"\n\n", 1)
                    if not raw.startswith(b"data: "):
                        continue
                    event = json.loads(raw[len(b"data: "):].decode())
                    result["request_id"] = event.get(
                        "request_id", result["request_id"])
                    result["replica"] = event.get(
                        "replica", result["replica"])
                    if event.get("done"):
                        result["finish_reason"] = event["finish_reason"]
                        return result
                    tokens.append(int(event["token"]))
                    if on_token is not None:
                        on_token(event["token"], event["index"])
                    if (disconnect_after is not None
                            and len(tokens) >= disconnect_after):
                        # hard hangup mid-stream: reset rather than
                        # FIN-drain, like a crashed client
                        sock.setsockopt(socket.SOL_SOCKET,
                                        socket.SO_LINGER,
                                        struct.pack("ii", 1, 0))
                        result["disconnected"] = True
                        return result
                chunk = sock.recv(4096)
                if not chunk:
                    # server closed without a done event (e.g. it saw our
                    # own earlier hangup); report what we have
                    result["disconnected"] = True
                    return result
                buf += chunk
        finally:
            sock.close()
