"""Async front door: streaming HTTP server, multi-replica router, and
the client that drives them.  See docs/serving.md ("Front door")."""

from repro.serving.frontdoor.client import FrontDoorClient
from repro.serving.frontdoor.replica import Replica, RequestHandle
from repro.serving.frontdoor.router import POLICIES, Router
from repro.serving.frontdoor.server import (FrontDoor, FrontDoorServer,
                                            HttpError)

__all__ = [
    "FrontDoor",
    "FrontDoorClient",
    "FrontDoorServer",
    "HttpError",
    "POLICIES",
    "Replica",
    "RequestHandle",
    "Router",
]
