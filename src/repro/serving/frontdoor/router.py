"""Multi-replica request router.

Policies:

``"affinity"`` (default)
    Prefix-affinity with load spill: the routing key is a hash of the
    prompt's LEADING blocks (``affinity_blocks * block_size`` tokens —
    the same granularity the paged ``BlockPool`` deduplicates at), so
    requests sharing a system prompt land on the replica whose prefix
    trie already holds those pages and admit by reference instead of
    recomputing prefill KV.  A key's home replica is sticky (LRU-capped
    map); when the home's queue depth exceeds the lightest replica's by
    more than ``max_imbalance`` the request SPILLS to the least-loaded
    replica without re-homing — transient hot spots shed load, the
    prefix home (and its cached pages) stays put.

``"least_loaded"``
    Smallest queue depth, ties broken by the modeled cost hint
    (``cost_hint_cycles_per_token`` from the hw_estimate probe stream)
    then name — the first step toward cost-aware admission.

``"round_robin"`` / ``"random"``
    Baselines (``random`` is seeded — benchmarks stay reproducible).
"""

from __future__ import annotations

import collections
import hashlib
import random
from typing import List, Optional, Sequence

import numpy as np

from repro.serving.frontdoor.replica import Replica

POLICIES = ("affinity", "least_loaded", "round_robin", "random")


class Router:
    def __init__(self, replicas: Sequence[Replica], *,
                 policy: str = "affinity", affinity_blocks: int = 2,
                 max_imbalance: int = 4, max_keys: int = 4096,
                 seed: int = 0):
        if not replicas:
            raise ValueError("router needs at least one replica")
        if policy not in POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"expected one of {POLICIES}")
        if affinity_blocks < 1:
            raise ValueError("affinity_blocks must be >= 1")
        self.replicas: List[Replica] = list(replicas)
        self.policy = policy
        self.affinity_blocks = affinity_blocks
        self.max_imbalance = max_imbalance
        self.max_keys = max_keys
        self._rng = random.Random(seed)
        self._rr = 0
        # affinity key -> replica index, LRU-evicted past max_keys (a
        # dropped key just re-homes on its next request)
        self._home: "collections.OrderedDict[str, int]" = (
            collections.OrderedDict())
        self.n_spills = 0

    # -- policy -------------------------------------------------------------

    def _key(self, prompt) -> str:
        n = self.affinity_blocks * self.replicas[0].block_size
        head = np.asarray(prompt, np.int32).reshape(-1)[:n]
        return hashlib.sha1(head.tobytes()).hexdigest()

    def _depths(self) -> List[int]:
        return [r.stats()["queue_depth"] for r in self.replicas]

    def _least_loaded(self) -> int:
        ranked = []
        for i, r in enumerate(self.replicas):
            s = r.stats()
            ranked.append((s["queue_depth"], s["cost_hint_cycles_per_token"],
                           s["name"], i))
        return min(ranked)[3]

    def pick(self, prompt) -> Replica:
        """Choose the replica for one prompt (pure routing decision; the
        caller submits to it)."""
        if self.policy == "round_robin":
            i = self._rr % len(self.replicas)
            self._rr += 1
            return self.replicas[i]
        if self.policy == "random":
            return self.replicas[self._rng.randrange(len(self.replicas))]
        if self.policy == "least_loaded":
            return self.replicas[self._least_loaded()]
        # affinity
        key = self._key(prompt)
        home = self._home.get(key)
        if home is None:
            home = self._least_loaded()
            self._home[key] = home
            while len(self._home) > self.max_keys:
                self._home.popitem(last=False)
        else:
            self._home.move_to_end(key)
        depths = self._depths()
        if depths[home] - min(depths) > self.max_imbalance:
            self.n_spills += 1
            return self.replicas[self._least_loaded()]
        return self.replicas[home]

    def submit(self, request, on_token=None, on_finish=None):
        """Route + submit in one call; returns ``(replica, request_id)``."""
        replica = self.pick(request.prompt)
        rid = replica.submit(request, on_token=on_token,
                             on_finish=on_finish)
        return replica, rid

    # -- observability ------------------------------------------------------

    def stats(self) -> dict:
        return {"policy": self.policy,
                "n_spills": int(self.n_spills),
                "n_affinity_keys": len(self._home),
                "replicas": [r.stats() for r in self.replicas]}
