"""Asyncio streaming front door (stdlib only: ``asyncio`` + raw HTTP/1.1).

Endpoints
---------

``POST /v1/generate``
    Body: ``{"prompt": [ints], "max_new_tokens": 16, "slo_class":
    "default", "stream": false, "deadline_s": null, "ttft_deadline_s":
    null}``.  Non-streaming replies with one JSON object once the
    request reaches a terminal state.  With ``"stream": true`` the reply
    is ``text/event-stream``: one ``data: {"token": t, "index": i}``
    event per token as it commits, then a final ``data: {"done": true,
    ...}`` event.  A client that disconnects mid-stream maps onto the
    engine's existing cancellation lifecycle (``engine.cancel`` → next
    sweep evicts the request and frees its slot/blocks) — disconnects
    cost capacity for at most one sweep interval, never leak it.

``GET /healthz``
    ``{"ok": true}`` liveness probe.

``GET /v1/stats``
    Router + per-replica load/cost/prefix-cache gauges (JSON).

Responses are ``Connection: close`` framed (body ends when the socket
does) — no chunked encoding, so the tiny test client stays a plain
socket reader.

:class:`FrontDoor` bundles replicas + router + server and runs the
asyncio event loop on a background thread, giving tests and benchmarks a
synchronous ``start()``/``stop()`` surface.
"""

from __future__ import annotations

import asyncio
import json
import threading
from typing import Optional, Sequence

import numpy as np

from repro.serving.frontdoor.replica import Replica
from repro.serving.frontdoor.router import Router
from repro.serving.queue import Request

_MAX_HEADER = 64 * 1024
_MAX_BODY = 16 * 1024 * 1024


def _response(status: str, body: bytes,
              content_type: str = "application/json") -> bytes:
    return (f"HTTP/1.1 {status}\r\n"
            f"Content-Type: {content_type}\r\n"
            f"Content-Length: {len(body)}\r\n"
            f"Connection: close\r\n\r\n").encode() + body


def _json_response(status: str, obj) -> bytes:
    return _response(status, json.dumps(obj).encode())


def _sse_event(obj) -> bytes:
    return b"data: " + json.dumps(obj).encode() + b"\n\n"


class HttpError(Exception):
    def __init__(self, status: str, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


class FrontDoorServer:
    """The asyncio server proper (runs inside an existing event loop)."""

    def __init__(self, router: Router, *, host: str = "127.0.0.1",
                 port: int = 0):
        self.router = router
        self.host = host
        self.port = port          # 0 = ephemeral; real port set at start
        self._server: Optional[asyncio.base_events.Server] = None

    async def start(self) -> int:
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self.port

    async def stop(self):
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    # -- request plumbing ---------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter):
        try:
            try:
                method, path, body = await self._read_request(reader)
            except HttpError as e:
                writer.write(_json_response(e.status, {"error": e.message}))
                await writer.drain()
                return
            try:
                await self._dispatch(method, path, body, reader, writer)
            except HttpError as e:
                writer.write(_json_response(e.status, {"error": e.message}))
                await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _read_request(self, reader):
        try:
            head = await reader.readuntil(b"\r\n\r\n")
        except asyncio.LimitOverrunError:
            raise HttpError("431 Request Header Fields Too Large",
                            "header block too large")
        if len(head) > _MAX_HEADER:
            raise HttpError("431 Request Header Fields Too Large",
                            "header block too large")
        lines = head.decode("latin-1").split("\r\n")
        try:
            method, path, _ = lines[0].split(" ", 2)
        except ValueError:
            raise HttpError("400 Bad Request", "malformed request line")
        headers = {}
        for line in lines[1:]:
            if ":" in line:
                k, v = line.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        length = int(headers.get("content-length", "0") or "0")
        if length > _MAX_BODY:
            raise HttpError("413 Payload Too Large", "body too large")
        body = await reader.readexactly(length) if length else b""
        return method.upper(), path, body

    async def _dispatch(self, method, path, body, reader, writer):
        if method == "GET" and path == "/healthz":
            writer.write(_json_response("200 OK", {"ok": True}))
            await writer.drain()
        elif method == "GET" and path == "/v1/stats":
            writer.write(_json_response("200 OK", self.router.stats()))
            await writer.drain()
        elif method == "POST" and path == "/v1/generate":
            await self._generate(body, reader, writer)
        else:
            raise HttpError("404 Not Found", f"no route {method} {path}")

    # -- /v1/generate -------------------------------------------------------

    def _parse_generate(self, body: bytes) -> dict:
        try:
            obj = json.loads(body.decode())
        except (ValueError, UnicodeDecodeError):
            raise HttpError("400 Bad Request", "body is not valid JSON")
        prompt = obj.get("prompt")
        if (not isinstance(prompt, list) or not prompt
                or not all(isinstance(t, int) for t in prompt)):
            raise HttpError("400 Bad Request",
                            "prompt must be a non-empty list of ints")
        return obj

    async def _generate(self, body, reader, writer):
        obj = self._parse_generate(body)
        aloop = asyncio.get_running_loop()
        events: asyncio.Queue = asyncio.Queue()

        def _post(event: dict):
            try:
                aloop.call_soon_threadsafe(events.put_nowait, event)
            except RuntimeError:
                pass    # event loop already closed (shutdown race) —
                        # nobody is waiting on this connection anymore

        def on_token(tok: int, index: int):
            _post({"token": tok, "index": index})

        def on_finish(req: Request):
            _post({"done": True, "finish_reason": req.finish_reason,
                   "n_tokens": len(req.tokens)})

        stream = bool(obj.get("stream", False))
        request = Request(
            prompt=np.asarray(obj["prompt"], np.int32),
            max_new_tokens=int(obj.get("max_new_tokens", 16)),
            slo_class=str(obj.get("slo_class", "default")),
            deadline_s=obj.get("deadline_s"),
            ttft_deadline_s=obj.get("ttft_deadline_s"))
        tokens = []
        try:
            replica, rid = self.router.submit(
                request, on_token=on_token if stream else None,
                on_finish=on_finish)
        except (RuntimeError, ValueError) as e:
            raise HttpError("503 Service Unavailable", str(e))

        if not stream:
            done = await events.get()
            done.update(request_id=rid, replica=replica.name,
                        tokens=[int(t) for t in request.tokens])
            writer.write(_json_response("200 OK", done))
            await writer.drain()
            return

        # streaming: SSE events as tokens commit; a concurrent EOF watch
        # on the reader detects the client hanging up mid-stream
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        eof = asyncio.ensure_future(reader.read(1))
        try:
            while True:
                get = asyncio.ensure_future(events.get())
                await asyncio.wait({get, eof},
                                   return_when=asyncio.FIRST_COMPLETED)
                if eof.done():
                    eof.exception()     # observe (a client RST lands here)
                    if not get.done():
                        get.cancel()
                        raise ConnectionResetError("client disconnected")
                event = get.result()
                if "token" in event:
                    tokens.append(event["token"])
                event.setdefault("request_id", rid)
                event.setdefault("replica", replica.name)
                try:
                    writer.write(_sse_event(event))
                    await writer.drain()
                except (ConnectionError, OSError):
                    raise ConnectionResetError("client disconnected")
                if event.get("done"):
                    return
        except ConnectionResetError:
            # the disconnect path: cancel into the engine lifecycle —
            # the replica's next sweep frees the slot and its blocks
            replica.cancel(rid)
            raise
        finally:
            if not eof.done():
                eof.cancel()
            elif not eof.cancelled():
                eof.exception()         # keep the loop's unretrieved-
                                        # exception warning quiet


class FrontDoor:
    """Replicas + router + HTTP server with a synchronous lifecycle.

    ``start()`` spins the replica worker threads and an asyncio event
    loop on a background thread, then binds the server (``port=0`` picks
    an ephemeral port, published as ``self.port``).  ``stop()`` tears
    everything down and returns the per-replica ``ServeReport``s."""

    def __init__(self, replicas: Sequence[Replica], *,
                 host: str = "127.0.0.1", port: int = 0,
                 router: Optional[Router] = None, **router_kw):
        self.replicas = list(replicas)
        engines = [id(r.engine) for r in self.replicas]
        if len(set(engines)) != len(engines):
            # cancellation rides engine._pending_cancels; with a shared
            # engine one replica's sweep would steal (and silently drop)
            # another replica's cancel ids
            raise ValueError(
                "replicas must not share a ServingEngine: build one "
                "engine per replica (params can be shared)")
        self.router = (router if router is not None
                       else Router(self.replicas, **router_kw))
        self.host = host
        self.port = port
        self.server: Optional[FrontDoorServer] = None
        self._aloop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None

    def start(self) -> "FrontDoor":
        if self._thread is not None:
            raise RuntimeError("front door already started")
        for r in self.replicas:
            r.start()
        self._aloop = asyncio.new_event_loop()
        self._thread = threading.Thread(
            target=self._aloop.run_forever, name="frontdoor-http",
            daemon=True)
        self._thread.start()
        self.server = FrontDoorServer(self.router, host=self.host,
                                      port=self.port)
        fut = asyncio.run_coroutine_threadsafe(self.server.start(),
                                               self._aloop)
        self.port = fut.result(timeout=30)
        return self

    def stop(self) -> dict:
        """Graceful shutdown; returns ``{replica_name: ServeReport}``."""
        if self.server is not None:
            asyncio.run_coroutine_threadsafe(
                self.server.stop(), self._aloop).result(timeout=30)
            self.server = None
        # drain replicas while the event loop is still alive: in-flight
        # requests' on_token/on_finish callbacks bridge onto it
        reports = {r.name: r.close() for r in self.replicas}
        if self._aloop is not None:
            self._aloop.call_soon_threadsafe(self._aloop.stop)
            self._thread.join(timeout=30)
            self._aloop.close()
            self._aloop = self._thread = None
        return reports
