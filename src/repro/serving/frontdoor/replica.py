"""One engine replica behind the front door.

A :class:`Replica` wraps a ``ServingEngine`` plus a live ``ServeLoop``
(``run_forever`` on a daemon worker thread) and exposes the thread-safe
surface the router and HTTP server need: ``submit`` with per-request
token/finish callbacks, ``cancel`` (client disconnects ride the engine's
existing cancellation lifecycle — slot and blocks free at the loop's next
sweep), and ``stats`` (queue depth, modeled cost hint, prefix-cache
gauges) for routing decisions.

Callbacks fire ON THE REPLICA'S WORKER THREAD: keep them cheap and
thread-safe (the HTTP server bridges them onto its event loop with
``call_soon_threadsafe``).
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Optional

from repro.serving.engine import ServeReport, ServingEngine
from repro.serving.queue import Request


class RequestHandle:
    """Per-request callback registration + terminal-state latch."""

    __slots__ = ("request", "on_token", "on_finish", "notified")

    def __init__(self, request: Request,
                 on_token: Optional[Callable[[int, int], None]] = None,
                 on_finish: Optional[Callable[[Request], None]] = None):
        self.request = request
        self.on_token = on_token
        self.on_finish = on_finish
        self.notified = False


class Replica:
    """A named engine replica running a live serve loop."""

    def __init__(self, engine: ServingEngine, *, name: str = "r0",
                 n_slots: int = 4, cache_T: int = 256,
                 num_blocks: Optional[int] = None, sched_cfg=None,
                 poll_s: float = 0.001):
        self.name = name
        self.engine = engine
        self.poll_s = poll_s
        # an explicit cache_T is REQUIRED here: the loop is built over an
        # empty request list, so the usual derive-from-requests default
        # would size the cache for nothing
        self.loop = engine.make_loop([], n_slots=n_slots, cache_T=cache_T,
                                     num_blocks=num_blocks,
                                     sched_cfg=sched_cfg)
        self.loop.on_token = self._on_token
        self.loop.on_step_end = self._on_step_end
        self._handles: Dict[int, RequestHandle] = {}
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self.report: Optional[ServeReport] = None
        self.error: Optional[BaseException] = None

    # -- lifecycle ----------------------------------------------------------

    def start(self) -> "Replica":
        if self._thread is not None:
            raise RuntimeError(f"replica {self.name} already started")
        self._thread = threading.Thread(
            target=self._run, name=f"replica-{self.name}", daemon=True)
        self._thread.start()
        return self

    def _run(self):
        try:
            self.report = self.loop.run_forever(poll_s=self.poll_s)
        except BaseException as e:  # noqa: BLE001 — recorded, re-raised
            self.error = e
            raise
        finally:
            # a normal drain leaves no handles; after a worker crash the
            # in-flight ones would wait forever — fire their on_finish so
            # callers unblock (the request is still non-terminal, which
            # is how they can tell)
            with self._lock:
                orphans = [h for h in self._handles.values()
                           if not h.notified]
                for h in orphans:
                    h.notified = True
                self._handles.clear()
            for h in orphans:
                if h.on_finish is not None:
                    h.on_finish(h.request)

    def close(self, join: bool = True) -> Optional[ServeReport]:
        """Stop accepting work, drain in-flight requests, and (with
        ``join``) wait for the worker to exit and return its report.
        Re-raises (wrapped) if the worker died on an exception."""
        self.loop.close()
        if join and self._thread is not None:
            self._thread.join()
            if self.error is not None:
                raise RuntimeError(
                    f"replica {self.name} worker died") from self.error
        return self.report

    # -- request surface ----------------------------------------------------

    def submit(self, request: Request,
               on_token: Optional[Callable[[int, int], None]] = None,
               on_finish: Optional[Callable[[Request], None]] = None) -> int:
        """Enqueue one request; returns its request_id.  ``on_token(tok,
        index)`` fires once per FRESH token (replay re-emissions after a
        preemption are suppressed upstream), ``on_finish(request)`` once
        when it reaches a terminal state."""
        if self.error is not None:
            raise RuntimeError(
                f"replica {self.name} worker died") from self.error
        handle = RequestHandle(request, on_token, on_finish)
        with self._lock:
            self._handles[int(request.request_id)] = handle
        try:
            self.loop.submit(request)
        except RuntimeError:
            with self._lock:
                self._handles.pop(int(request.request_id), None)
            raise
        return int(request.request_id)

    def cancel(self, request_id: int) -> None:
        """Cancel an in-flight request (idempotent; unknown ids no-op).
        The loop's next sweep evicts it and frees its slot/blocks — this
        is the client-disconnect path."""
        self.engine.cancel(int(request_id))

    # -- loop hooks (worker thread) -----------------------------------------

    def _on_token(self, req: Request, tok: int, index: int):
        with self._lock:
            handle = self._handles.get(int(req.request_id))
        if handle is not None and handle.on_token is not None:
            handle.on_token(int(tok), int(index))

    def _on_step_end(self, loop):
        done = []
        with self._lock:
            for rid, handle in self._handles.items():
                if handle.request.is_terminal and not handle.notified:
                    handle.notified = True
                    done.append(rid)
            finished = [self._handles.pop(rid) for rid in done]
        for handle in finished:
            if handle.on_finish is not None:
                handle.on_finish(handle.request)

    # -- routing inputs -----------------------------------------------------

    @property
    def block_size(self) -> int:
        return int(self.engine.serve_cfg.block_size)

    def stats(self) -> dict:
        """Routing-relevant load snapshot (thread-safe, approximate: the
        worker may move a request between stages mid-read)."""
        loop = self.loop
        with loop._inbox_lock:
            inbox = len(loop._inbox)
        out = {
            "name": self.name,
            "queue_depth": (inbox + len(loop.arrivals) + len(loop.rq)
                            + len(loop.active)),
            "active_slots": len(loop.active),
            "n_slots": int(loop.n_slots),
            # cost-aware routing hint: running mean of modeled BitParticle
            # array cycles per processed token (0.0 until the probe's
            # first hw_estimate sample lands)
            "cost_hint_cycles_per_token": float(
                loop.cost_hint_cycles_per_token),
        }
        pool = getattr(loop.cm, "pool", None)
        if pool is not None:
            out["prefix_hit_blocks"] = int(pool.n_prefix_hits)
            out["blocks_in_use"] = int(pool.n_live)
        return out
