"""Paged KV-cache memory subsystem: BlockPool accounting + PagedCacheManager.

The slab backend (``cache_manager.CacheManager``) reserves a full worst-case
``cache_T`` region per slot, so admission is governed by
``prompt_len + max_new_tokens`` even when most requests finish early — the
serving-memory analogue of the paper's "one factor's sparsity is completely
wasted" problem.  This module partializes that variable-size reservation into
fixed-size **blocks** (``block_size`` tokens each), allocated on demand, with
cheap control logic:

  * ``BlockPool`` — pure host-side accounting: a free list, per-block
    reference counts, and a hash-trie over *full* prompt-token blocks that
    makes prefix sharing automatic (two requests with the same system prompt
    map their shared prefix onto the same physical blocks).  Blocks whose
    refcount drops to zero but that are registered in the trie are retained
    in an LRU "cached" list and only really evicted when the pool runs dry.
  * ``PagedCacheManager`` — the device-facing manager with the same slot
    interface as the slab ``CacheManager`` (alloc/free/insert/advance/...),
    plus per-slot block tables, copy-on-write on the first divergent write
    into a shared block, and the block-budget accounting the scheduler uses
    for admission.

Physical layout: every KV leaf is paged as ``(L, num_blocks, block_size,
heads...)``; a request's logical positions ``[0, len)`` live at
``pages[:, table[i], pos % block_size]`` with ``i = pos // block_size``.
Block id 0 is reserved as a trash/scratch block: unused table entries point
at it, so every gather/scatter stays in-range at fixed shapes (writes that
must go nowhere land there, reads of it are masked by ``cache_len``).
"""

from __future__ import annotations

import collections
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax.numpy as jnp

from repro.serving.cache_manager import BaseCacheManager
from repro.serving.faults import InjectedFault, NULL_INJECTOR

TRASH_BLOCK = 0  # reserved scratch block id (never allocated, never shared)


class NoFreeBlocks(RuntimeError):
    """Raised when an allocation cannot be satisfied even after evicting
    every unreferenced cached block (the engine preempts a request then)."""


class InjectedPoolExhaustion(NoFreeBlocks, InjectedFault):
    """Injected pool exhaustion: rides the normal ``NoFreeBlocks``
    preempt-and-retry path, but — being an :class:`InjectedFault` — stays
    recoverable when no preemption victim exists (a REAL exhaustion with
    no victim is a sizing error and keeps raising)."""

    site = "pool"


class BlockPool:
    """Host-side accounting for a pool of fixed-size KV blocks.

    Pure control logic — never touches device memory.  The paged cache
    manager (and its tests) drive it; the device-side pages are indexed by
    the block ids this pool hands out.
    """

    def __init__(self, num_blocks: int, block_size: int, *, faults=None):
        if num_blocks < 2:
            raise ValueError("num_blocks must be >= 2 (block 0 is reserved)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.faults = faults if faults is not None else NULL_INJECTOR
        # block 0 is the trash block; ids [1, num_blocks) are allocatable
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self.refcount = np.zeros(num_blocks, np.int32)
        # hash-trie over full prompt blocks: key = (parent_key, tokens);
        # the root parent is None.  node key -> block id, plus the children
        # map used for partial-suffix matching.
        self._trie: Dict[tuple, int] = {}
        self._children: Dict[Optional[tuple], Dict[tuple, int]] = {}
        self._block_key: Dict[int, tuple] = {}     # block id -> trie key
        # refcount-0 blocks still registered in the trie, LRU order
        # (oldest first); they are reclaimed only when the free list is dry.
        self._cached: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self.n_evictions = 0
        self.n_cow = 0
        self.n_prefix_hits = 0
        self.peak_live = 0        # high-water mark of referenced blocks

    # -- capacity -----------------------------------------------------------

    @property
    def n_free(self) -> int:
        """Blocks allocatable right now (free list + evictable cached)."""
        return len(self._free) + len(self._cached)

    @property
    def n_live(self) -> int:
        """Blocks with at least one live reference."""
        return int((self.refcount > 0).sum())

    # -- alloc / refcount ---------------------------------------------------

    def alloc(self) -> int:
        """Allocate a private (refcount 1, unregistered) block; evicts the
        LRU cached prefix block if the free list is empty."""
        if self.faults.fire("pool"):
            raise InjectedPoolExhaustion("injected pool exhaustion")
        if self._free:
            bid = self._free.pop()
        elif self._cached:
            bid, _ = self._cached.popitem(last=False)   # LRU eviction
            self._forget(bid)
            self.n_evictions += 1
        else:
            raise NoFreeBlocks(
                f"pool of {self.num_blocks - 1} blocks exhausted")
        assert self.refcount[bid] == 0, bid
        self.refcount[bid] = 1
        self.peak_live = max(self.peak_live, self.n_live)
        return bid

    def incref(self, bid: int):
        if bid == TRASH_BLOCK:
            raise ValueError("cannot reference the trash block")
        if self.refcount[bid] == 0:
            # resurrecting a cached prefix block
            if bid not in self._cached:
                raise ValueError(f"block {bid} is free, cannot incref")
            del self._cached[bid]
            self.refcount[bid] = 1
            self.peak_live = max(self.peak_live, self.n_live)
            return
        self.refcount[bid] += 1

    def decref(self, bid: int):
        if bid == TRASH_BLOCK:
            return
        if self.refcount[bid] <= 0:
            raise ValueError(f"double free of block {bid}")
        self.refcount[bid] -= 1
        if self.refcount[bid] == 0:
            if bid in self._block_key:
                # registered prefix block: retain content, LRU-evictable
                self._cached[bid] = None
            else:
                self._free.append(bid)

    def is_registered(self, bid: int) -> bool:
        """Is this block's content indexed by the prefix trie?  Registered
        blocks are immutable — writers must copy-on-write them."""
        return bid in self._block_key

    def _forget(self, bid: int):
        """Drop a block's trie registration (its content is being reused)."""
        key = self._block_key.pop(bid, None)
        if key is None:
            return
        if self._trie.get(key) == bid:
            del self._trie[key]
            parent, toks = key
            kids = self._children.get(parent)
            if kids is not None and kids.get(toks) == bid:
                del kids[toks]
                if not kids:
                    self._children.pop(parent, None)

    # -- prefix trie --------------------------------------------------------

    def register(self, parent_key: Optional[tuple], tokens: Tuple[int, ...],
                 bid: int) -> Tuple[tuple, int]:
        """Register a *full* block's token content under its parent chain.

        Returns ``(key, canonical_bid)``.  If an identical block is already
        registered (e.g. two requests with the same prompt admitted in one
        prefill group), the existing block is the canonical one: the caller
        should swap its table entry to it (incref canonical / decref own).
        """
        if len(tokens) != self.block_size:
            raise ValueError("only full blocks are registered in the trie")
        key = (parent_key, tuple(int(t) for t in tokens))
        existing = self._trie.get(key)
        if existing is not None and existing != bid:
            return key, existing
        self._trie[key] = bid
        self._children.setdefault(parent_key, {})[key[1]] = bid
        self._block_key[bid] = key
        return key, bid

    def match_prefix(self, tokens: Sequence[int], *, peek: bool = False):
        """Longest shared prefix of ``tokens`` present in the trie.

        ``peek`` inspects without side effects (no LRU touch, no hit
        counting) — the scheduler's admission budget uses it every step.

        Returns ``(full_ids, partial)``:
          * ``full_ids`` — block ids covering the first
            ``len(full_ids) * block_size`` tokens (each LRU-touched, NOT
            incref'ed — the caller adopts them via :meth:`incref`);
          * ``partial`` — ``(bid, n)`` when the remaining suffix (shorter
            than a block) is a prefix of some registered block's content: its
            first ``n`` positions hold exactly the K/V this prompt needs
            (K/V at position p depends only on tokens <= p).  Adopting it
            shares a *partial* block, so the first append into it must
            copy-on-write.  ``None`` when no such block exists.
        """
        bs = self.block_size
        toks = [int(t) for t in tokens]
        full_ids: List[int] = []
        parent: Optional[tuple] = None
        i = 0
        while i + bs <= len(toks):
            key = (parent, tuple(toks[i:i + bs]))
            bid = self._trie.get(key)
            if bid is None:
                break
            if not peek:
                self._touch(bid)
            full_ids.append(bid)
            parent = key
            i += bs
        partial = None
        rem = tuple(toks[i:])
        if rem and i + bs <= len(toks):
            rem = ()      # broke on a full-block miss: no partial to match
        if rem:
            for child_toks, bid in self._children.get(parent, {}).items():
                if child_toks[:len(rem)] == rem:
                    if not peek:
                        self._touch(bid)
                    partial = (bid, len(rem))
                    break
        if not peek and (full_ids or partial):
            self.n_prefix_hits += len(full_ids) + (1 if partial else 0)
        return full_ids, partial

    def _touch(self, bid: int):
        if bid in self._cached:
            self._cached.move_to_end(bid)


class PagedCacheManager(BaseCacheManager):
    """Block-paged decode cache with the slab manager's slot interface.

    Supported families: those whose decode cache is purely position-indexed
    KV (dense / moe / vlm).  Recurrent families (ssm / hybrid) have O(1)
    state per slot — paging buys nothing there; use the slab backend.
    """

    def __init__(self, cfg, n_slots: int, cache_T: int, *,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 executor=None, telemetry=None, faults=None):
        from repro.serving.telemetry import NULL_TELEMETRY
        if cfg.family not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"cache_backend='paged' supports position-indexed KV "
                f"families (dense/moe/vlm), not {cfg.family!r}; use 'slab'")
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        self.block_size = block_size
        # blocks per sequence: logical capacity rounded up to whole blocks
        self.blocks_per_seq = -(-cache_T // block_size)
        if num_blocks is None:
            # same HBM as the slab pool by default (+1 for the trash block)
            num_blocks = n_slots * self.blocks_per_seq + 1
        super().__init__(cfg, n_slots)
        self.num_blocks = num_blocks
        self.pool = BlockPool(num_blocks, block_size, faults=faults)
        # device ops (page allocation, the jitted+donating scatter insert
        # and copy-on-write block copy) live behind the executor; page
        # leaves stay replicated under a mesh (no batch/seq axis to shard)
        if executor is None:
            from repro.serving.executor import make_executor
            executor = make_executor(cfg)
        self.executor = executor
        self.pages = executor.zeros_paged_cache(num_blocks, block_size)
        # per-slot block tables, unset entries point at the trash block
        self.tables = np.full((n_slots, self.blocks_per_seq), TRASH_BLOCK,
                              np.int32)
        self._n_blocks_of = np.zeros(n_slots, np.int32)   # live table entries
        self.n_preemptions = 0

    # -- capacity / admission budget ---------------------------------------

    @property
    def cache_T(self) -> int:
        """Max logical context per sequence (for fits/bucketing), bounded by
        both the per-slot table and the whole pool."""
        return min(self.blocks_per_seq,
                   max(self.num_blocks - 1, 1)) * self.block_size

    @property
    def prefill_T(self) -> int:
        """Prefill caches must pad to whole blocks so ``paged_insert`` can
        slice them: the per-slot table span, in tokens."""
        return self.blocks_per_seq * self.block_size

    def fits(self, prompt_len: int, max_new_tokens: int) -> bool:
        return prompt_len + max_new_tokens <= self.cache_T

    @property
    def n_free_blocks(self) -> int:
        return self.pool.n_free

    def admissible_prefix(self, requests) -> int:
        """How many front-of-queue requests fit the current block budget
        (prefix-sharing hits counted) and free slots — the paged admission
        rule: by free-*block* budget, not worst-case slot reservation.

        The budget (``pool.n_free``) counts refcount-0 cached blocks as
        allocatable-by-eviction; a cached block CLAIMED as a prefix hit for
        an earlier request in the plan must stop counting (evicting it
        would destroy the hit that made that admission cheap), so each
        newly-claimed cached hit also debits the budget."""
        bs = self.pool.block_size
        budget = self.pool.n_free
        claimed: set = set()
        slots = self.n_free
        n = 0
        for req in requests:
            if slots == 0:
                break
            toks = req.prompt.tolist()
            hit_ids, partial = self.pool.match_prefix(toks, peek=True)
            full, rem = divmod(len(toks), bs)
            # a partial hit ADOPTS a shared tail block, so the remainder
            # costs no fresh block at insert time (CoW pays later)
            need = (full - len(hit_ids)) + (1 if rem and partial is None
                                            else 0)
            reserve = 0
            for bid in hit_ids + ([partial[0]] if partial else []):
                if self.pool.refcount[bid] == 0 and bid not in claimed:
                    claimed.add(bid)
                    reserve += 1
            if need + reserve > budget:
                break
            budget -= need + reserve
            slots -= 1
            n += 1
        return n

    # -- slot lifecycle -----------------------------------------------------

    def free(self, slot: int):
        k = int(self._n_blocks_of[slot])
        for bid in self.tables[slot, :k]:
            self.pool.decref(int(bid))
        self.tables[slot] = TRASH_BLOCK
        self._n_blocks_of[slot] = 0
        super().free(slot)

    # -- prefill insert with prefix sharing --------------------------------

    def insert(self, slot: int, src_cache, length: int, src_index: int = 0,
               tokens: Optional[Sequence[int]] = None):
        """Install request ``src_index`` of a prefill cache into ``slot``.

        ``tokens`` (the prompt) drives prefix sharing: full blocks already in
        the trie are adopted by reference (never re-written — their content
        is identical since K/V at position p depends only on tokens <= p);
        a partial-suffix hit adopts a shared block copy-on-write.  Freshly
        written full blocks are registered for future requests.
        Raises :class:`NoFreeBlocks` when the pool cannot cover the miss
        suffix — the engine preempts a request and retries.
        """
        if not self._occupied[slot]:
            raise ValueError(f"slot {slot} must be alloc()ed before insert")
        if tokens is None:
            raise ValueError("paged insert needs the prompt tokens")
        toks = [int(t) for t in tokens][:length]
        bs = self.block_size
        full_ids, partial = self.pool.match_prefix(toks)
        n_counted_hits = len(full_ids) + (1 if partial is not None else 0)
        n_hit = len(full_ids)
        for bid in full_ids:
            self.pool.incref(bid)
        table: List[int] = list(full_ids)
        keys: List[Optional[tuple]] = [None]
        for j, bid in enumerate(full_ids):
            keys.append((keys[j], tuple(toks[j * bs:(j + 1) * bs])))
        n_total = -(-length // bs)
        fresh: List[int] = []
        adopted_partial = partial is not None
        if adopted_partial:
            # match_prefix only returns a partial when every full block hit,
            # so this is always the request's final (tail) block
            self.pool.incref(partial[0])
            table.append(partial[0])
        try:
            while len(table) < n_total:
                bid = self.pool.alloc()
                fresh.append(bid)
                table.append(bid)
        except NoFreeBlocks:
            for bid in table:
                self.pool.decref(bid)
            # roll back the hit count too: the engine preempts and RETRIES
            # this insert, which re-counts the same hits — without this the
            # prefix-sharing metric inflates under memory pressure
            self.pool.n_prefix_hits -= n_counted_hits
            raise
        # one jitted scatter at fixed (blocks_per_seq,) shape: hit blocks are
        # redirected to the trash block so they are NEVER written in place
        ids = np.full(self.blocks_per_seq, TRASH_BLOCK, np.int32)
        skip = n_hit + (1 if adopted_partial else 0)
        ids[skip:n_total] = table[skip:n_total]
        try:
            with self.telemetry.span("block_insert", slot=slot,
                                     n_blocks=n_total - skip,
                                     prefix_hits=n_counted_hits):
                self.pages = self.executor.paged_insert(self.pages, src_cache,
                                                        ids, src_index)
        except Exception:
            # the device scatter failed (e.g. injected OOM) AFTER the table
            # refs were taken: release them or the pool leaks every block
            # this request claimed
            for bid in table:
                self.pool.decref(bid)
            self.pool.n_prefix_hits -= n_counted_hits
            raise
        # register freshly written FULL blocks; on a same-content collision
        # (two identical prompts in one prefill group) swap to the canonical
        # block so the copies share
        for j in range(skip, n_total):
            if (j + 1) * bs > length:
                break   # trailing partial block: content not yet final
            key, canon = self.pool.register(keys[j], tuple(
                toks[j * bs:(j + 1) * bs]), table[j])
            if canon != table[j]:
                self.pool.incref(canon)
                self.pool.decref(table[j])
                table[j] = canon
            keys.append(key)
        self.tables[slot, :n_total] = table
        self.tables[slot, n_total:] = TRASH_BLOCK
        self._n_blocks_of[slot] = n_total
        self.lengths[slot] = length

    # -- decode-step support ------------------------------------------------

    def prepare_append(self, slots, counts=None) -> Optional[int]:
        """Make sure every slot in ``slots`` can write its next ``n``
        tokens (positions ``lengths[slot] .. lengths[slot] + n - 1``; ``n``
        is 1 for the classic decode step, ``counts[i]`` per slot for a
        speculative verify that appends the committed token plus drafts):
        allocate new tail blocks at block boundaries, copy-on-write a
        shared tail block on first divergent write.  Speculative overhang
        past the per-slot table span is NOT an error — those writes
        redirect to the trash block in ``decode_step_paged``/``verify_step_
        paged`` and can never be committed (``fits`` bounds the committed
        length).  Returns the first slot that could NOT be satisfied (pool
        dry — caller preempts and retries), or None when all are ready."""
        if counts is None:
            counts = [1] * len(slots)
        for s, n in zip(slots, counts):
            pos = int(self.lengths[s])
            first_bi = pos // self.block_size
            last_bi = (pos + max(int(n), 1) - 1) // self.block_size
            for bi in range(first_bi, last_bi + 1):
                if bi >= self.blocks_per_seq:
                    if bi == first_bi:
                        # even the COMMITTED next token has no table entry
                        # left: a real capacity bug, not spec overhang
                        raise RuntimeError(
                            f"slot {s} exceeded its block table")
                    break
                if bi >= self._n_blocks_of[s]:
                    try:
                        bid = self.pool.alloc()
                    except NoFreeBlocks:
                        return s
                    self.tables[s, bi] = bid
                    self._n_blocks_of[s] = bi + 1
                else:
                    bid = int(self.tables[s, bi])
                    if (self.pool.refcount[bid] > 1
                            or self.pool.is_registered(bid)):
                        # shared (or registered immutable prefix) block:
                        # first divergent write copies it — never write in
                        # place
                        try:
                            new = self.pool.alloc()
                        except NoFreeBlocks:
                            return s
                        try:
                            with self.telemetry.span("cow", slot=s,
                                                     src=bid, dst=new):
                                self.pages = self.executor.copy_block(
                                    self.pages, new, bid)
                        except Exception:
                            # device copy failed: the fresh private block
                            # would leak (nothing references it yet)
                            self.pool.decref(new)
                            raise
                        self.pool.decref(bid)
                        self.tables[s, bi] = new
                        self.pool.n_cow += 1
        return None

    def release_tail(self, slot: int):
        """Speculative rollback: free whole blocks past the slot's last
        committed position (``lengths[slot]`` counts valid K/V entries).
        A freed block was by construction allocated privately for the
        rejected draft span — ``prepare_append`` copies any shared or
        trie-registered block before the verify step writes it, so a
        rewind can never mutate or release shared content in place; this
        is asserted, not assumed."""
        n_keep = -(-int(self.lengths[slot]) // self.block_size)
        k = int(self._n_blocks_of[slot])
        if k > n_keep:
            self.telemetry.instant("release_tail", slot=slot,
                                   n_blocks=k - n_keep)
        for bi in range(n_keep, k):
            bid = int(self.tables[slot, bi])
            if (self.pool.refcount[bid] != 1
                    or self.pool.is_registered(bid)):
                raise RuntimeError(
                    f"speculative rollback would release shared block "
                    f"{bid} (slot {slot}): CoW invariant violated")
            self.pool.decref(bid)
            self.tables[slot, bi] = TRASH_BLOCK
        self._n_blocks_of[slot] = min(k, n_keep)

    def block_tables_device(self) -> jnp.ndarray:
        return self.executor.put(self.tables)

    def update(self, new_cache):
        self.pages = new_cache

    @property
    def cache(self):
        return self.pages

    # -- introspection ------------------------------------------------------

    def blocks_in_use(self) -> int:
        return self.pool.n_live
