"""Hardware-cost probe: measured bit-sparsity of live serving traffic folded
through the paper's cost models (Tables II-III, the array simulator).

``SparsityProbe`` threads through ``ServeConfig -> ServeLoop -> Executor``
exactly like ``Telemetry`` and ``FaultInjector``.  When enabled, the
executor jits *probed* variants of the prefill/decode/verify step fns whose
bodies run under ``core.probe.probe_tap()``: fused scalar reductions on the
already-quantized int8 activations produce one small ``(L[+1], N_STATS)``
array per step — the only probe data that leaves the device.  Weight bit
sparsity is computed once at engine construction from the pre-quantized
int8 weights (they never change during a serve).

On the host, ``fold`` prices each sampled step: modeled avg cycles/MAC for
bp_exact / bp_approx / adas / bitwave (Monte-Carlo models interpolated over
a lazily-built sparsity grid so per-step cost is a table lookup), a small
seeded quasi-sync array simulation for utilization, and Table III energy
interpolation — emitted as an additive-v1 ``hw_estimate`` telemetry record.

The disabled path (``NULL_PROBE``) never enters the tap, never jits probed
variants, and is pinned token-identical by ``tests/test_probe.py``.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core import array_sim
from repro.core import cost_model as cm
from repro.core.sparsity import N_STATS

PROBE_METHODS = ("bp_exact", "bp_approx", "adas", "bitwave")

# Interpolation grid for the Monte-Carlo cycle models.  Live traffic sits
# well off Table III's 0.5-0.9 ladder (random-init weights measure ~0.6,
# near-zero activations ~0.9+), so the grid spans wider.
_GRID = (0.2, 0.35, 0.5, 0.65, 0.8, 0.95)


def probe_supported(cfg) -> bool:
    """The probe taps int8 operands at the quantized-matmul boundary: only
    the causal-LM families in a BitParticle int8 mode have them."""
    return (cfg.family in ("dense", "moe", "vlm")
            and cfg.matmul_mode in ("bp_exact", "bp_approx"))


def _rates(stats: np.ndarray):
    """(bit_sparsity, value_sparsity) from summed stat rows (numpy)."""
    stats = np.asarray(stats, np.float64)
    n = max(float(stats[..., 1].sum() if stats.ndim > 1 else stats[1]), 1.0)
    if stats.ndim > 1:
        return float(stats[:, 0].sum() / (7.0 * n)), float(stats[:, 2].sum() / n)
    return float(stats[0] / (7.0 * n)), float(stats[2] / n)


def _row_rates(stats: np.ndarray):
    """Per-row (bit_sparsity, value_sparsity) lists from an (R, N_STATS)."""
    stats = np.asarray(stats, np.float64)
    n = np.maximum(stats[:, 1], 1.0)
    return ((stats[:, 0] / (7.0 * n)).tolist(), (stats[:, 2] / n).tolist())


def per_layer_weight_stats(params, n_layers: int):
    """``(n_layers, N_STATS)`` weight stats + optional unstacked tail row.

    Walks the quantized param tree once: int8 leaves under the scan-stacked
    ``layers`` subtree contribute per-layer rows; unstacked int8 leaves
    (an untied lm head) sum into the tail.  Returns ``(stacked, tail)``
    with ``tail is None`` when no unstacked int8 leaf exists.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.sparsity import per_layer_stats, sm_bit_stats

    stacked = np.zeros((n_layers, N_STATS), np.float64)
    tail = np.zeros((N_STATS,), np.float64)
    has_tail = False
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        if getattr(leaf, "dtype", None) != jnp.int8:
            continue
        keys = [str(getattr(k, "key", getattr(k, "idx", k))) for k in path]
        if "layers" in keys and leaf.ndim >= 3 and leaf.shape[0] == n_layers:
            stacked += np.asarray(per_layer_stats(leaf), np.float64)
        else:
            tail += np.asarray(sm_bit_stats(leaf), np.float64)
            has_tail = True
    return stacked, (tail if has_tail else None)


class _CycleModel:
    """Lazily-built interpolation tables over the Monte-Carlo cycle models.

    bp_exact / bp_approx depend on both factors' bit sparsity -> 2D grid
    (activation x weight, bilinear).  adas (bit_serial) and bitwave are
    single-factor (the activation) -> 1D grid.
    """

    def __init__(self, n_mc: int = 20_000, seed: int = 0):
        self.n_mc = n_mc
        self.seed = seed
        self._tables: Dict[str, np.ndarray] = {}

    def _table(self, method: str) -> np.ndarray:
        tab = self._tables.get(method)
        if tab is None:
            if method in ("bp_exact", "bp_approx"):
                tab = np.array(
                    [[cm.modeled_avg_cycles_dual(method, a, w, n=self.n_mc,
                                                 seed=self.seed)
                      for a in _GRID] for w in _GRID])
            else:
                m = "bit_serial" if method == "adas" else method
                tab = np.array([cm.modeled_avg_cycles(m, a, n=self.n_mc,
                                                      seed=self.seed)
                                for a in _GRID])
            self._tables[method] = tab
        return tab

    def cycles(self, method: str, a_bs: float, w_bs: float) -> float:
        grid = np.asarray(_GRID)
        a = float(np.clip(a_bs, grid[0], grid[-1]))
        w = float(np.clip(w_bs, grid[0], grid[-1]))
        tab = self._table(method)
        if tab.ndim == 1:
            return float(np.interp(a, grid, tab))
        col = np.array([np.interp(a, grid, row) for row in tab])
        return float(np.interp(w, grid, col))


class SparsityProbe:
    """Serving-side sparsity probe handle (``ServeConfig(probe=...)``).

    ``probe_every=0`` is the strict no-op handle (``NULL_PROBE``): no probed
    step fns are jitted, the serve path is byte-identical.  ``probe_every=k``
    samples every k-th decode/verify step (and every admission prefill).
    """

    def __init__(self, probe_every: int = 1, *, n_mc: int = 20_000,
                 array_steps: int = 24, seed: int = 0):
        self.probe_every = int(probe_every)
        self.array_steps = int(array_steps)
        self.seed = int(seed)
        self._model = _CycleModel(n_mc=n_mc, seed=seed)
        self._sim_cache: Dict[tuple, tuple] = {}

    @property
    def enabled(self) -> bool:
        return self.probe_every > 0

    def should_sample(self, step: int) -> bool:
        return self.enabled and step % self.probe_every == 0

    def _array_point(self, a_bs, a_vs, w_bs, w_vs):
        """(pe_utilization, avg_cycles_per_step) of a small seeded quasi-sync
        array sim at the measured operating point; memoized on the rates
        rounded to the grid the sim can actually resolve."""
        key = tuple(round(v, 2) for v in (a_bs, a_vs, w_bs, w_vs))
        out = self._sim_cache.get(key)
        if out is None:
            cfg = array_sim.ArrayConfig(rows=8, cols=16, E=3, Q=2,
                                        zero_filter=True)
            r = array_sim.run_experiment(self.seed, cfg, self.array_steps,
                                         bit_sparsity=key[2],
                                         w_value_sparsity=key[3],
                                         a_value_sparsity=key[1],
                                         a_bit_sparsity=key[0])
            out = (float(r.pe_utilization), float(r.avg_cycles_per_step))
            self._sim_cache[key] = out
        return out

    def fold(self, stats: np.ndarray, weight_profile: dict,
             phase: str) -> dict:
        """Price one sampled step: device stat rows + the static weight
        profile -> the ``hw_estimate`` record fields (native Python values,
        ready for ``Telemetry.emit``)."""
        stats = np.asarray(stats, np.float64)
        n_layers = len(weight_profile["per_layer_bit_sparsity"])
        act_bs, act_vs = _rates(stats)
        per_bs, per_vs = _row_rates(stats)
        w_bs = float(weight_profile["bit_sparsity"])
        w_vs = float(weight_profile.get("value_sparsity", 0.0))
        cycles = {m: self._model.cycles(m, act_bs, w_bs)
                  for m in PROBE_METHODS}
        util, cyc_step = self._array_point(act_bs, act_vs, w_bs, w_vs)
        # Table III operating point: the table is indexed by one shared
        # sparsity level, so energy interpolates at the two factors' mean.
        op_bs = 0.5 * (act_bs + w_bs)
        energy = {m: float(cm.mac_energy_pj(m, op_bs)) for m in PROBE_METHODS}
        return {
            "phase": phase,
            "n_layers": int(n_layers),
            "act_bit_sparsity": act_bs,
            "act_value_sparsity": act_vs,
            "weight_bit_sparsity": w_bs,
            "per_layer_act_bit_sparsity": per_bs,
            "per_layer_act_value_sparsity": per_vs,
            "cycles": cycles,
            "array_utilization": util,
            "array_cycles_per_step": cyc_step,
            "mac_energy_pj": energy,
        }


NULL_PROBE = SparsityProbe(probe_every=0)
