"""Speculative decoding: drafters + elastic multi-token verification.

BitParticle's scheduling story is that per-unit work is *variable* (bit
sparsity makes MAC cycle counts fluctuate) and that a quasi-synchronous
array with bounded elasticity recovers the utilization rigid lock-step
wastes.  Speculative decoding is the exact software analogue one level up:
a cheap **drafter** guesses the next K tokens per slot, one batched
``verify_step`` checks all of them in a single target-model forward pass,
and each slot **commits a variable number of tokens per step** (1 when the
first draft misses, up to K+1 when every draft lands).  The serving
stack's per-slot ``cache_len`` machinery — built for requests advancing at
their own depth — absorbs that fluctuation unchanged: slots now diverge by
*committed tokens*, not merely by admission staggering, and the
``QuasiSyncScheduler``'s lead window / divergence metrics read the same.

Two drafters ship behind one interface:

  * :class:`PromptLookupDrafter` — weight-free n-gram lookup: the longest
    suffix of the slot's context (prompt + generated) that re-occurs
    earlier in the context predicts its historical continuation.  Zero
    model cost, surprisingly effective on extractive/repetitive workloads
    (summarization, code edits), ideal for CPU tests.
  * :class:`ModelDrafter` — a small same-family model (its own
    ``ArchConfig`` + params) runs K+1 greedy one-token decode steps over
    its OWN slot-aligned slab cache, batched across slots.  All its device
    work routes through a ``serving.executor.Executor`` built over the
    target engine's mesh, so drafting composes with ``MeshExecutor``
    tensor parallelism.

Correctness contract (the headline property): with greedy decoding the
verify/accept rule commits EXACTLY the token stream the non-speculative
engine would emit — ``argmax`` of the target logits at every position —
so speculation changes step counts, never outputs.  Drafting is therefore
greedy-only (``ServeConfig.temperature == 0``); temperature sampling would
need the rejection-resampling scheme and is rejected with a clear error.

Rollback lives in the cache managers, not here: the slab store simply
advances ``cache_len`` by the committed count (rejected-draft K/V beyond
it is masked and later overwritten); the paged store additionally releases
whole tail blocks past the committed length (``PagedCacheManager.
release_tail``) — never a shared block, because ``prepare_append``
copy-on-writes any shared/registered block before the verify step writes.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np
import jax.numpy as jnp

from repro.serving.faults import NULL_INJECTOR


class Drafter:
    """Per-slot draft-token proposer driven by the ``ServeLoop``.

    Lifecycle hooks mirror the target cache manager's slot lifecycle so a
    stateful drafter (the model drafter's own KV cache) stays aligned with
    the slots it drafts for; the weight-free drafter ignores them.
    ``propose_all`` is called once per verify step with every slot that
    will ride it and must return at most ``caps[slot]`` tokens per slot
    (the loop caps drafts by each request's remaining output budget).
    """

    name = "none"
    # fault-injection handle (threaded by the serve loop like telemetry);
    # ``propose_all`` implementations check the "drafter" site on entry —
    # the loop catches the raised ``DrafterFault`` and falls back to a
    # plain decode step (degrading to no speculation after repeats)
    faults = NULL_INJECTOR

    def propose_all(self, requests: Dict[int, object],
                    caps: Dict[int, int]) -> Dict[int, np.ndarray]:
        raise NotImplementedError

    def on_admit(self, slot: int, req) -> None:     # noqa: B027
        """A request was installed into ``slot`` (after target prefill)."""

    def on_free(self, slot: int) -> None:           # noqa: B027
        """``slot`` was released (finish or preemption)."""

    def observe_commit(self, slot: int, committed_len: int) -> None:  # noqa: B027
        """The verify step committed tokens: the slot's valid context
        length (prompt + generated - 1 unfed token) is now
        ``committed_len``.  Stateful drafters rewind here."""


def _context(req) -> np.ndarray:
    """The slot's full token context: prompt + every generated token
    (including the last, not-yet-fed one)."""
    return np.concatenate([np.asarray(req.prompt, np.int64),
                           np.asarray(req.tokens, np.int64)])


class PromptLookupDrafter(Drafter):
    """Weight-free prompt-lookup (n-gram) drafting.

    The last ``n`` context tokens (``n`` from ``max_ngram`` down to
    ``min_ngram``) are searched for an earlier occurrence in the context;
    on a hit, the tokens that historically followed the match are proposed
    as the draft.  The most recent (rightmost) match wins — it is the best
    local predictor of the continuation.  No weights, no device work: the
    ideal CPU-test drafter, and a genuinely useful one on inputs that
    reuse their own phrasing.
    """

    name = "prompt_lookup"

    def __init__(self, num_draft_tokens: int, *, max_ngram: int = 3,
                 min_ngram: int = 1):
        if not 1 <= min_ngram <= max_ngram:
            raise ValueError("need 1 <= min_ngram <= max_ngram")
        self.k = int(num_draft_tokens)
        self.max_ngram = int(max_ngram)
        self.min_ngram = int(min_ngram)

    def _lookup(self, ctx: np.ndarray, k: int) -> np.ndarray:
        L = len(ctx)
        for n in range(min(self.max_ngram, L - 1), self.min_ngram - 1, -1):
            pat = ctx[L - n:]
            # rightmost earlier occurrence of the suffix n-gram; the tokens
            # that followed it are the proposal (they may reach into the
            # suffix itself — that is exactly how a repeat extends)
            for i in range(L - n - 1, -1, -1):
                if np.array_equal(ctx[i:i + n], pat):
                    return ctx[i + n:i + n + k].astype(np.int32)
        return np.zeros(0, np.int32)

    def propose_all(self, requests, caps):
        self.faults.check("drafter")
        return {slot: self._lookup(_context(req),
                                   min(self.k, caps.get(slot, self.k)))
                for slot, req in requests.items()}


class ModelDrafter(Drafter):
    """A small same-family draft model with its own slot-aligned cache.

    The drafter owns a slab ``CacheManager`` over the DRAFT model's cache
    shapes, one slot per target slot.  ``on_admit`` prefills the prompt
    through the draft executor; ``propose_all`` runs K+1 batched greedy
    decode steps (feeding each slot's last committed token, then its own
    proposals) — the extra (K+1-th) feed integrates the K-th proposal's
    K/V so a full acceptance (commit of K+1 tokens) still leaves the draft
    cache covering every committed position; ``observe_commit`` rewinds
    the draft ``cache_len`` to the committed context length, which IS the
    rollback (a slab cache masks everything past ``cache_len``).

    All device work (prefill/decode traces, cache allocation, placement)
    goes through a ``serving.executor.Executor`` built for the draft
    config — over the target's mesh when one is active, so drafting
    composes with tensor-parallel serving.
    """

    name = "model"

    def __init__(self, draft_cfg, executor, n_slots: int, cache_T: int,
                 num_draft_tokens: int, target_cfg=None, telemetry=None):
        if target_cfg is not None:
            if draft_cfg.family != target_cfg.family:
                raise ValueError(
                    f"draft family {draft_cfg.family!r} != target family "
                    f"{target_cfg.family!r}: the draft must propose from "
                    f"the same token space")
            if draft_cfg.vocab_size != target_cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {draft_cfg.vocab_size} != target vocab "
                    f"{target_cfg.vocab_size}")
        from repro.serving.cache_manager import CacheManager
        from repro.serving.telemetry import NULL_TELEMETRY
        self.cfg = draft_cfg
        self.executor = executor
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        self.k = int(num_draft_tokens)
        # the draft cache must absorb the full speculative overhang
        # (cache_len transiently reaches committed + K + 1 during a
        # proposal run) — size it past the target's worst case
        self.cm = CacheManager(draft_cfg, n_slots, cache_T + self.k + 1,
                               executor=executor)
        self.n_slots = n_slots
        self._decode = executor.decode_sample_fn(0.0)   # greedy, slab
        self._last: Dict[int, int] = {}                 # slot -> last fed tok
        self._zero_keys = np.zeros((n_slots, 2), np.uint32)
        self._zero_counts = np.zeros(n_slots, np.uint32)

    # -- lifecycle ----------------------------------------------------------

    def on_admit(self, slot: int, req):
        self.cm.alloc(slot)     # draft slots mirror target slots 1:1
        # right-pad the prompt to its pow2 bucket (ragged prefill gathers
        # nothing — only the cache matters here) so draft prefill compiles
        # O(log S) shape variants, not one per distinct prompt length
        from repro.serving.scheduler import prefill_bucket_len
        L = req.prompt_len
        pad_to = prefill_bucket_len(L, self.cm.cache_T)
        toks = np.zeros((1, pad_to), np.int32)
        toks[0, :L] = np.asarray(req.prompt, np.int32)
        with self.telemetry.span("draft_prefill", slot=slot, pad_to=pad_to):
            self.telemetry.count("h2d_bytes", toks.nbytes)
            _, cache = self.executor.prefill({"tokens": toks},
                                             self.cm.cache_T,
                                             prompt_lens=np.asarray([L]))
            self.cm.insert(slot, cache, L)

    def on_free(self, slot: int):
        if self.cm._occupied[slot]:
            self.cm.free(slot)
        self._last.pop(slot, None)

    def observe_commit(self, slot: int, committed_len: int):
        # slab rollback: everything past cache_len is masked, so rewinding
        # the position IS the rollback
        self.cm.lengths[slot] = committed_len

    # -- drafting -----------------------------------------------------------

    def propose_all(self, requests, caps):
        self.faults.check("drafter")
        slots = list(requests.keys())
        if not slots:
            return {}
        feed = np.zeros(self.n_slots, np.int32)
        for s, req in requests.items():
            feed[s] = req.tokens[-1]        # last committed, not yet fed
        rows = []
        with self.telemetry.span("draft_propose", n_slots=len(slots),
                                 k=self.k):
            for _ in range(self.k + 1):
                step = {"tokens": jnp.asarray(feed[:, None]),
                        "cache_len": self.cm.cache_len_vector()}
                self.telemetry.count("h2d_bytes",
                                     int(step["tokens"].nbytes)
                                     + int(step["cache_len"].nbytes))
                toks, new_cache = self._decode(self.cm.cache, step,
                                               jnp.asarray(self._zero_keys),
                                               jnp.asarray(self._zero_counts))
                self.cm.update(new_cache)
                self.cm.advance(slots)
                feed = np.asarray(toks, np.int32).copy()
                self.telemetry.count("d2h_bytes", feed.nbytes)
                rows.append(feed)
        grid = np.stack(rows, axis=1)       # (n_slots, K+1) greedy chain
        return {s: grid[s, :min(self.k, caps.get(s, self.k))].astype(np.int32)
                for s in slots}


def make_drafter(serve_cfg, engine, *, n_slots: int, cache_T: int,
                 telemetry=None) -> Optional[Drafter]:
    """Build the drafter selected by ``ServeConfig.draft`` for one serve
    loop (``None`` for ``draft='none'``).  The model drafter's executor is
    created by the engine (``ServingEngine.draft_executor``) so its traces
    ride the same mesh/backend scoping as the target's; ``telemetry`` (the
    loop's handle) gives the model drafter spans + byte counters."""
    draft = getattr(serve_cfg, "draft", "none") or "none"
    if draft == "none":
        return None
    from repro.models import api
    if not api.supports_verify(engine.cfg):
        raise ValueError(
            f"family {engine.cfg.family!r} has no multi-token verify path: "
            f"speculative decoding needs position-indexed KV that can be "
            f"rewound on rejection; serve with draft='none'")
    if serve_cfg.temperature > 0:
        raise ValueError(
            "speculative decoding is greedy-only (temperature == 0): the "
            "accept rule compares argmax streams; temperature sampling "
            "would need rejection resampling")
    k = int(serve_cfg.num_draft_tokens)
    if k < 1:
        raise ValueError("num_draft_tokens must be >= 1 when drafting")
    if draft == "prompt_lookup":
        return PromptLookupDrafter(k)
    if draft == "model":
        executor = engine.draft_executor
        if executor is None:
            raise ValueError(
                "draft='model' needs a draft model: construct the engine "
                "with draft_cfg=<small ArchConfig> and draft_params")
        return ModelDrafter(engine.draft_cfg, executor, n_slots, cache_T,
                            k, target_cfg=engine.cfg, telemetry=telemetry)
    raise ValueError(f"unknown draft {draft!r}; expected "
                     f"'none', 'prompt_lookup' or 'model'")
