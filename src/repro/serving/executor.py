"""Serving execution layer: device placement, jit tracing, donation, meshes.

The engine (``serving/engine.py``) is pure host-side orchestration —
admission, scheduling, preemption, token bookkeeping.  Everything
device-shaped lives here, behind one ``Executor`` interface:

  * **jit tracing** — every compiled entry point (prefill, fused
    decode+sample step, multi-token decode scan, cache surgery) is traced
    under the config's ``matmul_backend`` (``core.bp_matmul`` dispatch), so
    ``bp_*`` contractions route through the fused Pallas kernel / XLA
    oracle exactly as before the engine/executor split.
  * **buffer donation** — the pooled decode cache is donated
    (``donate_argnums``) into the decode step, the decode scan chunk, and
    the ``slot_insert``/``paged_insert``/``copy_block`` surgery ops: per-step
    KV updates and admissions alias the cache buffer in place instead of
    allocating a second cache-sized copy (``tests/test_executor.py`` pins
    this with an HLO aliasing regression test).
  * **device placement** — params are placed once at construction; caches
    are allocated through the executor so their residency/sharding is an
    executor decision, not an engine one.

Two executors ship behind the interface:

  * :class:`SingleDeviceExecutor` — the default: plain jit on the default
    device (the pre-split behavior).
  * :class:`MeshExecutor` — tensor-parallel serving over a
    ``("data", "model")`` jax mesh.  Pre-quantized weights are TP-sharded
    over ``"model"`` (``distributed.sharding.param_specs``, serve recipe:
    last dim of every dense kernel), the slab KV cache is sharded per the
    existing ``decode`` logical-axis recipe (slot/batch axis over
    ``"data"``, KV sequence axis over ``"model"`` — split-KV decode), and
    the block-paged cache + block tables stay replicated
    (``api.paged_cache_logical_axes``).  Every trace runs inside the mesh +
    ``decode`` recipe scope, so the model's ``shard()`` constraints engage.
    Kernel backends stay active under the mesh: the dispatch sites wrap the
    Pallas kernels in ``shard_map`` (TP column / split-K matmul partitions,
    split-KV paged attention with an (m, l, acc) cross-shard softmax
    combine — ``kernels/*/ops.py``), so ``matmul_backend="kernel"`` means
    the kernel on every executor.  Greedy outputs are token-identical to
    single-device execution for both backends
    (``tests/test_sharded_serving.py``, ``tests/test_mesh_kernels.py``).

``params`` may be None for cache-only use: the cache managers build a
default executor when constructed directly (tests); the model entry points
then raise.
"""

from __future__ import annotations

import contextlib
import functools
from typing import Dict, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import bp_matmul
from repro.core import probe as core_probe
from repro.distributed import sharding as shd
from repro.models import api


class Executor:
    """Execution-layer interface + the shared jit/donation machinery.

    Subclasses override the placement hooks (``_place_params``,
    ``_place_cache``, ``put``) and the trace scope (``_scopes``); the entry
    points themselves are layout-agnostic.
    """

    def __init__(self, cfg, params=None,
                 matmul_backend: Optional[str] = None):
        from repro.serving import telemetry as _telemetry
        from repro.serving import faults as _faults
        self.cfg = cfg
        self.matmul_backend = (getattr(cfg, "matmul_backend", "auto")
                               if matmul_backend is None else matmul_backend)
        # observability handle: the serve loop attaches its own via
        # ``set_telemetry`` so ``put`` transfers count against the run;
        # default is the shared no-op handle (zero overhead)
        self.telemetry = _telemetry.NULL_TELEMETRY
        # fault-injection handle, threaded exactly like telemetry; checks
        # fire BEFORE a jit dispatch so an injected fault never consumes
        # the donated cache (retry-safe by construction)
        self.faults = _faults.NULL_INJECTOR
        # sparsity-probe handle, threaded the same way; only consulted when
        # the serve loop asks for probed step-fn variants
        from repro.serving import probe as _probe
        self.probe = _probe.NULL_PROBE
        self._params = (self._place_params(params)
                        if params is not None else None)
        self._jits: Dict[tuple, object] = {}

    def set_telemetry(self, telemetry) -> None:
        """Attach a serve loop's telemetry handle (byte counters / spans);
        None reverts to the shared no-op handle."""
        from repro.serving import telemetry as _telemetry
        self.telemetry = (telemetry if telemetry is not None
                          else _telemetry.NULL_TELEMETRY)

    def set_faults(self, injector) -> None:
        """Attach a fault injector (None reverts to the no-op handle)."""
        from repro.serving import faults as _faults
        self.faults = (injector if injector is not None
                       else _faults.NULL_INJECTOR)

    def set_probe(self, probe) -> None:
        """Attach a sparsity probe (None reverts to the no-op handle)."""
        from repro.serving import probe as _probe
        self.probe = probe if probe is not None else _probe.NULL_PROBE

    def _require_probe_support(self):
        from repro.serving.probe import probe_supported
        if not probe_supported(self.cfg):
            raise ValueError(
                f"sparsity probe unsupported for family={self.cfg.family!r} "
                f"matmul_mode={self.cfg.matmul_mode!r}: the probe taps int8 "
                f"operands at the quantized-matmul boundary (causal-LM "
                f"family + bp_exact/bp_approx mode)")

    def reset(self) -> None:
        """Drop every cached jitted entry point (recovery path: after an
        executor failure the serve loop rebuilds its step functions from a
        clean trace cache and replays in-flight requests)."""
        self._jits.clear()

    def set_matmul_backend(self, backend: str) -> None:
        """Switch the matmul backend (degradation ladder: repeated kernel
        faults fall back to the XLA oracle) and invalidate every trace
        compiled under the old one."""
        self.matmul_backend = backend
        self._jits.clear()

    # -- placement hooks (single-device defaults) ---------------------------

    @property
    def mesh(self):
        """The mesh this executor runs over (None on a single device)."""
        return None

    def _place_params(self, params):
        return params

    def _place_cache(self, cache, *, paged: bool):
        return cache

    def put(self, x):
        """Host array -> device array (replicated under a mesh); the bytes
        moved count against the attached telemetry handle."""
        x = jnp.asarray(x)
        self.telemetry.count("h2d_bytes", getattr(x, "nbytes", 0))
        return x

    def _trace_scopes(self):
        """Context managers entered INSIDE the traced function — they set
        thread-local state consulted while tracing (backend dispatch,
        recipe rules), so on cached dispatches they cost nothing."""
        return [bp_matmul.use_matmul_backend(self.matmul_backend)]

    def _call_scopes(self):
        """Context managers entered around every CALL — only what cannot
        live inside a trace (mesh activation on the mesh executor).  Empty
        here, so the single-device hot loop is a bare jitted dispatch."""
        return []

    # -- jit plumbing -------------------------------------------------------

    def _jit(self, fn, **jit_kwargs):
        """jax.jit with the executor's scopes applied: trace-time scopes
        wrap the traced body (entered only while tracing), call-time scopes
        wrap the dispatch.  The returned callable keeps a ``.lower``
        (scoped the same way) so tests can inspect the compiled HLO —
        e.g. the donation/aliasing regression test."""
        trace_scopes = self._trace_scopes

        @functools.wraps(fn)
        def traced(*args, **kwargs):
            with contextlib.ExitStack() as stack:
                for ctx in trace_scopes():
                    stack.enter_context(ctx)
                return fn(*args, **kwargs)

        jitted = jax.jit(traced, **jit_kwargs)

        def call(*args, **kwargs):
            scopes = self._call_scopes()
            if not scopes:
                return jitted(*args, **kwargs)
            with contextlib.ExitStack() as stack:
                for ctx in scopes:
                    stack.enter_context(ctx)
                return jitted(*args, **kwargs)

        def lower(*args, **kwargs):
            with contextlib.ExitStack() as stack:
                for ctx in self._call_scopes():
                    stack.enter_context(ctx)
                return jitted.lower(*args, **kwargs)

        call.lower = lower
        return call

    def _get(self, key, build):
        fn = self._jits.get(key)
        if fn is None:
            fn = build()
            self._jits[key] = fn
        return fn

    def _require_params(self):
        if self._params is None:
            raise ValueError(
                "this executor was built without params (cache-only use); "
                "model entry points are unavailable")

    # -- model entry points -------------------------------------------------

    @property
    def params(self):
        """The placed (and, upstream, pre-quantized) model params."""
        return self._params

    def prefill(self, batch, cache_T: int, prompt_lens=None,
                probed: bool = False):
        """Compiled prefill; ``prompt_lens`` selects the ragged right-padded
        variant (per-row last-position logits, pow2 prefill buckets).
        ``probed=True`` jits a separate variant whose body runs under the
        sparsity tap and additionally returns the fused
        ``(n_layers[+1], N_STATS)`` activation stats."""
        self._require_params()
        self.faults.check("prefill")
        cfg = self.cfg
        if probed:
            self._require_probe_support()

        def run(p, b, t, lens=None):
            if not probed:
                return api.prefill(p, cfg, b, t, prompt_lens=lens)
            with core_probe.probe_tap():
                logits, cache = api.prefill(p, cfg, b, t, prompt_lens=lens)
                stats = core_probe.collect()
            return logits, cache, stats

        if prompt_lens is None:
            fn = self._get(("prefill", bool(probed)), lambda: self._jit(
                lambda p, b, t: run(p, b, t), static_argnums=(2,)))
            return fn(self._params, batch, cache_T)
        fn = self._get(("prefill_ragged", bool(probed)), lambda: self._jit(
            lambda p, b, t, lens: run(p, b, t, lens), static_argnums=(2,)))
        return fn(self._params, batch, cache_T, jnp.asarray(prompt_lens))

    def decode_step(self, step):
        """One raw decode dispatch (logits leave the device; no sampling
        fusion, no donation) — the legacy-loop comparison path used by
        ``benchmarks/decode_latency.py`` and logits-level tests."""
        self._require_params()
        cfg = self.cfg
        fn = self._get(("decode_step",), lambda: self._jit(
            lambda p, s: api.decode_step(p, cfg, s)))
        return fn(self._params, step)

    def decode_sample_fn(self, temperature: float, paged: bool = False,
                         probed: bool = False):
        """``fn(cache, step, keys, counts) -> (tokens, new_cache)`` for the
        continuous path: decode + per-slot sampling fused into ONE dispatch
        (only the (n_slots,) sampled tokens cross to the host, never the
        logits), with the cache buffer DONATED — the per-step KV update
        aliases the pool instead of copying it.  ``paged`` routes through
        the block-table decode step (``step`` then carries
        ``block_tables``).  ``probed=True`` jits a separate tapped variant
        returning ``(tokens, new_cache, stats)`` (donation unchanged)."""
        self._require_params()
        cfg = self.cfg
        if probed:
            self._require_probe_support()

        def build():
            decode = api.decode_step_paged if paged else api.decode_step

            def step_fn(p, cache, step, keys, counts):
                step = dict(step, cache=cache)
                # optional fault-injection mask (n_slots,) bool: NaN the
                # whole logit row for flagged slots (exercises the guard)
                nan_mask = step.pop("nan_mask", None)
                with contextlib.ExitStack() as tap:
                    if probed:
                        tap.enter_context(core_probe.probe_tap())
                    logits, new_cache = decode(p, cfg, step)
                    stats = core_probe.collect() if probed else None
                if nan_mask is not None:
                    logits = jnp.where(nan_mask[:, None], jnp.nan, logits)
                # pin the output layout to the input layout so the donated
                # buffer aliases instead of resharding (no-op off-mesh)
                new_cache = api.shard_cache(cfg, new_cache, paged=paged)
                if temperature <= 0:
                    tok = jnp.argmax(logits, axis=-1)
                else:
                    ks = jax.vmap(jax.random.fold_in)(keys, counts)
                    tok = jax.vmap(jax.random.categorical)(
                        ks, logits / temperature)
                # NaN guard, fused into the step: a non-finite logit row
                # yields the -1 sentinel (argmax/categorical are always
                # >= 0) so the loop can fail ONLY the affected slot
                ok = jnp.isfinite(logits).all(axis=-1)
                tok = jnp.where(ok, tok, -1)
                if probed:
                    return tok.astype(jnp.int32), new_cache, stats
                return tok.astype(jnp.int32), new_cache

            jitted = self._jit(step_fn, donate_argnums=(1,))

            def fn(cache, step, keys, counts):
                self.faults.check("step")
                self.faults.delay()
                return jitted(self._params, cache, step, keys, counts)

            fn.lower = lambda cache, step, keys, counts: jitted.lower(
                self._params, cache, step, keys, counts)
            return fn

        return self._get(("decode_sample", float(temperature), bool(paged),
                          bool(probed)), build)

    def verify_sample_fn(self, paged: bool = False, probed: bool = False):
        """``fn(cache, step) -> (greedy (B, S) int32 tokens, new_cache)``
        for the speculative path: ONE forward pass appends the S fed tokens
        (last committed + drafts) at per-slot positions and the per-position
        greedy argmax is fused into the dispatch — only the (B, S) token
        grid crosses to the host, never (B, S, V) logits.  The cache buffer
        is donated exactly like the decode step.  Greedy-only by design:
        the accept rule compares argmax streams, which is what makes
        speculative outputs token-identical to non-speculative greedy.
        ``probed=True``: tapped variant returning (tokens, cache, stats)."""
        self._require_params()
        cfg = self.cfg
        if probed:
            self._require_probe_support()

        def build():
            verify = api.verify_step_paged if paged else api.verify_step

            def step_fn(p, cache, step):
                step = dict(step, cache=cache)
                nan_mask = step.pop("nan_mask", None)
                with contextlib.ExitStack() as tap:
                    if probed:
                        tap.enter_context(core_probe.probe_tap())
                    logits, new_cache = verify(p, cfg, step)
                    stats = core_probe.collect() if probed else None
                if nan_mask is not None:
                    logits = jnp.where(nan_mask[:, None, None], jnp.nan,
                                       logits)
                new_cache = api.shard_cache(cfg, new_cache, paged=paged)
                tok = jnp.argmax(logits, axis=-1)
                # same -1 sentinel as the decode step, per (slot, position)
                ok = jnp.isfinite(logits).all(axis=-1)
                tok = jnp.where(ok, tok, -1)
                if probed:
                    return tok.astype(jnp.int32), new_cache, stats
                return tok.astype(jnp.int32), new_cache

            jitted = self._jit(step_fn, donate_argnums=(1,))

            def fn(cache, step):
                self.faults.check("step")
                self.faults.delay()
                return jitted(self._params, cache, step)

            fn.lower = lambda cache, step: jitted.lower(self._params, cache,
                                                        step)
            return fn

        return self._get(("verify_sample", bool(paged), bool(probed)), build)

    def decode_scan_fn(self, chunk: int, temperature: float,
                       eos_id: Optional[int]):
        """``fn(tok, cache, done, key, pos0, i0) -> (tok, cache, done, key,
        tokens (chunk, B))`` for the static path: a jitted ``lax.scan`` over
        ``chunk`` decode steps with sampling + EOS masking folded in and the
        cache donated across the dispatch."""
        self._require_params()
        cfg = self.cfg

        def build():
            def scan_fn(p, tok, cache, done, key, pos0, i0):
                def body(carry, j):
                    tok, cache, done, key = carry
                    if eos_id is not None:
                        done = done | (tok == eos_id)
                    step = {"tokens": tok[:, None], "cache": cache,
                            "cache_len": (pos0 + j).astype(jnp.int32)}
                    logits, cache = api.decode_step(p, cfg, step)
                    key = jax.random.fold_in(key, i0 + j)
                    if temperature <= 0:
                        new = jnp.argmax(logits, axis=-1)
                    else:
                        new = jax.random.categorical(
                            key, logits / temperature, axis=-1)
                    new = new.astype(tok.dtype)
                    if eos_id is not None:
                        new = jnp.where(done, eos_id, new)
                    return (new, cache, done, key), new

                carry, toks = jax.lax.scan(
                    body, (tok, cache, done, key), jnp.arange(chunk))
                tok, cache, done, key = carry
                cache = api.shard_cache(cfg, cache)
                return tok, cache, done, key, toks

            jitted = self._jit(scan_fn, donate_argnums=(2,))
            return lambda tok, cache, done, key, pos0, i0: jitted(
                self._params, tok, cache, done, key, pos0, i0)

        return self._get(("decode_scan", int(chunk), float(temperature),
                          eos_id), build)

    # -- cache allocation / surgery (params-free) ---------------------------

    def zeros_cache(self, n_slots: int, cache_T: int):
        """Allocate the pooled slab decode cache, placed per this
        executor's layout."""
        return self._place_cache(api.zeros_cache(self.cfg, n_slots, cache_T),
                                 paged=False)

    def zeros_paged_cache(self, num_blocks: int, block_size: int):
        return self._place_cache(
            api.zeros_paged_cache(self.cfg, num_blocks, block_size),
            paged=True)

    def slot_insert(self, pool, src, slot: int, src_index: int = 0):
        """Install request ``src_index`` of a prefill cache into ``slot`` of
        the pooled cache; the pool buffer is donated (in-place surgery, no
        second pool-sized allocation)."""
        self.faults.check("oom")
        cfg = self.cfg
        fn = self._get(("slot_insert",), lambda: self._jit(
            lambda pool, src, slot, i: api.shard_cache(
                cfg, api.slot_insert(cfg, pool, src, slot, i)),
            donate_argnums=(0,)))
        return fn(pool, src, jnp.int32(slot), jnp.int32(src_index))

    def paged_insert(self, pages, src, block_ids, src_index: int = 0):
        """Scatter a prefill cache into physical pages through ``block_ids``
        (trash-redirected entries skip shared blocks); pages donated."""
        self.faults.check("oom")
        cfg = self.cfg
        fn = self._get(("paged_insert",), lambda: self._jit(
            lambda pages, src, ids, i: api.shard_cache(
                cfg, api.paged_insert(cfg, pages, src, ids, i), paged=True),
            donate_argnums=(0,)))
        return fn(pages, src, jnp.asarray(block_ids, jnp.int32),
                  jnp.int32(src_index))

    def copy_block(self, pages, dst: int, src: int):
        """Copy physical page ``src`` -> ``dst`` (copy-on-write); pages
        donated."""
        self.faults.check("oom")
        cfg = self.cfg
        fn = self._get(("copy_block",), lambda: self._jit(
            lambda pages, dst, src: api.shard_cache(
                cfg,
                jax.tree.map(lambda p: p.at[:, dst].set(p[:, src]), pages),
                paged=True),
            donate_argnums=(0,)))
        return fn(pages, jnp.int32(dst), jnp.int32(src))


class SingleDeviceExecutor(Executor):
    """The default executor: plain jit on the default device."""


class MeshExecutor(Executor):
    """Tensor-parallel serving executor over a ``("data", "model")`` mesh.

    Weights TP-shard over ``"model"`` (last dims, ``param_specs`` serve
    recipe), the slab cache shards per the ``decode`` logical-axis recipe
    (slots over ``"data"``, KV sequence over ``"model"``), paged pages and
    block tables replicate.  Non-divisible dims silently stay replicated —
    the same model code runs on every mesh shape.
    """

    def __init__(self, cfg, params=None, *, mesh: Mesh,
                 matmul_backend: Optional[str] = None,
                 recipe_name: str = "decode"):
        self._mesh = mesh
        self._mesh_axes = shd.mesh_axes_dict(mesh)
        self.recipe_name = recipe_name
        super().__init__(cfg, params, matmul_backend)

    @property
    def mesh(self):
        return self._mesh

    def _trace_scopes(self):
        return [shd.recipe(self.recipe_name),
                bp_matmul.use_matmul_backend(self.matmul_backend)]

    def _call_scopes(self):
        # mesh activation cannot happen inside a trace; the recipe/backend
        # thread-locals ride in the traced body (_trace_scopes)
        return [shd.activate_mesh(self._mesh)]

    def _place_params(self, params):
        shardings = shd.named_shardings(params, self.recipe_name, self._mesh)
        return jax.tree.map(jax.device_put, params, shardings)

    def _place_cache(self, cache, *, paged: bool):
        specs = api.cache_pspec_tree(self.cfg, cache, self._mesh_axes,
                                     self.recipe_name, paged=paged)
        return jax.tree.map(
            lambda leaf, s: jax.device_put(
                leaf, NamedSharding(self._mesh, s)),
            cache, specs)

    def put(self, x):
        x = jnp.asarray(x)
        self.telemetry.count("h2d_bytes", getattr(x, "nbytes", 0))
        return jax.device_put(
            x, NamedSharding(self._mesh, P(*([None] * x.ndim))))


def make_serving_mesh(shape: Sequence[int]) -> Mesh:
    """A ``("data", "model")`` mesh over the first ``prod(shape)`` local
    devices — validation here, construction shared with
    ``launch.mesh.make_local_mesh`` (one version-portable mesh factory)."""
    shape = tuple(int(d) for d in shape)
    if len(shape) != 2:
        raise ValueError(f"mesh shape must be (data, model), got {shape!r}")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise ValueError(
            f"mesh shape {shape} needs {n} devices, found {len(devices)} "
            f"(on CPU, set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{n} before jax initializes)")
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh(*shape)


def make_executor(cfg, params=None, *, mesh: Optional[Mesh] = None,
                  mesh_shape: Optional[Tuple[int, int]] = None,
                  matmul_backend: Optional[str] = None) -> Executor:
    """Build the executor selected by ``mesh``/``mesh_shape`` (None/None ->
    single device)."""
    if mesh is None and mesh_shape is not None:
        mesh = make_serving_mesh(mesh_shape)
    if mesh is not None:
        return MeshExecutor(cfg, params, mesh=mesh,
                            matmul_backend=matmul_backend)
    return SingleDeviceExecutor(cfg, params, matmul_backend=matmul_backend)
