"""Serving engine: static batched generation + quasi-sync continuous batching.

Two paths over the same ``models/api.py`` init/prefill/decode surface:

  * ``generate(batch)`` — the static path: one prefill, then the whole batch
    decodes in lock-step until every sequence finishes.  The decode loop is
    device-resident: a jitted multi-token ``lax.scan`` advances ``chunk``
    tokens per dispatch with sampling (greedy argmax / temperature
    categorical) fused into the step, so only ``(B,)`` tokens and done flags
    cross to the host per chunk — never the full ``(B, V)`` logits.  EOS
    early-exit is checked at chunk boundaries and the output is trimmed to
    the exact step the per-token loop would have stopped at.
  * ``serve(requests)`` — continuous batching: a slot pool (``CacheManager``)
    decodes with per-slot sequence positions, finished sequences are evicted
    mid-flight, and waiting requests are admitted into freed slots under the
    ``QuasiSyncScheduler``'s bounded lead window (the paper's inter-group
    elasticity E, one level up).  Sampling is fused into the jitted decode
    step here too (one dispatch, ``(n_slots,)`` tokens to host).  Greedy
    outputs are token-identical to the static path; throughput on
    heterogeneous-length workloads is not.

Inference fast path: when a ``bp_*`` matmul mode is active the engine
pre-quantizes every dense kernel to int8 + per-channel scale once at
construction (``quantize_dense_params``), so no call path under
``serve``/``generate`` re-quantizes weights per decode step; and every
compiled entry point is traced under the config's ``matmul_backend`` so the
contractions route through the fused Pallas kernel on TPU
(``core.bp_matmul`` dispatch).

Supports all 10 architectures (KV caches for attention families, recurrent
state for RWKV/Zamba), greedy and temperature sampling, per-sequence EOS
early exit, and BitParticle deployment estimates (per-layer bit sparsity ->
modeled cycles/energy) when a quantized matmul mode is active.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bp_matmul
from repro.models import api
from repro.models.layers import quantize_dense_params
from repro.serving.block_pool import NoFreeBlocks, PagedCacheManager
from repro.serving.cache_manager import CacheManager, make_cache_manager
from repro.serving.queue import Request, RequestQueue, RequestState
from repro.serving.scheduler import (QuasiSyncScheduler, SchedulerConfig,
                                     prefill_bucket_len)


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    eos_id: Optional[int] = None
    cache_margin: int = 8             # extra cache slots beyond prompt+new
    decode_chunk: int = 8             # tokens per jitted decode scan dispatch
    # decode-cache backing store: "slab" reserves a worst-case cache_T
    # region per slot; "paged" allocates fixed-size KV blocks on demand
    # with prefix sharing + copy-on-write (position-indexed KV families)
    cache_backend: str = "slab"
    block_size: int = 16              # tokens per KV block (paged backend)


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray                # (B, <=max_new_tokens)
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def decode_tokens_per_s(self) -> float:
        n = self.tokens.shape[0] * self.tokens.shape[1]
        return n / max(self.decode_s, 1e-9)


@dataclasses.dataclass
class RequestResult:
    request_id: int
    tokens: np.ndarray                # generated tokens (incl. EOS if hit)
    prompt_len: int
    arrival_time: float
    ttft_steps: Optional[float]       # decode-step clock
    latency_steps: Optional[float]
    finish_reason: str


@dataclasses.dataclass
class ServeReport:
    results: List[RequestResult]
    prefill_s: float
    decode_s: float
    steps: int                        # batched decode steps executed
    n_syncs: int                      # admission (prefill) syncs
    n_rejected: int
    total_new_tokens: int
    slot_utilization: float           # mean occupied-slot fraction per step
    max_divergence: int               # max spread of per-slot positions
    deployment: Optional[dict] = None # BitParticle per-layer cycle/energy
    cache_backend: str = "slab"
    n_preemptions: int = 0            # paged: requests requeued on pool-dry
    prefix_hit_blocks: int = 0        # paged: trie hits adopted by reference
    cow_blocks: int = 0               # paged: copy-on-write block copies
    peak_blocks_in_use: int = 0       # paged: max live blocks at any step
    peak_active_slots: int = 0        # max concurrently-decoding requests

    @property
    def decode_tokens_per_s(self) -> float:
        if self.steps == 0:
            # everything finished at prefill: tokens were still generated
            # (one per admitted request) — report them over total wall time
            # instead of a blind 0.0
            return self.total_new_tokens / max(self.prefill_s + self.decode_s,
                                               1e-9)
        return self.total_new_tokens / max(self.decode_s, 1e-9)

    def tokens_by_request(self) -> Dict[int, np.ndarray]:
        return {r.request_id: r.tokens for r in self.results}


class ServingEngine:
    def __init__(self, arch_cfg, params, serve_cfg: Optional[ServeConfig] = None):
        self.cfg = arch_cfg
        self.serve_cfg = ServeConfig() if serve_cfg is None else serve_cfg
        self.matmul_backend = getattr(arch_cfg, "matmul_backend", "auto")
        if arch_cfg.matmul_mode in ("bp_exact", "bp_approx"):
            # weight-resident fast path: quantize every dense kernel to int8 +
            # per-channel scale ONCE, instead of per-channel re-quantizing the
            # float weights on every forward inside the decode hot loop
            # (idempotent — already-int8 params pass through untouched)
            params = quantize_dense_params(params)
        self.params = params
        self._prefill = self._jit(
            lambda p, b, t: api.prefill(p, self.cfg, b, t),
            static_argnums=(2,))
        # ragged variant: per-row last-position logits for power-of-two
        # prefill buckets (compiles per bucket shape — O(log S) variants)
        self._prefill_ragged = self._jit(
            lambda p, b, t, lens: api.prefill(p, self.cfg, b, t,
                                              prompt_lens=lens),
            static_argnums=(2,))
        self._decode = self._jit(lambda p, b: api.decode_step(p, self.cfg, b))
        # fused decode+sample entry points, built lazily per (temperature,
        # eos, chunk) so ``serve_cfg`` stays mutable between calls
        self._decode_sample_jits: Dict[tuple, object] = {}
        self._decode_scan_jits: Dict[tuple, object] = {}
        self._deployment_cache: Dict[int, Optional[dict]] = {}

    def _jit(self, fn, **jit_kwargs):
        """jax.jit with the config's matmul backend scoped around the trace,
        so bp_* contractions route through the fused Pallas kernel / XLA
        oracle as selected (``core.bp_matmul`` dispatch)."""
        backend = self.matmul_backend

        @functools.wraps(fn)
        def traced(*args, **kwargs):
            with bp_matmul.use_matmul_backend(backend):
                return fn(*args, **kwargs)

        return jax.jit(traced, **jit_kwargs)

    def _sample(self, logits, key):
        if self.serve_cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.serve_cfg.temperature,
                                      axis=-1)

    # ------------------------------------------------------------------
    # Device-resident decode steps (sampling fused into the jitted step)
    # ------------------------------------------------------------------

    def _decode_sample_fn(self, temperature: float, paged: bool = False):
        """Jitted (params, step, keys, counts) -> (tokens, new_cache) for the
        continuous path: decode + per-slot sampling in ONE dispatch, so only
        the (n_slots,) sampled tokens ever cross to the host — not the
        (n_slots, V) logits.  ``paged`` routes through the block-table
        decode step (``step`` then carries ``block_tables``)."""
        cache_key = (float(temperature), bool(paged))
        fn = self._decode_sample_jits.get(cache_key)
        if fn is not None:
            return fn
        decode = api.decode_step_paged if paged else api.decode_step

        def step_fn(p, step, keys, counts):
            logits, new_cache = decode(p, self.cfg, step)
            if temperature <= 0:
                tok = jnp.argmax(logits, axis=-1)
            else:
                ks = jax.vmap(jax.random.fold_in)(keys, counts)
                tok = jax.vmap(jax.random.categorical)(ks,
                                                       logits / temperature)
            return tok.astype(jnp.int32), new_cache

        fn = self._jit(step_fn)
        self._decode_sample_jits[cache_key] = fn
        return fn

    def _decode_scan_fn(self, chunk: int, temperature: float,
                        eos_id: Optional[int]):
        """Jitted multi-token decode for the static path: a ``lax.scan`` over
        ``chunk`` steps with sampling + EOS masking folded in.  Returns
        (last_tok, cache, done, key, tokens (chunk, B)); only the sampled
        tokens and done flags leave the device."""
        cache_key = (int(chunk), float(temperature), eos_id)
        fn = self._decode_scan_jits.get(cache_key)
        if fn is not None:
            return fn

        def scan_fn(p, tok, cache, done, key, pos0, i0):
            def body(carry, j):
                tok, cache, done, key = carry
                if eos_id is not None:
                    done = done | (tok == eos_id)
                step = {"tokens": tok[:, None], "cache": cache,
                        "cache_len": (pos0 + j).astype(jnp.int32)}
                logits, cache = api.decode_step(p, self.cfg, step)
                key = jax.random.fold_in(key, i0 + j)
                if temperature <= 0:
                    new = jnp.argmax(logits, axis=-1)
                else:
                    new = jax.random.categorical(key, logits / temperature,
                                                 axis=-1)
                new = new.astype(tok.dtype)
                if eos_id is not None:
                    new = jnp.where(done, eos_id, new)
                return (new, cache, done, key), new

            carry, toks = jax.lax.scan(
                body, (tok, cache, done, key), jnp.arange(chunk))
            tok, cache, done, key = carry
            return tok, cache, done, key, toks

        fn = self._jit(scan_fn)
        self._decode_scan_jits[cache_key] = fn
        return fn

    # ------------------------------------------------------------------
    # Static path (device-resident chunked decode)
    # ------------------------------------------------------------------

    def generate(self, batch: dict, key=None, *,
                 max_new_tokens: Optional[int] = None,
                 cache_T: Optional[int] = None) -> GenerationResult:
        """batch: {"tokens": (B, S_prompt) [, "src_embeds", vision...]}.

        ``max_new_tokens``/``cache_T`` override the config per call; pinning
        ``cache_T`` across calls keeps one compiled decode shape (outputs are
        unaffected — the padded cache region is masked)."""
        key = jax.random.PRNGKey(0) if key is None else key
        prompt = batch["tokens"]
        B, S = prompt.shape
        max_new = (self.serve_cfg.max_new_tokens if max_new_tokens is None
                   else max_new_tokens)
        if max_new < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if cache_T is None:
            cache_T = S + max_new + self.serve_cfg.cache_margin
        eos = self.serve_cfg.eos_id
        temperature = self.serve_cfg.temperature
        chunk_pref = max(1, self.serve_cfg.decode_chunk)

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cache_T)
        logits.block_until_ready()
        t1 = time.perf_counter()

        # device-resident decode: chunks of ``decode_chunk`` tokens advance
        # inside one jitted lax.scan each; per chunk only (B,) tokens + done
        # flags come back to the host (EOS early-exit at chunk boundaries)
        tok = self._sample(logits, key).astype(jnp.int32)
        done = jnp.zeros((B,), bool)
        chunks = [tok[:, None]]
        start, n_steps = 0, max_new - 1
        while start < n_steps:
            if eos is not None and bool(np.asarray(
                    (done | (tok == eos)).all())):
                break
            remaining = n_steps - start
            # tail chunks decompose into powers of two so the number of
            # compiled scan variants stays O(log decode_chunk) no matter how
            # max_new_tokens varies across calls (each distinct chunk length
            # is a separate whole-model compile)
            chunk = (chunk_pref if remaining >= chunk_pref
                     else 1 << (remaining.bit_length() - 1))
            scan = self._decode_scan_fn(chunk, temperature, eos)
            tok, cache, done, key, toks = scan(
                self.params, tok, cache, done, key,
                jnp.int32(S + start), jnp.int32(start))
            chunks.append(toks.T)
            start += chunk
        jax.block_until_ready(tok)
        t2 = time.perf_counter()

        mat = np.concatenate([np.asarray(c) for c in chunks], axis=1)
        if eos is not None:
            # trim to the step the per-token loop would have stopped at:
            # the first column where every row has already emitted EOS
            col_done = (np.cumsum(mat == eos, axis=1) > 0).all(axis=0)
            if col_done.any():
                mat = mat[:, :int(np.argmax(col_done)) + 1]
        return GenerationResult(tokens=mat,
                                prefill_s=t1 - t0, decode_s=t2 - t1,
                                steps=mat.shape[1])

    # ------------------------------------------------------------------
    # Continuous batching (quasi-sync path)
    # ------------------------------------------------------------------

    def _request_key_base(self, req: Request):
        """Per-request PRNG base; the n-th sampled token folds this with n
        (prefill samples with n=0, the decode step folds in the running
        token count — one consistent stream per request)."""
        return jax.random.fold_in(jax.random.PRNGKey(0), req.request_id)

    def _request_key(self, req: Request, n: int):
        return jax.random.fold_in(self._request_key_base(req), n)

    def _finished(self, req: Request, token: int) -> Optional[str]:
        eos = self.serve_cfg.eos_id
        if eos is not None and token == eos:
            return "eos"
        if len(req.tokens) >= req.max_new_tokens:
            return "length"
        return None

    def serve(self, requests: Sequence[Request], *, n_slots: int = 8,
              cache_T: Optional[int] = None,
              sched_cfg: Optional[SchedulerConfig] = None,
              extras: Optional[Dict[int, dict]] = None,
              num_blocks: Optional[int] = None) -> ServeReport:
        """Continuously-batched generation over a request stream.

        ``requests``: ``serving.queue.Request`` objects; ``arrival_time`` is
        interpreted on the decode-step clock (request i becomes visible once
        ``step >= arrival_time``), which makes runs deterministic and
        replayable.  ``extras`` optionally maps request_id -> extra prefill
        inputs (e.g. ``src_embeds`` for the audio family); per-request
        arrays are stacked on a new leading batch axis, so model inputs
        whose batch axis is not leading (the vlm family's M-RoPE
        ``positions``, shaped (3, B, S)) cannot ride through ``extras``.

        The decode cache is backed by ``ServeConfig.cache_backend``:
        ``"slab"`` reserves ``cache_T`` per slot; ``"paged"`` allocates
        ``block_size``-token blocks on demand (``num_blocks`` caps the pool
        — default matches the slab footprint) with automatic prefix sharing
        and LRU-backed preemption-and-requeue when the pool runs dry.
        Greedy outputs are token-identical across backends.
        """
        requests = sorted(requests, key=lambda r: (r.arrival_time, r.request_id))
        if cache_T is None:
            need = [r.prompt_len + r.max_new_tokens for r in requests] or [1]
            cache_T = max(need) + self.serve_cfg.cache_margin
        cm = make_cache_manager(self.cfg, n_slots, cache_T,
                                backend=self.serve_cfg.cache_backend,
                                block_size=self.serve_cfg.block_size,
                                num_blocks=num_blocks)
        paged = isinstance(cm, PagedCacheManager)
        if paged:
            # prefill caches must slice into whole blocks
            cache_T = cm.prefill_T
        sched_cfg = sched_cfg if sched_cfg is not None else SchedulerConfig()
        if sched_cfg.prefill_bucketing is None:
            # pow2 buckets need right-padding-safe prefill: attention KV
            # families without per-request extra inputs
            ragged_ok = self.cfg.family not in ("ssm", "hybrid") and not extras
            sched_cfg = dataclasses.replace(
                sched_cfg, prefill_bucketing="pow2" if ragged_ok else "exact")
        rq = RequestQueue(max_waiting=sched_cfg.max_waiting)
        sched = QuasiSyncScheduler(rq, cm, sched_cfg)
        ragged = sched.bucketing == "pow2"

        # deque: submit_arrivals pops from the head every decode step, and
        # list.pop(0) is O(n) — O(n^2) over long request streams
        arrivals = collections.deque(requests)
        active: Dict[int, Request] = {}           # slot -> request
        last_tok = np.zeros(n_slots, np.int32)    # per-slot last sampled token
        slot_keys = np.zeros((n_slots, 2), np.uint32)  # per-slot PRNG base
        now = 0.0
        prefill_s = 0.0
        t_decode = 0.0
        n_preempt = 0
        peak_active = 0
        decode_fn = self._decode_sample_fn(self.serve_cfg.temperature,
                                           paged=paged)

        def submit_arrivals():
            while arrivals and arrivals[0].arrival_time <= now:
                req = arrivals.popleft()
                if not cm.fits(req.prompt_len, req.max_new_tokens):
                    rq.reject(req, now)
                    continue
                rq.submit(req, now)

        def pick_victim() -> Optional[int]:
            """Preemption victim: the most recently admitted active request
            — it has the least progress to replay (oldest requests keep
            theirs; unreferenced prefix-cache blocks were already reclaimed
            LRU-first by the pool)."""
            cands = [(req.admitted_at or 0.0, req.request_id, slot)
                     for slot, req in active.items()]
            if not cands:
                return None
            return max(cands)[2]

        def preempt(slot: int):
            nonlocal n_preempt
            req = active.pop(slot)
            cm.free(slot)
            req.preempt()           # -> WAITING, tokens queued for replay
            rq.push_front(req)
            n_preempt += 1

        def insert_with_preemption(slot, cache, req, src_index):
            while True:
                try:
                    cm.insert(slot, cache, req.prompt_len,
                              src_index=src_index, tokens=req.prompt)
                    return
                except NoFreeBlocks:
                    # the inserting request holds no slot entry in `active`
                    # yet, so it can never preempt itself here
                    victim = pick_victim()
                    if victim is None:
                        raise RuntimeError(
                            "paged pool cannot hold a single admitted "
                            "request; increase num_blocks")
                    preempt(victim)

        def admit(group: List[Request]):
            nonlocal prefill_s
            for req in group:
                req.transition(RequestState.PREFILL)
                req.admitted_at = now
            lens = np.asarray([r.prompt_len for r in group], np.int32)
            # pow2 buckets: right-pad hetero prompts to one fused prefill
            # shape (valid rows are causal-mask-independent of the padding)
            pad_to = (prefill_bucket_len(int(lens.max()), cm.cache_T)
                      if ragged else int(lens.max()))
            toks = np.zeros((len(group), pad_to), np.int32)
            for j, r in enumerate(group):
                toks[j, :r.prompt_len] = r.prompt
            batch = {"tokens": toks}
            if extras:
                keys = sorted({k for r in group
                               for k in (extras.get(r.request_id) or {})})
                if "positions" in keys:
                    raise NotImplementedError(
                        "M-RoPE 'positions' is (3, B, S) — extras are "
                        "stacked on a leading batch axis and cannot "
                        "express it")
                for k in keys:
                    missing = [r.request_id for r in group
                               if k not in (extras.get(r.request_id) or {})]
                    if missing:
                        raise ValueError(
                            f"prefill group mixes requests with and without "
                            f"extra input {k!r} (missing for {missing})")
                    batch[k] = np.stack(
                        [np.asarray(extras[r.request_id][k]) for r in group])
            t0 = time.perf_counter()
            if ragged:
                logits, cache = self._prefill_ragged(self.params, batch,
                                                     cache_T,
                                                     jnp.asarray(lens))
            else:
                logits, cache = self._prefill(self.params, batch, cache_T)
            logits.block_until_ready()
            prefill_s += time.perf_counter() - t0
            for j, req in enumerate(group):
                if req.replay:
                    # preempted request: re-emit its original first token
                    tok = req.replay.pop(0)
                else:
                    tok = int(np.asarray(self._sample(
                        logits[j:j + 1], self._request_key(req, 0)))[0])
                req.tokens.append(tok)
                if req.first_token_at is None:
                    req.first_token_at = now
                reason = self._finished(req, tok)
                if reason is not None:
                    req.finish(now, reason)
                    continue
                slot = cm.alloc()
                insert_with_preemption(slot, cache, req, j)
                req.slot = slot
                req.transition(RequestState.DECODE)
                active[slot] = req
                last_tok[slot] = tok
                if self.serve_cfg.temperature > 0:
                    slot_keys[slot] = np.asarray(self._request_key_base(req))

        submit_arrivals()
        while arrivals or len(rq) or active:
            for group in sched.plan_admissions():
                admit(group)
            if not active:
                if not arrivals and not len(rq):
                    break
                if not len(rq) and arrivals:
                    # idle: jump the virtual clock to the next arrival
                    now = max(now, arrivals[0].arrival_time)
                    submit_arrivals()
                continue

            slots = list(active.keys())
            if paged:
                # every active slot must own a writable block for this
                # step's token: allocate at block boundaries, copy-on-write
                # shared tails; preempt-and-requeue when the pool runs dry
                while slots:
                    if cm.prepare_append(slots) is None:
                        break
                    preempt(pick_victim())   # newest admission goes
                    slots = list(active.keys())
                if not slots:
                    continue

            # fixed (n_slots, ...) shapes: decode + fold + sample fused into
            # ONE jitted dispatch, free-slot rows sampled and discarded; only
            # the (n_slots,) sampled tokens transfer to host, never logits
            counts = np.zeros(n_slots, np.uint32)
            for s in slots:
                counts[s] = len(active[s].tokens)
            step = {"tokens": jnp.asarray(last_tok[:, None]),
                    "cache": cm.cache,
                    "cache_len": cm.cache_len_vector()}
            if paged:
                step["block_tables"] = cm.block_tables_device()
            t0 = time.perf_counter()
            toks, new_cache = decode_fn(self.params, step,
                                        jnp.asarray(slot_keys),
                                        jnp.asarray(counts))
            toks.block_until_ready()
            t_decode += time.perf_counter() - t0
            cm.update(new_cache)
            cm.advance(slots)
            sched.observe_decode_step()
            peak_active = max(peak_active, len(slots))
            now += 1.0
            toks_np = np.asarray(toks)
            for slot in slots:
                req = active[slot]
                if req.replay:
                    # replaying a preemption: force the recorded token (the
                    # greedy resample equals it; this also pins temperature
                    # sampling to the original stream)
                    tok = req.replay.pop(0)
                else:
                    tok = int(toks_np[slot])
                req.tokens.append(tok)
                last_tok[slot] = tok
                reason = self._finished(req, tok)
                if reason is not None:
                    del active[slot]
                    cm.free(slot)
                    req.finish(now, reason)
            submit_arrivals()

        results = [
            RequestResult(
                request_id=r.request_id,
                tokens=np.asarray(r.tokens, np.int64),
                prompt_len=r.prompt_len,
                arrival_time=r.arrival_time,
                ttft_steps=r.ttft,
                latency_steps=r.latency,
                finish_reason=r.finish_reason or "unknown",
            )
            for r in sorted(requests, key=lambda r: r.request_id)
        ]
        total_new = sum(len(r.tokens) for r in results
                        if r.finish_reason != "rejected")
        return ServeReport(
            results=results,
            prefill_s=prefill_s,
            decode_s=t_decode,
            steps=sched.n_decode_steps,
            n_syncs=sched.n_syncs,
            n_rejected=rq.n_rejected,
            total_new_tokens=total_new,
            slot_utilization=sched.slot_utilization,
            max_divergence=sched.max_divergence,
            deployment=self.deployment_estimate(),
            cache_backend=self.serve_cfg.cache_backend,
            n_preemptions=n_preempt,
            prefix_hit_blocks=(cm.pool.n_prefix_hits if paged else 0),
            cow_blocks=(cm.pool.n_cow if paged else 0),
            # the pool's own high-water mark: catches allocation peaks hit
            # during prefill inserts, not just post-decode-step samples
            peak_blocks_in_use=(cm.pool.peak_live if paged else 0),
            peak_active_slots=peak_active,
        )

    # ------------------------------------------------------------------
    # BitParticle deployment estimate
    # ------------------------------------------------------------------

    def deployment_estimate(self, n_mc: int = 20_000) -> Optional[dict]:
        """Per-layer modeled cycles/energy of the quantized weights on the
        BitParticle array (None unless a bp_* matmul mode is active).
        Cached: it depends only on the immutable params."""
        mode = self.cfg.matmul_mode
        if mode not in ("bp_exact", "bp_approx"):
            return None
        if n_mc in self._deployment_cache:
            return self._deployment_cache[n_mc]
        from repro.core import cost_model as cost
        from repro.core.sparsity import bit_sparsity_sign_magnitude

        L = self.cfg.num_layers
        per_layer_bs: Dict[int, List[float]] = {}
        for leaf in jax.tree.leaves(self.params):
            if not (hasattr(leaf, "dtype") and leaf.dtype == jnp.int8):
                continue
            if leaf.ndim >= 2 and leaf.shape[0] == L:
                for l in range(L):
                    per_layer_bs.setdefault(l, []).append(
                        float(bit_sparsity_sign_magnitude(leaf[l])))
            else:
                per_layer_bs.setdefault(-1, []).append(
                    float(bit_sparsity_sign_magnitude(leaf)))
        if not per_layer_bs:
            return None
        layers = []
        for l in sorted(per_layer_bs):
            bs = float(np.mean(per_layer_bs[l]))
            layers.append({
                "layer": l,          # -1 = non-stacked weights (e.g. lm_head)
                "bit_sparsity": bs,
                "avg_cycles_per_mac": cost.modeled_avg_cycles(mode, bs, n=n_mc),
                "mac_energy_pj": cost.mac_energy_pj(mode, bs),
            })
        mean_bs = float(np.mean([e["bit_sparsity"] for e in layers]))
        est = {
            "mode": mode,
            "per_layer": layers,
            "mean_bit_sparsity": mean_bs,
            "mean_cycles_per_mac": float(
                np.mean([e["avg_cycles_per_mac"] for e in layers])),
            "mean_mac_energy_pj": float(
                np.mean([e["mac_energy_pj"] for e in layers])),
        }
        self._deployment_cache[n_mc] = est
        return est
