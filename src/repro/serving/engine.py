"""Batched serving engine: prefill + decode loop with per-request state.

Serves batched requests against any of the 10 architectures (KV caches for
attention families, recurrent state for RWKV/Zamba).  Supports greedy and
temperature sampling, per-sequence EOS early-exit masks, and reports
BitParticle deployment estimates (per-layer bit sparsity -> modeled
cycles/energy) when a quantized matmul mode is active.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import api


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    eos_id: Optional[int] = None
    cache_margin: int = 8             # extra cache slots beyond prompt+new


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray                # (B, <=max_new_tokens)
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def decode_tokens_per_s(self) -> float:
        n = self.tokens.shape[0] * self.tokens.shape[1]
        return n / max(self.decode_s, 1e-9)


class ServingEngine:
    def __init__(self, arch_cfg, params, serve_cfg: ServeConfig = ServeConfig()):
        self.cfg = arch_cfg
        self.params = params
        self.serve = serve_cfg
        self._prefill = jax.jit(
            lambda p, b, t: api.prefill(p, self.cfg, b, t),
            static_argnums=(2,))
        self._decode = jax.jit(lambda p, b: api.decode_step(p, self.cfg, b))

    def _sample(self, logits, key):
        if self.serve.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.serve.temperature,
                                      axis=-1)

    def generate(self, batch: dict, key=None) -> GenerationResult:
        """batch: {"tokens": (B, S_prompt) [, "src_embeds", vision...]}."""
        key = jax.random.PRNGKey(0) if key is None else key
        prompt = batch["tokens"]
        B, S = prompt.shape
        max_new = self.serve.max_new_tokens
        cache_T = S + max_new + self.serve.cache_margin

        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cache_T)
        logits.block_until_ready()
        t1 = time.perf_counter()

        out = []
        done = jnp.zeros((B,), bool)
        tok = self._sample(logits, key)
        for i in range(max_new):
            out.append(tok)
            if self.serve.eos_id is not None:
                done = done | (tok == self.serve.eos_id)
                if bool(done.all()):
                    break
            step = {"tokens": tok[:, None], "cache": cache,
                    "cache_len": jnp.int32(S + i)}
            logits, cache = self._decode(self.params, step)
            key = jax.random.fold_in(key, i)
            tok = self._sample(logits, key)
            if self.serve.eos_id is not None:
                tok = jnp.where(done, self.serve.eos_id, tok)
        jax.block_until_ready(out[-1])
        t2 = time.perf_counter()
        return GenerationResult(tokens=np.stack([np.asarray(t) for t in out], 1),
                                prefill_s=t1 - t0, decode_s=t2 - t1,
                                steps=len(out))
