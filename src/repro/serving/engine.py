"""Serving engine: static batched generation + quasi-sync continuous batching.

Two paths over the same ``models/api.py`` init/prefill/decode surface:

  * ``generate(batch)`` — the static path: one prefill, then the whole batch
    decodes in lock-step until every sequence finishes.  The decode loop is
    device-resident: a jitted multi-token ``lax.scan`` advances ``chunk``
    tokens per dispatch with sampling (greedy argmax / temperature
    categorical) fused into the step, so only ``(B,)`` tokens and done flags
    cross to the host per chunk — never the full ``(B, V)`` logits.  EOS
    early-exit is checked at chunk boundaries and the output is trimmed to
    the exact step the per-token loop would have stopped at.
  * ``serve(requests)`` — continuous batching: a slot pool (``CacheManager``)
    decodes with per-slot sequence positions, finished sequences are evicted
    mid-flight, and waiting requests are admitted into freed slots under the
    ``QuasiSyncScheduler``'s bounded lead window (the paper's inter-group
    elasticity E, one level up).  Greedy outputs are token-identical to the
    static path; throughput on heterogeneous-length workloads is not.

The engine is HOST-SIDE ORCHESTRATION ONLY.  Everything device-shaped —
jit tracing, matmul-backend scoping, device/mesh placement, cache
allocation, and buffer donation — lives behind ``serving/executor.py``:
the default :class:`SingleDeviceExecutor`, or a :class:`MeshExecutor`
running the same engine tensor-parallel over a ``("data", "model")`` mesh
(``ServeConfig.mesh_shape``) with token-identical greedy outputs.  One
``serve()`` call's loop state is a :class:`ServeLoop`: admission,
preemption, and decode stepping are its unit-testable methods.

Inference fast path: when a ``bp_*`` matmul mode is active the engine
pre-quantizes every dense kernel to int8 + per-channel scale once at
construction (``quantize_dense_params``) before handing params to the
executor, so no call path under ``serve``/``generate`` re-quantizes weights
per decode step.

Supports all 10 architectures (KV caches for attention families, recurrent
state for RWKV/Zamba), greedy and temperature sampling, per-sequence EOS
early exit, and BitParticle deployment estimates (per-layer bit sparsity ->
modeled cycles/energy) when a quantized matmul mode is active.
"""

from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.bp_matmul import resolve_matmul_backend
from repro.models.layers import quantize_dense_params
from repro.serving.block_pool import NoFreeBlocks, PagedCacheManager
from repro.serving.cache_manager import make_cache_manager
from repro.serving.executor import Executor, make_executor
from repro.serving.faults import (NULL_INJECTOR, DrafterFault, FaultInjector,
                                  InjectedFault, StepFault, StepTimeout)
from repro.serving.probe import NULL_PROBE, SparsityProbe, probe_supported
from repro.serving.queue import Request, RequestQueue, RequestState
from repro.serving.scheduler import (QuasiSyncScheduler, SchedulerConfig,
                                     prefill_bucket_len)
from repro.serving.telemetry import (SCHEMA_VERSION, Telemetry, percentiles,
                                     reduce_stream)

#: errors the serve loop survives via rebuild-and-replay recovery: injected
#: faults that exhausted their retry budget, watchdog aborts, and wrapped
#: real executor failures.  Everything else (config/user errors) raises.
RECOVERABLE_ERRORS = (InjectedFault, StepTimeout, StepFault)


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0          # 0 => greedy
    eos_id: Optional[int] = None
    cache_margin: int = 8             # extra cache slots beyond prompt+new
    decode_chunk: int = 8             # tokens per jitted decode scan dispatch
    # decode-cache backing store: "slab" reserves a worst-case cache_T
    # region per slot; "paged" allocates fixed-size KV blocks on demand
    # with prefix sharing + copy-on-write (position-indexed KV families)
    cache_backend: str = "slab"
    block_size: int = 16              # tokens per KV block (paged backend)
    # (data, model) mesh shape for tensor-parallel serving; None = single
    # device.  Requires prod(mesh_shape) visible jax devices.
    mesh_shape: Optional[Tuple[int, int]] = None
    # chunked prefill: bound the per-step prefill cost.  A prompt longer
    # than ``prefill_chunk`` is admitted on its first chunk only; the rest
    # of the prompt rides the multi-token verify step — at most
    # ``prefill_chunk`` prompt tokens per batched step, interleaved with
    # every other slot's decode — so a 10k-token prompt cannot stall
    # in-flight decoders for its whole prefill.  The final chunk's argmax
    # IS the first generated token (token-identical to one-shot prefill by
    # construction).  Greedy-only; needs a multi-token verify family
    # (dense/moe/vlm).  None = off (classic one-shot prefill).
    prefill_chunk: Optional[int] = None
    # speculative decoding: "none" | "prompt_lookup" (weight-free n-gram
    # drafter) | "model" (small same-family draft model — pass draft_cfg/
    # draft_params to the engine).  Greedy-only; outputs stay token-
    # identical to non-speculative greedy, steps shrink with acceptance.
    draft: str = "none"
    num_draft_tokens: int = 4         # K: drafts verified per step
    # observability: a ``serving.telemetry.Telemetry`` handle (metrics JSONL
    # / Chrome-trace / jax.profiler sinks).  None (the default) builds a
    # disabled no-op handle — no files written, token-identical outputs.
    telemetry: Optional[Telemetry] = None
    # hardware-cost observability: a ``serving.probe.SparsityProbe`` handle.
    # When enabled, probed step-fn variants measure activation bit/value
    # sparsity on-device (every ``probe_every`` decode steps + every
    # admission prefill) and each sample is priced through the paper's cost
    # models into an ``hw_estimate`` record.  None = NULL_PROBE, a strict
    # no-op pinned token-identical (docs/observability.md).
    probe: Optional[SparsityProbe] = None
    # -- robustness (docs/robustness.md) ------------------------------------
    # fault injection: a ``serving.faults.FaultInjector`` threaded to the
    # executor / cache managers / block pool / drafter exactly like the
    # telemetry handle.  None = the no-op NULL_INJECTOR (pinned a strict
    # no-op by token-identity tests).
    faults: Optional[FaultInjector] = None
    # bounded retry on transient (injected) step faults, with exponential
    # backoff base retry_backoff_s * 2**attempt (0 = immediate retry)
    max_step_retries: int = 2
    retry_backoff_s: float = 0.0
    # full rebuild-and-replay recoveries allowed per serve() before the
    # loop fails every in-flight request and returns
    max_recoveries: int = 3
    # wall-clock watchdog: abort any single dispatch exceeding this budget
    # (None = no watchdog; the aborted step recovers like a failed one)
    step_timeout_s: Optional[float] = None
    # degradation ladder thresholds: consecutive drafter faults before
    # speculation is disabled; recoveries before a non-XLA matmul backend
    # falls back to the XLA oracle; preemptions between ladder checks
    # before the lead window is halved (0 disables the rung)
    drafter_fault_limit: int = 2
    kernel_fault_limit: int = 2
    pool_pressure_limit: int = 8


def tokens_per_second(n_tokens: int, decode_s: float, prefill_s: float = 0.0,
                      steps: Optional[int] = None) -> float:
    """THE tokens/s rule for both engine paths: tokens over decode wall
    time — unless no decode step ran (everything finished at prefill), in
    which case the generated tokens are reported over total wall time
    instead of a blind 0.0."""
    if steps == 0:
        return n_tokens / max(prefill_s + decode_s, 1e-9)
    return n_tokens / max(decode_s, 1e-9)


# THE percentile rule lives in serving.telemetry now so the report and the
# benchmark scripts share one implementation; alias kept for existing callers.
_percentiles = percentiles


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray                # (B, <=max_new_tokens)
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def decode_tokens_per_s(self) -> float:
        n = self.tokens.shape[0] * self.tokens.shape[1]
        return tokens_per_second(n, self.decode_s, self.prefill_s,
                                 self.steps)


@dataclasses.dataclass
class RequestResult:
    request_id: int
    tokens: np.ndarray                # generated tokens (incl. EOS if hit)
    prompt_len: int
    arrival_time: float
    ttft_steps: Optional[float]       # decode-step clock
    latency_steps: Optional[float]
    finish_reason: str
    ttft_wall_s: Optional[float] = None   # wall clock, queue entry -> tok 0


@dataclasses.dataclass
class ServeReport:
    results: List[RequestResult]
    prefill_s: float
    decode_s: float
    steps: int                        # batched decode steps executed
    n_syncs: int                      # admission (prefill) syncs
    n_rejected: int
    total_new_tokens: int
    slot_utilization: float           # mean occupied-slot fraction per step
    max_divergence: int               # max spread of per-slot positions
    deployment: Optional[dict] = None # BitParticle per-layer cycle/energy
    cache_backend: str = "slab"
    n_preemptions: int = 0            # paged: requests requeued on pool-dry
    prefix_hit_blocks: int = 0        # paged: trie hits adopted by reference
    cow_blocks: int = 0               # paged: copy-on-write block copies
    peak_blocks_in_use: int = 0       # paged: max live blocks at any step
    peak_active_slots: int = 0        # max concurrently-decoding requests
    mesh_shape: Optional[Tuple[int, int]] = None  # executor mesh (None=1dev)
    # speculative decoding (draft != "none")
    draft: str = "none"
    drafted_tokens: int = 0           # drafts submitted to verify steps
    accepted_tokens: int = 0          # drafts the target's argmax confirmed
    committed_tokens_per_step: float = 0.0
    # wall-clock latency percentiles ({p50, p90, p99} seconds, or None when
    # no sample exists): TTFT from queue entry to first token, and the
    # inter-token gap pooled over every request's consecutive emissions
    ttft_wall: Optional[Dict[str, float]] = None
    itl_wall: Optional[Dict[str, float]] = None
    # queue-wait percentiles (wall seconds from queue entry to admission)
    # and per-SLO-class latency breakdown: class name -> {"n", "ttft_wall",
    # "itl_wall", "queue_wait"} — folded from the stream's per-request
    # records, so the JSONL file reproduces them exactly
    queue_wait: Optional[Dict[str, float]] = None
    slo_classes: Optional[Dict[str, dict]] = None
    # chunked prefill: prompt tokens ingested through bounded chunk steps
    chunk_tokens: int = 0
    # robustness (docs/robustness.md): lifecycle evictions + fault ledger
    n_cancelled: int = 0              # requests cancelled (API or chaos)
    n_timed_out: int = 0              # requests past deadline_s/ttft budget
    n_failed: int = 0                 # requests failed (NaN guard / abort)
    n_faults: int = 0                 # fault records (injected + detected)
    n_injected_faults: int = 0        # fault records with injected=True
    n_retries: int = 0                # transient-fault dispatch retries
    n_degrades: int = 0               # degradation-ladder transitions
    n_recoveries: int = 0             # rebuild-and-replay recoveries
    # hardware-cost probe: measured-traffic means over the run's
    # ``hw_estimate`` records (None when the probe was off / never sampled).
    # Unlike ``deployment`` — a static weights-only estimate — these numbers
    # come from the bit sparsity live requests actually exhibited.
    hw_measured: Optional[dict] = None

    @property
    def acceptance_rate(self) -> float:
        """Fraction of drafted tokens the verify step accepted."""
        if self.drafted_tokens == 0:
            return 0.0
        return self.accepted_tokens / self.drafted_tokens

    @property
    def decode_tokens_per_s(self) -> float:
        return tokens_per_second(self.total_new_tokens, self.decode_s,
                                 self.prefill_s, self.steps)

    def tokens_by_request(self) -> Dict[int, np.ndarray]:
        return {r.request_id: r.tokens for r in self.results}


class ServeLoop:
    """Host-side orchestration state of ONE ``serve()`` call.

    The former nested closures of ``ServingEngine.serve`` — arrival
    submission, victim picking, preemption, insert-with-preemption, and
    admission — are methods here so they can be unit-tested directly
    (``tests/test_serve_loop.py``) instead of only end-to-end.  The loop
    never touches jit or device placement: all device work goes through
    ``engine.executor``.
    """

    def __init__(self, engine: "ServingEngine", requests: Sequence[Request],
                 *, n_slots: int = 8, cache_T: Optional[int] = None,
                 sched_cfg: Optional[SchedulerConfig] = None,
                 extras: Optional[Dict[int, dict]] = None,
                 num_blocks: Optional[int] = None):
        self.engine = engine
        self.executor: Executor = engine.executor
        self.serve_cfg = engine.serve_cfg
        # observability: sinks ride the config's handle; a fresh disabled
        # handle otherwise.  The executors get the handle BEFORE any cache
        # is built so their host->device transfers count from step zero.
        self.tel: Telemetry = (self.serve_cfg.telemetry
                               if self.serve_cfg.telemetry is not None
                               else Telemetry())
        engine.executor.set_telemetry(self.tel)
        if engine.draft_executor is not None:
            engine.draft_executor.set_telemetry(self.tel)
        # the in-memory step-record stream: ALWAYS accumulated (host dicts,
        # negligible next to a device dispatch) so ``report()`` is a pure
        # reduction over it whether or not any sink is attached — the
        # aggregate counters and the stream can never disagree
        self.stream: List[dict] = []
        self._wall0 = time.perf_counter()
        self._h2d_mark = int(self.tel.counters.get("h2d_bytes", 0))
        self._d2h_mark = int(self.tel.counters.get("d2h_bytes", 0))
        # fault injection rides the config exactly like telemetry; the
        # executors get the handle before any cache op can fire a check
        self.faults: FaultInjector = (self.serve_cfg.faults
                                      if self.serve_cfg.faults is not None
                                      else NULL_INJECTOR)
        self.faults.bind(self._emit_injected)
        engine.executor.set_faults(self.faults)
        # sparsity probe rides the config exactly like telemetry/faults;
        # validate support up front so a misconfigured probe fails at loop
        # construction, not at the first probed trace
        self.probe: SparsityProbe = (self.serve_cfg.probe
                                     if self.serve_cfg.probe is not None
                                     else NULL_PROBE)
        if self.probe.enabled and not probe_supported(engine.cfg):
            raise ValueError(
                f"ServeConfig.probe: sparsity probe unsupported for "
                f"family={engine.cfg.family!r} "
                f"matmul_mode={engine.cfg.matmul_mode!r} (needs a causal-LM "
                f"family in bp_exact/bp_approx mode)")
        engine.executor.set_probe(self.probe)
        # weight bit-sparsity is static during a serve: computed once from
        # the pre-quantized int8 weights at engine level (cached there)
        self._weight_profile = (engine.weight_sparsity_profile()
                                if self.probe.enabled else None)
        requests = sorted(requests,
                          key=lambda r: (r.arrival_time, r.request_id))
        self.requests = requests
        if cache_T is None:
            need = [r.prompt_len + r.max_new_tokens for r in requests] or [1]
            cache_T = max(need) + self.serve_cfg.cache_margin
        # chunked prefill: validate up front so a misconfigured loop fails
        # at construction, not at the first long prompt
        self.prefill_chunk = self.serve_cfg.prefill_chunk
        if self.prefill_chunk is not None:
            if self.prefill_chunk < 1:
                raise ValueError("prefill_chunk must be >= 1 (or None)")
            from repro.models import api as _api
            if not _api.supports_verify(engine.cfg):
                raise ValueError(
                    f"family {engine.cfg.family!r} has no multi-token "
                    f"verify path: chunked prefill feeds prompt chunks "
                    f"through verify_step; serve with prefill_chunk=None")
            if self.serve_cfg.temperature > 0:
                raise ValueError(
                    "chunked prefill is greedy-only (temperature == 0): "
                    "the final chunk's first token comes from the verify "
                    "step's fused argmax")
            if extras:
                raise ValueError(
                    "chunked prefill does not compose with per-request "
                    "extra prefill inputs (extras ride the one-shot "
                    "prefill only)")
        # slot -> next unfed prompt position for requests mid-chunked-
        # prefill (cleared on preemption/eviction; replay restarts chunks)
        self.chunking: Dict[int, int] = {}
        # constructor args kept so ``recover()`` can rebuild a fresh store
        self.n_slots = n_slots
        self._cache_T_arg = cache_T
        self._num_blocks = num_blocks
        self.cm = self._build_cm()
        self.paged = isinstance(self.cm, PagedCacheManager)
        # prefill caches must slice into whole blocks on the paged store
        self.cache_T = self.cm.prefill_T if self.paged else cache_T
        sched_cfg = sched_cfg if sched_cfg is not None else SchedulerConfig()
        if sched_cfg.prefill_bucketing is None:
            # pow2 buckets need right-padding-safe prefill: attention KV
            # families without per-request extra inputs
            ragged_ok = (engine.cfg.family not in ("ssm", "hybrid")
                         and not extras)
            sched_cfg = dataclasses.replace(
                sched_cfg, prefill_bucketing="pow2" if ragged_ok else "exact")
        self.rq = RequestQueue(max_waiting=sched_cfg.max_waiting,
                               on_reject=self._on_reject)
        self.sched = QuasiSyncScheduler(self.rq, self.cm, sched_cfg,
                                        telemetry=self.tel)
        self.sched.prefill_chunk = self.prefill_chunk
        self.ragged = self.sched.bucketing == "pow2"
        self.extras = extras
        # deque: submit_arrivals pops from the head every decode step, and
        # list.pop(0) is O(n) — O(n^2) over long request streams
        self.arrivals = collections.deque(requests)
        self.active: Dict[int, Request] = {}      # slot -> request
        self.last_tok = np.zeros(n_slots, np.int32)
        self.slot_keys = np.zeros((n_slots, 2), np.uint32)
        self.now = 0.0
        self.prefill_s = 0.0
        self.decode_s = 0.0
        self.n_preemptions = 0
        self.peak_active = 0
        # robustness state: pending cancellations, recovery/ladder counters,
        # and whether any request carries a wall-clock deadline (the sweep
        # stays O(1) when nothing can cancel or expire)
        self._cancel_ids: Set[int] = set()
        self._any_deadlines = any(
            r.deadline_s is not None or r.ttft_deadline_s is not None
            for r in requests)
        self.n_recoveries = 0
        self._drafter_faults = 0
        self._pressure_mark = 0
        #: optional test/debug hook called after every loop iteration
        self.on_step_end: Optional[Callable[["ServeLoop"], None]] = None
        #: optional streaming hook: called once per FRESHLY emitted token
        #: with (request, token, index) — replay re-emissions after a
        #: preemption are suppressed (the client already received them),
        #: so a streaming consumer sees each position exactly once
        self.on_token: Optional[Callable[[Request, int, int], None]] = None
        # live-serving inbox: thread-safe dynamic submission for
        # ``run_forever`` (the front door's replica workers push here);
        # ``close()`` lets the loop drain and return
        self._inbox: List[Request] = []
        self._inbox_lock = threading.Lock()
        self._closed = False
        # cost hint (cost-aware routing): running mean of modeled
        # BitParticle array cycles per processed token over the probe's
        # ``hw_estimate`` samples (0.0 until the first sample)
        self._hw_cycles_sum = 0.0
        self._hw_tokens_sum = 0
        # speculative decoding: a drafter proposes up to K tokens per slot,
        # one multi-token verify step checks them all, slots commit a
        # VARIABLE 1..K+1 tokens per step (greedy-only, token-identical)
        from repro.serving.speculative import make_drafter
        self.drafter = make_drafter(self.serve_cfg, engine,
                                    n_slots=n_slots, cache_T=self.cache_T,
                                    telemetry=self.tel)
        self.draft_name = (self.drafter.name if self.drafter is not None
                           else "none")
        if self.drafter is not None:
            self.drafter.faults = self.faults
        self.n_drafted = 0
        self.n_accepted = 0
        self._bind_step_fns()
        mesh = self.executor.mesh
        self._emit("run",
                   cache_backend=str(self.serve_cfg.cache_backend),
                   n_slots=int(n_slots), cache_T=int(self.cache_T),
                   draft=self.draft_name,
                   temperature=float(self.serve_cfg.temperature),
                   mesh_shape=(None if mesh is None else
                               [int(d) for d in mesh.devices.shape]),
                   block_size=int(self.serve_cfg.block_size),
                   probe_every=int(self.probe.probe_every))

    def _build_cm(self):
        return make_cache_manager(self.engine.cfg, self.n_slots,
                                  self._cache_T_arg,
                                  backend=self.serve_cfg.cache_backend,
                                  block_size=self.serve_cfg.block_size,
                                  num_blocks=self._num_blocks,
                                  executor=self.engine.executor,
                                  telemetry=self.tel, faults=self.faults)

    def _bind_step_fns(self):
        """(Re-)fetch the jitted step entry points from the executor —
        called at construction and again after a recovery rebuild or a
        matmul-backend downgrade invalidates the executor's trace cache."""
        self._decode_fn = self.engine.executor.decode_sample_fn(
            self.serve_cfg.temperature, paged=self.paged)
        # the multi-token verify entry point serves BOTH speculation and
        # chunked prefill (a chunk step feeds known prompt tokens where
        # speculation feeds drafts); keep it bound while either needs it —
        # the ladder may null the drafter mid-run with chunking still on
        need_verify = (self.drafter is not None
                       or self.prefill_chunk is not None)
        if need_verify:
            self._verify_fn = self.engine.executor.verify_sample_fn(
                paged=self.paged)
        # probed variants are SEPARATE jits (the unprobed traces stay
        # byte-identical to a probe-less serve); sampled steps swap fns
        self._decode_probe_fn = self._verify_probe_fn = None
        if self.probe.enabled:
            self._decode_probe_fn = self.engine.executor.decode_sample_fn(
                self.serve_cfg.temperature, paged=self.paged, probed=True)
            if need_verify:
                self._verify_probe_fn = (
                    self.engine.executor.verify_sample_fn(paged=self.paged,
                                                          probed=True))

    # -- telemetry plumbing --------------------------------------------------

    def _emit(self, kind: str, **fields) -> dict:
        """Append one record to the step stream and forward it to the
        metrics sink.  Values must already be native Python scalars — the
        JSONL line and the in-memory record are the SAME dict, which is
        what makes the file reduction byte-equal to the live one."""
        rec = {"schema": SCHEMA_VERSION, "kind": kind,
               "ts_s": time.perf_counter() - self._wall0}
        rec.update(fields)
        self.stream.append(rec)
        self.tel.emit(rec)
        return rec

    def _on_reject(self, req: Request):
        self._emit("reject", step=int(self.sched.n_decode_steps),
                   request_id=int(req.request_id))

    def _step_clock(self) -> int:
        # the injector can fire during construction, before the scheduler
        # exists; everything after __init__ reads the real step clock
        sched = getattr(self, "sched", None)
        return int(sched.n_decode_steps) if sched is not None else 0

    def _emit_injected(self, site: str, **ctx) -> None:
        """Telemetry callback bound into the fault injector: every fired
        injection becomes a stream ``fault`` record with ``injected=True``
        (the chaos suite audits the stream 1:1 against the injector's
        ledger)."""
        self._emit("fault", step=self._step_clock(), site=site,
                   injected=True, **ctx)

    def _byte_deltas(self) -> Tuple[int, int]:
        """Host<->device bytes moved since the previous step record."""
        c = self.tel.counters
        h2d, d2h = int(c.get("h2d_bytes", 0)), int(c.get("d2h_bytes", 0))
        out = (h2d - self._h2d_mark, d2h - self._d2h_mark)
        self._h2d_mark, self._d2h_mark = h2d, d2h
        return out

    def _pool_gauges(self) -> dict:
        """Block-pool gauges for one step record (zeros on the slab store).
        Hit/CoW/peak counters are CUMULATIVE snapshots — monotone, so the
        stream reduction recovers the totals with a running max."""
        if not self.paged:
            return {"blocks_in_use": 0, "prefix_hit_blocks": 0,
                    "cow_blocks": 0, "peak_blocks_in_use": 0}
        pool = self.cm.pool
        return {"blocks_in_use": int(pool.n_live),
                "prefix_hit_blocks": int(pool.n_prefix_hits),
                "cow_blocks": int(pool.n_cow),
                "peak_blocks_in_use": int(pool.peak_live)}

    def _emit_hw(self, stats_np: np.ndarray, phase: str,
                 n_tokens: int = 1) -> None:
        """Fold one sampled step's device stats through the probe's cost
        models into an ``hw_estimate`` record plus Chrome-trace counter
        tracks (perfetto renders them alongside the phase spans).
        ``n_tokens`` is the tokens this step processed (prompt + committed
        + chunk-fed) — the denominator of the running cycles/token cost
        hint the front-door router reads for cost-aware routing."""
        fields = self.probe.fold(stats_np, self._weight_profile, phase)
        self._hw_cycles_sum += float(fields["array_cycles_per_step"])
        self._hw_tokens_sum += max(int(n_tokens), 1)
        self._emit("hw_estimate", step=int(self.sched.n_decode_steps),
                   **fields)
        self.tel.counter("sparsity",
                         act_bit=fields["act_bit_sparsity"],
                         act_value=fields["act_value_sparsity"],
                         weight_bit=fields["weight_bit_sparsity"])
        self.tel.counter("hw_model",
                         array_utilization=fields["array_utilization"],
                         cycles_bp_exact=fields["cycles"]["bp_exact"],
                         energy_bp_exact_pj=fields["mac_energy_pj"]
                         ["bp_exact"])

    @property
    def cost_hint_cycles_per_token(self) -> float:
        """Running mean of modeled BitParticle array cycles per processed
        token over the probe's sampled steps (0.0 with no sample / probe
        off) — the per-replica cost hint surfaced on router stats."""
        if self._hw_tokens_sum == 0:
            return 0.0
        return self._hw_cycles_sum / self._hw_tokens_sum

    # -- lifecycle: cancellation + deadlines --------------------------------

    def _live_requests(self) -> List[Request]:
        """Every request still in flight: not yet submitted, waiting, or
        active in a slot (terminal requests are no longer reachable)."""
        return (list(self.arrivals) + list(self.rq.peek())
                + list(self.active.values()))

    def _evict(self, slot: int) -> Request:
        """Remove ``slot``'s request from the batch and release every
        resource it holds (cache slot / block table, drafter state)."""
        req = self.active.pop(slot)
        self.chunking.pop(slot, None)
        self.cm.free(slot)
        if self.drafter is not None:
            self.drafter.on_free(slot)
        return req

    def sweep(self):
        """Run once per loop iteration BEFORE planning admissions: collect
        injector- and API-requested cancellations, then expire requests
        whose wall-clock deadline passed.  Evicted actives free their slot
        and blocks immediately, so the very next admission plan sees the
        reclaimed capacity."""
        if self.faults.enabled:
            live = [int(r.request_id) for r in self._live_requests()]
            self._cancel_ids.update(self.faults.cancel_requests(live))
        pending = self.engine._pending_cancels
        if pending:
            self._cancel_ids.update(pending)
            pending.clear()
        if self._cancel_ids:
            self._apply_cancels()
        if self._any_deadlines:
            self._apply_deadlines()

    def _finish_evicted(self, req: Request, reason: str, kind: str,
                        where: str, **fields):
        req.finish(self.now, reason)
        self._emit(kind, step=int(self.sched.n_decode_steps),
                   request_id=int(req.request_id), where=where, **fields)

    def _apply_cancels(self):
        ids, self._cancel_ids = self._cancel_ids, set()
        for req in [r for r in self.arrivals if int(r.request_id) in ids]:
            self.arrivals.remove(req)
            self._finish_evicted(req, "cancelled", "cancel", "arrivals")
        for req in [r for r in self.rq.peek() if int(r.request_id) in ids]:
            self.rq.remove(req)
            self._finish_evicted(req, "cancelled", "cancel", "waiting")
        for slot in [s for s, r in self.active.items()
                     if int(r.request_id) in ids]:
            req = self._evict(slot)
            self._finish_evicted(req, "cancelled", "cancel", "active")
        # ids for unknown/already-finished requests are dropped silently:
        # cancel() is idempotent and may race a natural finish

    def _apply_deadlines(self):
        wall = time.perf_counter()

        def expired(req: Request) -> Optional[str]:
            t0 = req.wall_submitted_at
            if t0 is None:
                return None       # not yet submitted: deadlines start then
            if (req.ttft_deadline_s is not None
                    and req.first_token_at is None
                    and wall - t0 >= req.ttft_deadline_s):
                return "ttft"
            if (req.deadline_s is not None
                    and wall - t0 >= req.deadline_s):
                return "total"
            return None

        for req in list(self.rq.peek()):
            which = expired(req)
            if which is not None:
                self.rq.remove(req)
                self._finish_evicted(req, "timeout", "timeout", "waiting",
                                     deadline=which)
        for slot in list(self.active):
            req = self.active[slot]
            which = expired(req)
            if which is not None:
                self._evict(slot)
                self._finish_evicted(req, "timeout", "timeout", "active",
                                     deadline=which)

    # -- fault-hardened dispatch --------------------------------------------

    def _with_watchdog(self, fn):
        """Run one device dispatch under the wall-clock watchdog.  The jit
        call runs in a worker thread; if it exceeds the budget the loop
        raises :class:`StepTimeout` and recovery rebuilds the executor —
        the stuck computation's results are never adopted."""
        budget = self.serve_cfg.step_timeout_s
        if budget is None:
            return fn()
        pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        try:
            fut = pool.submit(fn)
            try:
                return fut.result(timeout=budget)
            except concurrent.futures.TimeoutError:
                raise StepTimeout(
                    f"step exceeded the {budget:g}s watchdog budget"
                ) from None
        finally:
            pool.shutdown(wait=False, cancel_futures=True)

    def _dispatch(self, site: str, fn):
        """Fault boundary around one device dispatch: bounded retry with
        exponential backoff on injected transients (raised BEFORE the jit
        call, so the donated cache is untouched and a retry is safe), and
        real executor failures wrapped into :class:`StepFault` so ``run()``
        can tell recoverable infrastructure faults from plain bugs."""
        attempt = 0
        while True:
            try:
                return self._with_watchdog(fn)
            except StepTimeout:
                raise
            except InjectedFault as e:
                if attempt >= self.serve_cfg.max_step_retries:
                    raise
                attempt += 1
                self._emit("retry", step=int(self.sched.n_decode_steps),
                           site=str(getattr(e, "site", site)),
                           attempt=int(attempt))
                backoff = self.serve_cfg.retry_backoff_s
                if backoff > 0:
                    time.sleep(backoff * 2 ** (attempt - 1))
            except Exception as e:
                raise StepFault(site, e) from e

    def _maybe_inject_nan(self, step: dict, slots: List[int]) -> None:
        """Chaos only: poison the logits of injector-chosen slots with NaN
        inside the jitted step.  The mask key is added ONLY when an
        injector is live, so fault-free runs trace the exact same step
        structure as the seed."""
        if not self.faults.enabled:
            return
        bad = self.faults.nan_slots(slots)
        if not bad:
            return
        mask = np.zeros(self.n_slots, bool)
        mask[list(bad)] = True
        step["nan_mask"] = jnp.asarray(mask)

    def _fail_slot(self, slot: int):
        """The fused finite-logits guard flagged this slot (-1 sentinel):
        its logits were non-finite, so its stream cannot continue.  Fail
        just this request and release its resources — the batch survives."""
        req = self._evict(slot)
        req.finish(self.now, "failed")
        self._emit("fault", step=int(self.sched.n_decode_steps),
                   site="nan_guard", request_id=int(req.request_id),
                   slot=int(slot))

    # -- admission / preemption --------------------------------------------

    def submit_arrivals(self):
        """Move arrivals whose time has come into the waiting queue;
        requests that cannot ever fit the cache are rejected up front."""
        while self.arrivals and self.arrivals[0].arrival_time <= self.now:
            req = self.arrivals.popleft()
            req.wall_submitted_at = time.perf_counter()
            if not self.cm.fits(req.prompt_len, req.max_new_tokens):
                self.rq.reject(req, self.now)
                continue
            self.rq.submit(req, self.now)

    def pick_victim(self) -> Optional[int]:
        """Preemption victim: the most recently admitted active request —
        it has the least progress to replay (oldest requests keep theirs;
        unreferenced prefix-cache blocks were already reclaimed LRU-first
        by the pool)."""
        cands = [(req.admitted_at or 0.0, req.request_id, slot)
                 for slot, req in self.active.items()]
        if not cands:
            return None
        return max(cands)[2]

    def preempt(self, slot: int):
        """Evict ``slot``'s request back to the queue head with its
        generated tokens queued for token-exact replay."""
        req = self.active.pop(slot)
        # a mid-chunk preemption restarts chunked prefill on re-admission
        # (the emitted-token replay list still pins token identity)
        self.chunking.pop(slot, None)
        discarded = len(req.tokens)
        with self.tel.span("preempt", slot=slot,
                           request_id=req.request_id):
            self.cm.free(slot)
            if self.drafter is not None:
                self.drafter.on_free(slot)
            req.preempt()       # -> WAITING, tokens queued for replay
            self.rq.push_front(req)
        self.n_preemptions += 1
        self._emit("preempt", step=int(self.sched.n_decode_steps),
                   slot=int(slot), request_id=int(req.request_id),
                   discarded_tokens=int(discarded))
        # degradation ladder: sustained pool pressure (preemption churn)
        # halves the lead window — smaller admission bursts trade fusion
        # for fewer evictions
        lim = self.serve_cfg.pool_pressure_limit
        if (lim and self.n_preemptions - self._pressure_mark >= lim
                and self.sched.cfg.lead_window > 0):
            self._pressure_mark = self.n_preemptions
            new_e = self.sched.cfg.lead_window // 2
            self.sched.set_lead_window(new_e)
            self._emit("degrade", step=int(self.sched.n_decode_steps),
                       action="shrink_lead_window", lead_window=int(new_e))

    def insert_with_preemption(self, slot: int, cache, req: Request,
                               src_index: int,
                               length: Optional[int] = None):
        """Install a prefill cache into ``slot``, preempting actives (newest
        first) until the paged pool can cover the miss suffix.  ``length``
        is the prefilled prefix being installed (defaults to the full
        prompt; chunked admissions install only the first chunk)."""
        length = req.prompt_len if length is None else length
        while True:
            try:
                self.cm.insert(slot, cache, length,
                               src_index=src_index,
                               tokens=req.prompt[:length])
                return
            except NoFreeBlocks as e:
                # the inserting request holds no slot entry in `active`
                # yet, so it can never preempt itself here
                victim = self.pick_victim()
                if victim is None:
                    if isinstance(e, InjectedFault):
                        # injected exhaustion with nobody to preempt is an
                        # infrastructure fault — recoverable, not a sizing
                        # bug
                        raise
                    raise RuntimeError(
                        "paged pool cannot hold a single admitted "
                        "request; increase num_blocks")
                self.preempt(victim)

    def admit(self, group: List[Request], new_sync: bool = True):
        """Fused prefill of one admission group: run the prompts, sample
        (or replay) each request's first token, and install survivors into
        slots.  ``new_sync`` marks the group as opening a fresh admission
        sync in the metrics stream — ``run()`` passes True only for the
        first group of each ``plan_admissions()`` batch, so the stream's
        sync count matches the scheduler's."""
        engine = self.engine
        t_start = time.perf_counter()
        wall_admit = time.perf_counter()
        for req in group:
            req.transition(RequestState.PREFILL)
            req.admitted_at = self.now
            if req.wall_admitted_at is None:
                req.wall_admitted_at = wall_admit
        # chunked prefill: a long prompt is admitted on its FIRST chunk
        # only (bounded prefill cost); the remainder rides the multi-token
        # verify step, interleaved with every other slot's decode
        chunk = self.prefill_chunk
        eff = [r.prompt_len if chunk is None else min(r.prompt_len, chunk)
               for r in group]
        lens = np.asarray(eff, np.int32)
        # pow2 buckets: right-pad hetero prompts to one fused prefill
        # shape (valid rows are causal-mask-independent of the padding)
        pad_to = (prefill_bucket_len(int(lens.max()), self.cm.cache_T)
                  if self.ragged else int(lens.max()))
        toks = np.zeros((len(group), pad_to), np.int32)
        for j, r in enumerate(group):
            toks[j, :eff[j]] = r.prompt[:eff[j]]
        batch = {"tokens": toks}
        extras = self.extras
        if extras:
            keys = sorted({k for r in group
                           for k in (extras.get(r.request_id) or {})})
            if "positions" in keys:
                raise NotImplementedError(
                    "M-RoPE 'positions' is (3, B, S) — extras are "
                    "stacked on a leading batch axis and cannot "
                    "express it")
            for k in keys:
                missing = [r.request_id for r in group
                           if k not in (extras.get(r.request_id) or {})]
                if missing:
                    raise ValueError(
                        f"prefill group mixes requests with and without "
                        f"extra input {k!r} (missing for {missing})")
                batch[k] = np.stack(
                    [np.asarray(extras[r.request_id][k]) for r in group])
        self.tel.count("h2d_bytes", sum(int(np.asarray(v).nbytes)
                                        for v in batch.values()))
        t0 = time.perf_counter()

        probed = self.probe.enabled   # every admission prefill is sampled

        def dispatch():
            if self.ragged:
                out = self.executor.prefill(batch, self.cache_T,
                                            prompt_lens=lens, probed=probed)
            else:
                out = self.executor.prefill(batch, self.cache_T,
                                            probed=probed)
            out[0].block_until_ready()
            return out

        with self.tel.span("prefill", group_size=len(group), pad_to=pad_to):
            out = self._dispatch("prefill", dispatch)
        logits, cache = out[0], out[1]
        probe_stats = out[2] if probed else None
        wall = time.perf_counter()
        dispatch_s = wall - t0
        self.prefill_s += dispatch_s
        t_inst = time.perf_counter()
        n_emitted = 0
        with self.tel.span("install", group_size=len(group)):
            for j, req in enumerate(group):
                chunked = eff[j] < req.prompt_len
                tok = None
                if not chunked:
                    if req.replay:
                        # preempted request: re-emit its original first token
                        tok = req.replay.pop(0)
                    else:
                        arr = np.asarray(engine._sample(
                            logits[j:j + 1], engine._request_key(req, 0)))
                        self.tel.count("d2h_bytes", arr.nbytes)
                        tok = int(arr[0])
                    self._append_token(req, tok, wall)
                    n_emitted += 1
                    if req.first_token_at is None:
                        req.first_token_at = self.now
                    reason = engine._finished(req, tok)
                    if reason is not None:
                        req.finish(self.now, reason)
                        continue
                slot = self.cm.alloc()
                try:
                    self.insert_with_preemption(slot, cache, req, j,
                                                length=eff[j])
                except BaseException:
                    # never leak the slot: a failed install (injected OOM
                    # past its retries, recoverable exhaustion) must leave
                    # the pool exactly as it found it
                    self.cm.free(slot)
                    raise
                req.slot = slot
                self.active[slot] = req
                if chunked:
                    # no token yet: the request stays PREFILL while the
                    # remaining prompt rides the chunk steps; its first
                    # token comes from the FINAL chunk's argmax
                    self.chunking[slot] = eff[j]
                    continue
                req.transition(RequestState.DECODE)
                self.last_tok[slot] = tok
                if self.serve_cfg.temperature > 0:
                    self.slot_keys[slot] = np.asarray(
                        engine._request_key_base(req))
                if self.drafter is not None:
                    self.drafter.on_admit(slot, req)
        install_s = time.perf_counter() - t_inst
        if probe_stats is not None:
            # the stats array is the probe's only d2h traffic: count it
            # BEFORE the byte snapshot so this record carries it
            probe_stats = np.asarray(probe_stats)
            self.tel.count("d2h_bytes", int(probe_stats.nbytes))
        h2d, d2h = self._byte_deltas()
        self._emit("prefill", step=int(self.sched.n_decode_steps),
                   wall_s=time.perf_counter() - t_start,
                   phases={"dispatch_s": dispatch_s,
                           "install_s": install_s},
                   group_size=int(len(group)), pad_to=int(pad_to),
                   prompt_tokens=int(lens.sum()),
                   # every NON-CHUNKED request emits exactly one token at
                   # prefill (sampled or replayed), finished-at-prefill
                   # included; chunked admissions emit theirs at the final
                   # chunk step instead
                   committed_tokens=int(n_emitted),
                   new_sync=bool(new_sync),
                   active_slots=int(self.cm.n_active),
                   h2d_bytes=h2d, d2h_bytes=d2h,
                   **self._pool_gauges())
        if probe_stats is not None:
            self._emit_hw(probe_stats, "prefill",
                          n_tokens=int(lens.sum()) + n_emitted)

    def _append_token(self, req: Request, tok: int, wall: float):
        """Record one emitted token with its wall-clock stamp.  Replayed
        tokens (re-emitted after a preemption) keep their ORIGINAL stamps —
        they already streamed to the client once — so a stamp is only
        added once the token count grows past the recorded history.  Fresh
        emissions also fan out to the streaming hook (each position exactly
        once) and feed the scheduler's live SLO percentile windows."""
        req.tokens.append(tok)
        if len(req.wall_token_times) < len(req.tokens):
            req.wall_token_times.append(wall)
            n = len(req.wall_token_times)
            if n == 1:
                if req.wall_submitted_at is not None:
                    self.sched.observe_ttft(req.slo_class,
                                            wall - req.wall_submitted_at)
            else:
                self.sched.observe_itl(req.slo_class,
                                       wall - req.wall_token_times[-2])
            if self.on_token is not None:
                self.on_token(req, tok, len(req.tokens) - 1)

    # -- stepping -----------------------------------------------------------

    def writable_slots(self, counts: Optional[Dict[int, int]] = None
                       ) -> List[int]:
        """Active slots that can write this step's tokens — one per slot on
        the classic path, ``counts[slot]`` (committed token + drafts) under
        speculation.  On the paged store every slot must own writable
        blocks over its append span (allocate at block boundaries,
        copy-on-write shared tails); when the pool runs dry the newest
        admission is preempted and the check retried."""
        slots = list(self.active.keys())
        if not self.paged:
            return slots
        while slots:
            ns = None if counts is None else [counts.get(s, 1)
                                             for s in slots]
            if self.cm.prepare_append(slots, ns) is None:
                return slots
            self.preempt(self.pick_victim())   # newest admission goes
            slots = list(self.active.keys())
        return slots

    def decode_once(self, slots: List[int], prepare_s: float = 0.0):
        """One batched decode step: fixed (n_slots, ...) shapes, decode +
        fold + sample fused into ONE jitted dispatch with the cache buffer
        donated; only the (n_slots,) sampled tokens transfer to host.
        ``prepare_s`` is the caller-measured ``writable_slots`` wall (block
        allocation / CoW on the paged store) for the step record."""
        t_start = time.perf_counter()
        counts = np.zeros(self.n_slots, np.uint32)
        for s in slots:
            counts[s] = len(self.active[s].tokens)
        step = {"tokens": jnp.asarray(self.last_tok[:, None]),
                "cache_len": self.cm.cache_len_vector()}
        if self.paged:
            step["block_tables"] = self.cm.block_tables_device()
        self._maybe_inject_nan(step, slots)
        self.tel.count("h2d_bytes",
                       int(step["tokens"].nbytes)
                       + int(step["cache_len"].nbytes)
                       + int(self.slot_keys.nbytes) + int(counts.nbytes))
        probed = self.probe.should_sample(int(self.sched.n_decode_steps))
        t0 = time.perf_counter()

        def dispatch():
            fn = self._decode_probe_fn if probed else self._decode_fn
            out = fn(self.cm.cache, step, jnp.asarray(self.slot_keys),
                     jnp.asarray(counts))
            out[0].block_until_ready()
            return out

        with self.tel.span("decode", n_slots=len(slots)):
            out = self._dispatch("decode", dispatch)
        toks, new_cache = out[0], out[1]
        probe_stats = np.asarray(out[2]) if probed else None
        wall = time.perf_counter()
        dispatch_s = wall - t0
        self.decode_s += dispatch_s
        self.cm.update(new_cache)
        self.cm.advance(slots)
        self.sched.observe_decode_step(n_committed=len(slots))
        # occupancy/divergence captured HERE (before finished slots free)
        # so the record sees exactly what the scheduler observed
        occupancy = self.cm.n_active / self.cm.n_slots
        divergence = int(self.cm.divergence())
        self.peak_active = max(self.peak_active, len(slots))
        self.now += 1.0
        toks_np = np.asarray(toks)
        self.tel.count("d2h_bytes", int(toks_np.nbytes))
        n_committed = 0
        t_commit = time.perf_counter()
        with self.tel.span("commit", n_slots=len(slots)):
            for slot in slots:
                req = self.active[slot]
                if req.replay:
                    # replaying a preemption: force the recorded token (the
                    # greedy resample equals it; this also pins temperature
                    # sampling to the original stream)
                    tok = req.replay.pop(0)
                else:
                    tok = int(toks_np[slot])
                    if tok < 0:
                        # non-finite logits (the fused guard's -1 sentinel):
                        # fail ONLY the poisoned slot — its KV state is
                        # suspect, everyone else's tokens commit normally
                        self._fail_slot(slot)
                        continue
                self._append_token(req, tok, wall)
                self.last_tok[slot] = tok
                n_committed += 1
                reason = self.engine._finished(req, tok)
                if reason is not None:
                    del self.active[slot]
                    self.cm.free(slot)
                    req.finish(self.now, reason)
        commit_s = time.perf_counter() - t_commit
        if n_committed != len(slots):
            # nan-guard failures committed nothing: correct the scheduler's
            # optimistic per-slot count (observed above, before the frees,
            # so occupancy accounting matches the fault-free path exactly)
            self.sched.n_committed_tokens -= len(slots) - n_committed
        if probe_stats is not None:
            self.tel.count("d2h_bytes", int(probe_stats.nbytes))
        h2d, d2h = self._byte_deltas()
        self._emit("decode", step=int(self.sched.n_decode_steps),
                   wall_s=time.perf_counter() - t_start,
                   phases={"prepare_s": float(prepare_s),
                           "dispatch_s": dispatch_s,
                           "commit_s": commit_s},
                   active_slots=int(len(slots)), n_slots=int(self.n_slots),
                   occupancy=occupancy, divergence=divergence,
                   committed_tokens=int(n_committed),
                   h2d_bytes=h2d, d2h_bytes=d2h,
                   **self._pool_gauges())
        if probe_stats is not None:
            self._emit_hw(probe_stats, "decode", n_tokens=n_committed)

    def decode_once_spec(self):
        """One fused multi-token step: speculative verification and/or
        chunked prefill over ONE (n_slots, S) forward pass.

        Decode slots ride it as speculation: draft up to K tokens, verify
        them all, commit the accepted prefix plus the target's own next
        token — 1..K+1 tokens per step, token-identical to classic greedy.
        Chunk slots (requests mid-chunked-prefill) feed their next <= S
        KNOWN prompt tokens instead: the model writes their KV at the
        slot's positions exactly as it would rejected drafts, the
        mid-chunk argmaxes are ignored (the true continuation is the
        prompt itself), and when the prompt is exhausted the FINAL fed
        position's argmax is the request's first generated token — so
        chunked prefill is token-identical to one-shot prefill by
        construction.  With no drafter (chunked prefill only), decode
        slots degenerate to single-token commits, exactly a classic step.

        Per-slot draft lengths are capped by the remaining output budget
        (committing past ``max_new_tokens`` is impossible, so drafting
        there is pure waste).  The step rides a fixed shape per mode —
        (n_slots, K+1) for pure speculation, (n_slots, max(K+1, chunk))
        when chunk slots are aboard — so compiled variants stay O(1);
        causality makes the wider shape's extra garbage columns inert."""
        t_start = time.perf_counter()
        # the drafter may be disabled mid-step by the degradation ladder;
        # slot bookkeeping below must keep using the one that drafted
        drafter = self.drafter
        K = self.serve_cfg.num_draft_tokens if drafter is not None else 0
        chunk_now = dict(self.chunking)
        S = (max(K + 1, self.prefill_chunk or 0) if chunk_now else K + 1)
        slots = list(self.active.keys())
        dec = [s for s in slots if s not in chunk_now]
        caps = {s: max(min(K, self.active[s].max_new_tokens
                           - len(self.active[s].tokens) - 1), 0)
                for s in dec}
        t_draft = time.perf_counter()
        drafts = {}
        with self.tel.span("draft", n_slots=len(dec)):
            if drafter is not None and any(caps.values()):
                try:
                    drafts = drafter.propose_all(
                        {s: self.active[s] for s in dec}, caps)
                    self._drafter_faults = 0
                except DrafterFault:
                    # a failed drafter costs speculation, never correctness:
                    # the step proceeds draft-less (1 committed token per
                    # slot, exactly a classic decode)
                    drafts = {}
                    self._drafter_faults += 1
                    lim = self.serve_cfg.drafter_fault_limit
                    if lim and self._drafter_faults >= lim:
                        self.drafter = None
                        self._emit("degrade",
                                   step=int(self.sched.n_decode_steps),
                                   action="disable_speculation")
        draft_s = time.perf_counter() - t_draft
        drafts = {s: np.asarray(drafts.get(s, ()), np.int32)[:caps[s]]
                  for s in dec}
        # chunk rows: the next <= S unfed prompt tokens per chunk slot
        feeds = {s: np.asarray(self.active[s].prompt[p:p + S], np.int32)
                 for s, p in chunk_now.items()}
        # the paged store needs writable blocks over each slot's full
        # append span; preemption inside may shrink the slot set
        spans = {s: len(drafts[s]) + 1 for s in dec}
        spans.update({s: len(feeds[s]) for s in chunk_now})
        t_prep = time.perf_counter()
        slots = self.writable_slots(spans)
        prepare_s = time.perf_counter() - t_prep
        if not slots:
            return
        # a preemption inside writable_slots evicts slots (and clears
        # their chunk state): refresh both memberships before building rows
        live = set(slots)
        dec = [s for s in dec if s in live]
        chunk_now = {s: p for s, p in chunk_now.items() if s in live}
        toks = np.zeros((self.n_slots, S), np.int32)
        for s in dec:
            toks[s, 0] = self.last_tok[s]
            d = drafts[s]
            toks[s, 1:1 + len(d)] = d
        for s in chunk_now:
            toks[s, :len(feeds[s])] = feeds[s]
        step = {"tokens": jnp.asarray(toks),
                "cache_len": self.cm.cache_len_vector()}
        if self.paged:
            step["block_tables"] = self.cm.block_tables_device()
        self._maybe_inject_nan(step, slots)
        self.tel.count("h2d_bytes", int(step["tokens"].nbytes)
                       + int(step["cache_len"].nbytes))
        probed = self.probe.should_sample(int(self.sched.n_decode_steps))
        t0 = time.perf_counter()

        def dispatch():
            fn = self._verify_probe_fn if probed else self._verify_fn
            out = fn(self.cm.cache, step)
            out[0].block_until_ready()
            return out

        with self.tel.span("verify", n_slots=len(slots)):
            out = self._dispatch("verify", dispatch)
        greedy, new_cache = out[0], out[1]
        probe_stats = np.asarray(out[2]) if probed else None
        wall = time.perf_counter()
        dispatch_s = wall - t0
        self.decode_s += dispatch_s
        self.cm.update(new_cache)
        greedy_np = np.asarray(greedy)      # (n_slots, S) argmax stream
        self.tel.count("d2h_bytes", int(greedy_np.nbytes))
        drafted0, accepted0 = self.n_drafted, self.n_accepted
        commits: Dict[int, int] = {}        # cache POSITIONS advanced
        finished: Dict[int, str] = {}
        n_committed = 0                     # tokens EMITTED (decode output)
        n_chunk_fed = 0                     # prompt tokens fed (chunk rows)
        t_commit = time.perf_counter()
        with self.tel.span("commit", n_slots=len(slots)):
            for slot in slots:
                req = self.active[slot]
                if slot in chunk_now:
                    # chunked prefill: the fed prompt tokens are ground
                    # truth, so the cache always advances by the feed span;
                    # only the FINAL chunk's last argmax is a real output
                    n = len(feeds[slot])
                    commits[slot] = n
                    n_chunk_fed += n
                    new_pos = chunk_now[slot] + n
                    if new_pos < req.prompt_len:
                        self.chunking[slot] = new_pos
                        continue
                    del self.chunking[slot]
                    if req.replay:
                        tok = req.replay.pop(0)
                    else:
                        tok = int(greedy_np[slot, n - 1])
                        if tok < 0:
                            finished[slot] = "failed"
                            continue
                    self._append_token(req, tok, wall)
                    if req.first_token_at is None:
                        req.first_token_at = self.now
                    req.transition(RequestState.DECODE)
                    self.last_tok[slot] = tok
                    n_committed += 1
                    if self.drafter is not None:
                        self.drafter.on_admit(slot, req)
                    reason = self.engine._finished(req, tok)
                    if reason is not None:
                        finished[slot] = reason
                    continue
                d = drafts[slot]
                # greedy accept: drafts match the target's argmax stream up
                # to the first miss; the miss position's argmax is the
                # bonus token
                m = 1
                while m <= len(d) and greedy_np[slot, m - 1] == d[m - 1]:
                    m += 1
                self.n_drafted += len(d)
                self.n_accepted += m - 1
                appended = 0
                for j in range(m):
                    if req.replay:
                        # replay equals the greedy stream (token identity
                        # holds across preemption under speculation too)
                        tok = req.replay.pop(0)
                    else:
                        tok = int(greedy_np[slot, j])
                        if tok < 0:
                            # fused finite-logits guard tripped: fail this
                            # slot at the poisoned position, keep the prefix
                            finished[slot] = "failed"
                            break
                    self._append_token(req, tok, wall)
                    self.last_tok[slot] = tok
                    appended += 1
                    reason = self.engine._finished(req, tok)
                    if reason is not None:
                        finished[slot] = reason
                        break
                commits[slot] = appended
                n_committed += appended
        commit_s = time.perf_counter() - t_commit
        # commit the positions, then roll the paged store's speculative
        # tail blocks back BEFORE any slot is freed (free() releases whole
        # tables; release_tail only ever touches private draft-span blocks)
        self.cm.advance(slots, [commits[s] for s in slots])
        t_rb = time.perf_counter()
        if self.paged:
            with self.tel.span("rollback", n_slots=len(slots)):
                for slot in slots:
                    self.cm.release_tail(slot)
        rollback_s = time.perf_counter() - t_rb
        self.sched.observe_decode_step(n_committed=n_committed)
        occupancy = self.cm.n_active / self.cm.n_slots
        divergence = int(self.cm.divergence())
        self.peak_active = max(self.peak_active, len(slots))
        self.now += 1.0
        for slot in slots:
            if slot in finished:
                req = self.active.pop(slot)
                self.chunking.pop(slot, None)
                self.cm.free(slot)
                if drafter is not None:
                    drafter.on_free(slot)
                req.finish(self.now, finished[slot])
                if finished[slot] == "failed":
                    self._emit("fault", step=int(self.sched.n_decode_steps),
                               site="nan_guard",
                               request_id=int(req.request_id),
                               slot=int(slot))
            elif drafter is not None and slot not in chunk_now:
                # chunk slots have no drafter state: mid-chunk ones were
                # never admitted into it, just-completed ones had on_admit
                # called THIS step with the cache already at commit length
                drafter.observe_commit(slot,
                                       int(self.cm.lengths[slot]))
        if probe_stats is not None:
            self.tel.count("d2h_bytes", int(probe_stats.nbytes))
        h2d, d2h = self._byte_deltas()
        self._emit("verify", step=int(self.sched.n_decode_steps),
                   wall_s=time.perf_counter() - t_start,
                   phases={"draft_s": draft_s, "prepare_s": prepare_s,
                           "dispatch_s": dispatch_s, "commit_s": commit_s,
                           "rollback_s": rollback_s},
                   active_slots=int(len(slots)), n_slots=int(self.n_slots),
                   occupancy=occupancy, divergence=divergence,
                   committed_tokens=int(n_committed),
                   chunk_tokens=int(n_chunk_fed),
                   drafted_tokens=int(self.n_drafted - drafted0),
                   accepted_tokens=int(self.n_accepted - accepted0),
                   h2d_bytes=h2d, d2h_bytes=d2h,
                   **self._pool_gauges())
        if probe_stats is not None:
            self._emit_hw(probe_stats, "verify",
                          n_tokens=n_committed + n_chunk_fed)

    # -- live submission (the front door's entry points) --------------------

    def submit(self, request: Request) -> None:
        """Thread-safe dynamic submission for :meth:`run_forever`.  The
        request joins the arrival stream at the loop's next inbox drain;
        its ``arrival_time`` defaults to the loop's current virtual clock
        (stamped at drain) so step-clock metrics stay well-defined."""
        with self._inbox_lock:
            if self._closed:
                raise RuntimeError("serve loop is closed; cannot submit")
            self._inbox.append(request)

    def close(self) -> None:
        """Stop accepting submissions; :meth:`run_forever` returns once
        everything already in flight drains."""
        with self._inbox_lock:
            self._closed = True

    def _drain_inbox(self) -> None:
        with self._inbox_lock:
            if not self._inbox:
                return
            fresh, self._inbox = self._inbox, []
        for req in fresh:
            if req.arrival_time <= 0.0:
                req.arrival_time = self.now
            if (req.deadline_s is not None
                    or req.ttft_deadline_s is not None):
                self._any_deadlines = True
            self.requests.append(req)
            self.arrivals.append(req)

    def run(self) -> ServeReport:
        """Drain the constructor-supplied request list to completion (the
        classic batch entry point — a pre-closed live loop)."""
        self.close()
        return self.run_forever(poll_s=0.0)

    def run_forever(self, poll_s: float = 0.001) -> ServeReport:
        """Serve until closed AND drained.  Identical to the classic
        :meth:`run` loop except that each iteration first drains the
        thread-safe inbox, and an idle (empty) loop parks for ``poll_s``
        instead of returning — :meth:`submit` wakes it, :meth:`close`
        lets it finish.  Returns the same :class:`ServeReport`."""
        self.tel.start_profile()
        try:
            with self.tel.span("serve"):
                self.submit_arrivals()
                while True:
                    self._drain_inbox()
                    if not (self.arrivals or len(self.rq) or self.active):
                        with self._inbox_lock:
                            done = self._closed and not self._inbox
                        if done:
                            break
                        if poll_s > 0:
                            time.sleep(poll_s)
                        continue
                    # lifecycle sweep first: cancellations/expiries free
                    # capacity that this iteration's admission plan sees
                    self.sweep()
                    if not (self.arrivals or len(self.rq) or self.active):
                        # the sweep may have terminalized the only work
                        # (e.g. a cancel); observers still need to hear
                        # about it even though no step will run
                        if self.on_step_end is not None:
                            self.on_step_end(self)
                        continue
                    try:
                        self._step()
                    except RECOVERABLE_ERRORS as e:
                        self.recover(e)
                    if self.on_step_end is not None:
                        self.on_step_end(self)
            self._emit_request_records()
            return self.report()
        finally:
            self.tel.stop_profile()
            self.tel.flush()

    def _emit_request_records(self) -> None:
        """One ``request`` record per submitted request at drain time: the
        stream-side source for queue-wait and per-SLO-class wall-latency
        percentiles, so ``reduce_stream`` over the JSONL file reproduces
        the report's numbers exactly (file/live parity)."""
        step = int(self.sched.n_decode_steps)
        for req in sorted(self.requests, key=lambda r: r.request_id):
            wt = req.wall_token_times
            queue_wait = (None if req.wall_submitted_at is None
                          or req.wall_admitted_at is None
                          else req.wall_admitted_at - req.wall_submitted_at)
            ttft_wall = (None if req.wall_submitted_at is None or not wt
                         else wt[0] - req.wall_submitted_at)
            self._emit("request", step=step,
                       request_id=int(req.request_id),
                       slo_class=str(req.slo_class),
                       finish_reason=req.finish_reason,
                       n_tokens=int(len(req.tokens)),
                       queue_wait_s=queue_wait,
                       ttft_wall_s=ttft_wall,
                       itl_wall_s=[b - a for a, b in zip(wt, wt[1:])])

    def _step(self):
        """One loop iteration: admissions, then one batched decode/verify.
        Raising out of here with a RECOVERABLE error leaves no partial
        state — failed admissions are rolled back to the queue head."""
        groups = self.sched.plan_admissions()
        try:
            # one plan_admissions() batch is ONE admission sync;
            # only its first group opens the sync in the stream
            for gi, group in enumerate(groups):
                self.admit(group, new_sync=(gi == 0))
        except RECOVERABLE_ERRORS:
            self._rollback_admissions(groups)
            raise
        if not self.active:
            if not len(self.rq) and self.arrivals:
                # idle: jump the virtual clock to the next arrival
                self.now = max(self.now, self.arrivals[0].arrival_time)
                self.submit_arrivals()
            return
        if self.drafter is not None or self.chunking:
            self.decode_once_spec()
        else:
            t_prep = time.perf_counter()
            slots = self.writable_slots()
            prepare_s = time.perf_counter() - t_prep
            if not slots:
                return
            self.decode_once(slots, prepare_s=prepare_s)
        self.submit_arrivals()

    def _rollback_admissions(self, groups: List[List[Request]]):
        """A recoverable fault escaped mid-admission: return every
        not-yet-installed request to the queue head (tokens it already
        emitted ride the replay list), newest last-pushed so the original
        admission order is preserved."""
        queued = set(map(id, self.rq.peek()))
        for group in reversed(groups):
            for req in reversed(group):
                if req.state is RequestState.PREFILL:
                    req.preempt()          # -> WAITING, tokens -> replay
                    self.rq.push_front(req)
                elif (req.state is RequestState.WAITING
                      and id(req) not in queued):
                    # WAITING but already queued happens when the faulting
                    # insert preempted a groupmate: preempt() requeued it,
                    # a second push would double-admit it later
                    self.rq.push_front(req)
                # DECODE (already installed) and terminal states stay put

    # -- recovery -----------------------------------------------------------

    def recover(self, error: BaseException):
        """Rebuild-and-replay after a recoverable step failure: preempt
        every active request (token-exact replay), rebuild the executor's
        trace cache and a FRESH backing store, and let the loop re-admit.
        Past ``max_recoveries`` the loop degrades to failing all in-flight
        requests so ``serve()`` always returns."""
        step = int(self.sched.n_decode_steps)
        site = str(getattr(error, "site", "executor"))
        if not isinstance(error, InjectedFault):
            # injected faults were already recorded at fire time; real
            # failures (StepFault/StepTimeout) get their record here
            self._emit("fault", step=step, site=site,
                       error=f"{type(error).__name__}: {error}")
        self.n_recoveries += 1
        if self.n_recoveries > self.serve_cfg.max_recoveries:
            self._fail_inflight(error)
            return
        with self.tel.span("recover", site=site):
            n_requeued = 0
            while self.active:
                self.preempt(self.pick_victim())
                n_requeued += 1
            self.executor.reset()
            # degradation ladder: repeated kernel-layer faults fall back
            # to the XLA oracle backend (correctness over speed)
            lim = self.serve_cfg.kernel_fault_limit
            if (lim and self.n_recoveries >= lim
                    and resolve_matmul_backend(
                        self.executor.matmul_backend) != "xla"):
                self.executor.set_matmul_backend("xla")
                self._emit("degrade", step=step, action="xla_fallback")
            self.cm = self._build_cm()
            self.sched.cache_mgr = self.cm
            self._bind_step_fns()
        self._emit("recover", step=step, n_requeued=int(n_requeued))

    def _fail_inflight(self, error: BaseException):
        """Terminal degradation: the recovery budget is spent.  Fail every
        in-flight request (releasing all slots/blocks) so the loop drains
        and ``serve()`` returns a report instead of hanging or raising."""
        self._emit("degrade", step=int(self.sched.n_decode_steps),
                   action="abort",
                   error=f"{type(error).__name__}: {error}")
        for slot in list(self.active):
            req = self._evict(slot)
            req.finish(self.now, "failed")
        for req in self.rq.pop(len(self.rq)):
            req.finish(self.now, "failed")
        while self.arrivals:
            self.arrivals.popleft().finish(self.now, "failed")

    def report(self) -> ServeReport:
        """Build the report as a PURE REDUCTION over the step-record stream
        (``telemetry.reduce_stream``): every aggregate counter is folded
        from the same records the metrics sink saw, so the report and the
        JSONL file can never disagree (pinned byte-equal by
        ``tests/test_telemetry.py``).  Only the per-request results and
        wall-clock latency percentiles come from the Request objects — they
        are per-request artifacts, not step aggregates."""

        def ttft_wall(r: Request) -> Optional[float]:
            if not r.wall_token_times or r.wall_submitted_at is None:
                return None
            return r.wall_token_times[0] - r.wall_submitted_at

        results = [
            RequestResult(
                request_id=r.request_id,
                tokens=np.asarray(r.tokens, np.int64),
                prompt_len=r.prompt_len,
                arrival_time=r.arrival_time,
                ttft_steps=r.ttft,
                latency_steps=r.latency,
                finish_reason=r.finish_reason or "unknown",
                ttft_wall_s=ttft_wall(r),
            )
            for r in sorted(self.requests, key=lambda r: r.request_id)
        ]
        itl = [b - a for r in self.requests
               for a, b in zip(r.wall_token_times, r.wall_token_times[1:])]
        mesh = self.executor.mesh
        s = reduce_stream(self.stream)
        hw = None
        if s.n_hw_samples:
            n = s.n_hw_samples
            hw = {"n_samples": int(n),
                  "probe_every": int(self.probe.probe_every),
                  "act_bit_sparsity": s.hw_act_bit_sparsity / n,
                  "act_value_sparsity": s.hw_act_value_sparsity / n,
                  "weight_bit_sparsity": s.hw_weight_bit_sparsity / n,
                  "array_utilization": s.hw_array_utilization / n,
                  "cycles": {k: v / n for k, v in s.hw_cycles.items()},
                  "mac_energy_pj": {k: v / n for k, v
                                    in s.hw_mac_energy_pj.items()}}
        return ServeReport(
            results=results,
            prefill_s=s.prefill_s,
            decode_s=s.decode_s,
            steps=s.steps,
            n_syncs=s.n_syncs,
            n_rejected=s.n_rejected,
            total_new_tokens=s.total_new_tokens,
            slot_utilization=s.slot_utilization,
            max_divergence=s.max_divergence,
            deployment=self.engine.deployment_estimate(),
            hw_measured=hw,
            cache_backend=self.serve_cfg.cache_backend,
            n_preemptions=s.n_preemptions,
            prefix_hit_blocks=s.prefix_hit_blocks,
            cow_blocks=s.cow_blocks,
            peak_blocks_in_use=s.peak_blocks_in_use,
            peak_active_slots=s.peak_active_slots,
            mesh_shape=(None if mesh is None
                        else tuple(int(d) for d in mesh.devices.shape)),
            # ladder transitions may null the drafter mid-run; the report
            # names the drafter the run STARTED with
            draft=self.draft_name,
            n_cancelled=s.n_cancelled,
            n_timed_out=s.n_timed_out,
            n_failed=sum(1 for r in results if r.finish_reason == "failed"),
            n_faults=s.n_faults,
            n_injected_faults=s.n_injected_faults,
            n_retries=s.n_retries,
            n_degrades=s.n_degrades,
            n_recoveries=s.n_recoveries,
            drafted_tokens=s.drafted_tokens,
            accepted_tokens=s.accepted_tokens,
            committed_tokens_per_step=s.committed_tokens_per_step,
            ttft_wall=percentiles([ttft_wall(r) for r in self.requests]),
            itl_wall=percentiles(itl),
            # queue-wait and per-class percentiles fold from the stream's
            # ``request`` records (emitted at drain), preserving file/live
            # parity for the SLO numbers too
            queue_wait=percentiles(s.queue_wait_samples),
            slo_classes=self._slo_class_stats(s),
            chunk_tokens=s.chunk_tokens,
        )

    @staticmethod
    def _slo_class_stats(s) -> Optional[Dict[str, dict]]:
        names = sorted(set(s.slo_ttft_samples) | set(s.slo_itl_samples))
        if not names:
            return None
        return {name: {"n": len(s.slo_ttft_samples.get(name, ())),
                       "ttft_wall": percentiles(
                           s.slo_ttft_samples.get(name, ())),
                       "itl_wall": percentiles(
                           s.slo_itl_samples.get(name, ()))}
                for name in names}


class ServingEngine:
    def __init__(self, arch_cfg, params, serve_cfg: Optional[ServeConfig] = None,
                 executor: Optional[Executor] = None,
                 draft_cfg=None, draft_params=None):
        """``draft_cfg``/``draft_params``: a small same-family model for
        ``ServeConfig.draft == "model"`` speculative decoding.  Its traces
        run through an executor built over the SAME mesh as the target's
        (or single-device when none), so drafting composes with
        tensor-parallel serving."""
        self.cfg = arch_cfg
        self.serve_cfg = ServeConfig() if serve_cfg is None else serve_cfg
        if arch_cfg.matmul_mode in ("bp_exact", "bp_approx"):
            # weight-resident fast path: quantize every dense kernel to int8 +
            # per-channel scale ONCE, instead of per-channel re-quantizing the
            # float weights on every forward inside the decode hot loop
            # (idempotent — already-int8 params pass through untouched)
            params = quantize_dense_params(params)
        if executor is None:
            executor = make_executor(arch_cfg, params,
                                     mesh_shape=self.serve_cfg.mesh_shape)
        self.executor = executor
        self.matmul_backend = executor.matmul_backend
        self.draft_cfg = draft_cfg
        self.draft_executor: Optional[Executor] = None
        if draft_cfg is not None:
            if draft_params is None:
                raise ValueError("draft_cfg given without draft_params")
            if draft_cfg.matmul_mode in ("bp_exact", "bp_approx"):
                draft_params = quantize_dense_params(draft_params)
            self.draft_executor = make_executor(draft_cfg, draft_params,
                                                mesh=executor.mesh)
        self._deployment_cache: Dict[int, Optional[dict]] = {}
        self._weight_profile: Optional[dict] = None
        if (self.serve_cfg.probe is not None and self.serve_cfg.probe.enabled
                and arch_cfg.matmul_mode in ("bp_exact", "bp_approx")):
            # probe runs: compute the static weight factor eagerly so the
            # first sampled step folds without a construction-time stall
            self.weight_sparsity_profile()
        # request ids queued for cancellation; the serve loop's sweep
        # drains this set once per iteration (idempotent — unknown or
        # already-finished ids are ignored)
        self._pending_cancels: Set[int] = set()

    def cancel(self, request_id: int) -> None:
        """Request cancellation of an in-flight request.  Applied at the
        serve loop's next lifecycle sweep: the request reaches the
        CANCELLED terminal state, its slot and blocks are freed, and a
        ``cancel`` record lands in the metrics stream.  Safe to call from
        a ``ServeLoop.on_step_end`` hook or before ``serve()`` starts;
        cancelling an unknown or finished request is a no-op."""
        self._pending_cancels.add(int(request_id))

    @property
    def params(self):
        """The executor-placed (pre-quantized) params."""
        return self.executor.params

    def _sample(self, logits, key):
        if self.serve_cfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / self.serve_cfg.temperature,
                                      axis=-1)

    # ------------------------------------------------------------------
    # Static path (device-resident chunked decode)
    # ------------------------------------------------------------------

    def generate(self, batch: dict, key=None, *,
                 max_new_tokens: Optional[int] = None,
                 cache_T: Optional[int] = None) -> GenerationResult:
        """batch: {"tokens": (B, S_prompt) [, "src_embeds", vision...]}.

        ``max_new_tokens``/``cache_T`` override the config per call; pinning
        ``cache_T`` across calls keeps one compiled decode shape (outputs are
        unaffected — the padded cache region is masked)."""
        key = jax.random.PRNGKey(0) if key is None else key
        prompt = batch["tokens"]
        B, S = prompt.shape
        max_new = (self.serve_cfg.max_new_tokens if max_new_tokens is None
                   else max_new_tokens)
        if max_new < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if cache_T is None:
            cache_T = S + max_new + self.serve_cfg.cache_margin
        eos = self.serve_cfg.eos_id
        temperature = self.serve_cfg.temperature
        chunk_pref = max(1, self.serve_cfg.decode_chunk)

        t0 = time.perf_counter()
        logits, cache = self.executor.prefill(batch, cache_T)
        logits.block_until_ready()
        t1 = time.perf_counter()

        # device-resident decode: chunks of ``decode_chunk`` tokens advance
        # inside one jitted lax.scan each; per chunk only (B,) tokens + done
        # flags come back to the host (EOS early-exit at chunk boundaries).
        # The cache buffer is donated across chunk dispatches (executor).
        tok = self._sample(logits, key).astype(jnp.int32)
        done = jnp.zeros((B,), bool)
        chunks = [tok[:, None]]
        start, n_steps = 0, max_new - 1
        while start < n_steps:
            if eos is not None and bool(np.asarray(
                    (done | (tok == eos)).all())):
                break
            remaining = n_steps - start
            # tail chunks decompose into powers of two so the number of
            # compiled scan variants stays O(log decode_chunk) no matter how
            # max_new_tokens varies across calls (each distinct chunk length
            # is a separate whole-model compile)
            chunk = (chunk_pref if remaining >= chunk_pref
                     else 1 << (remaining.bit_length() - 1))
            scan = self.executor.decode_scan_fn(chunk, temperature, eos)
            tok, cache, done, key, toks = scan(
                tok, cache, done, key, jnp.int32(S + start), jnp.int32(start))
            chunks.append(toks.T)
            start += chunk
        jax.block_until_ready(tok)
        t2 = time.perf_counter()

        mat = np.concatenate([np.asarray(c) for c in chunks], axis=1)
        if eos is not None:
            # trim to the step the per-token loop would have stopped at:
            # the first column where every row has already emitted EOS
            col_done = (np.cumsum(mat == eos, axis=1) > 0).all(axis=0)
            if col_done.any():
                mat = mat[:, :int(np.argmax(col_done)) + 1]
        return GenerationResult(tokens=mat,
                                prefill_s=t1 - t0, decode_s=t2 - t1,
                                steps=mat.shape[1])

    # ------------------------------------------------------------------
    # Continuous batching (quasi-sync path)
    # ------------------------------------------------------------------

    def _request_key_base(self, req: Request):
        """Per-request PRNG base; the n-th sampled token folds this with n
        (prefill samples with n=0, the decode step folds in the running
        token count — one consistent stream per request)."""
        return jax.random.fold_in(jax.random.PRNGKey(0), req.request_id)

    def _request_key(self, req: Request, n: int):
        return jax.random.fold_in(self._request_key_base(req), n)

    def _finished(self, req: Request, token: int) -> Optional[str]:
        eos = self.serve_cfg.eos_id
        if eos is not None and token == eos:
            return "eos"
        if len(req.tokens) >= req.max_new_tokens:
            return "length"
        return None

    def make_loop(self, requests: Sequence[Request], *, n_slots: int = 8,
                  cache_T: Optional[int] = None,
                  sched_cfg: Optional[SchedulerConfig] = None,
                  extras: Optional[Dict[int, dict]] = None,
                  num_blocks: Optional[int] = None) -> ServeLoop:
        """Build (without running) the orchestration loop for one serve
        call — the unit-testing entry point for its components."""
        return ServeLoop(self, requests, n_slots=n_slots, cache_T=cache_T,
                         sched_cfg=sched_cfg, extras=extras,
                         num_blocks=num_blocks)

    def serve(self, requests: Sequence[Request], *, n_slots: int = 8,
              cache_T: Optional[int] = None,
              sched_cfg: Optional[SchedulerConfig] = None,
              extras: Optional[Dict[int, dict]] = None,
              num_blocks: Optional[int] = None) -> ServeReport:
        """Continuously-batched generation over a request stream.

        ``requests``: ``serving.queue.Request`` objects; ``arrival_time`` is
        interpreted on the decode-step clock (request i becomes visible once
        ``step >= arrival_time``), which makes runs deterministic and
        replayable.  ``extras`` optionally maps request_id -> extra prefill
        inputs (e.g. ``src_embeds`` for the audio family); per-request
        arrays are stacked on a new leading batch axis, so model inputs
        whose batch axis is not leading (the vlm family's M-RoPE
        ``positions``, shaped (3, B, S)) cannot ride through ``extras``.

        The decode cache is backed by ``ServeConfig.cache_backend``:
        ``"slab"`` reserves ``cache_T`` per slot; ``"paged"`` allocates
        ``block_size``-token blocks on demand (``num_blocks`` caps the pool
        — default matches the slab footprint) with automatic prefix sharing
        and LRU-backed preemption-and-requeue when the pool runs dry.
        Greedy outputs are token-identical across backends — and across
        executors (single-device vs mesh).
        """
        return self.make_loop(requests, n_slots=n_slots, cache_T=cache_T,
                              sched_cfg=sched_cfg, extras=extras,
                              num_blocks=num_blocks).run()

    # ------------------------------------------------------------------
    # BitParticle deployment estimate
    # ------------------------------------------------------------------

    def deployment_estimate(self, n_mc: int = 20_000) -> Optional[dict]:
        """Per-layer modeled cycles/energy of the quantized weights on the
        BitParticle array (None unless a bp_* matmul mode is active).
        Cached: it depends only on the immutable params."""
        mode = self.cfg.matmul_mode
        if mode not in ("bp_exact", "bp_approx"):
            return None
        if n_mc in self._deployment_cache:
            return self._deployment_cache[n_mc]
        from repro.core import cost_model as cost
        from repro.core.sparsity import bit_sparsity_sign_magnitude

        L = self.cfg.num_layers
        per_layer_bs: Dict[int, List[float]] = {}
        for leaf in jax.tree.leaves(self.params):
            if not (hasattr(leaf, "dtype") and leaf.dtype == jnp.int8):
                continue
            if leaf.ndim >= 2 and leaf.shape[0] == L:
                for l in range(L):
                    per_layer_bs.setdefault(l, []).append(
                        float(bit_sparsity_sign_magnitude(leaf[l])))
            else:
                per_layer_bs.setdefault(-1, []).append(
                    float(bit_sparsity_sign_magnitude(leaf)))
        if not per_layer_bs:
            return None
        layers = []
        for l in sorted(per_layer_bs):
            bs = float(np.mean(per_layer_bs[l]))
            layers.append({
                "layer": l,          # -1 = non-stacked weights (e.g. lm_head)
                "bit_sparsity": bs,
                "avg_cycles_per_mac": cost.modeled_avg_cycles(mode, bs, n=n_mc),
                "mac_energy_pj": cost.mac_energy_pj(mode, bs),
            })
        mean_bs = float(np.mean([e["bit_sparsity"] for e in layers]))
        est = {
            "mode": mode,
            "per_layer": layers,
            "mean_bit_sparsity": mean_bs,
            "mean_cycles_per_mac": float(
                np.mean([e["avg_cycles_per_mac"] for e in layers])),
            "mean_mac_energy_pj": float(
                np.mean([e["mac_energy_pj"] for e in layers])),
        }
        self._deployment_cache[n_mc] = est
        return est

    def weight_sparsity_profile(self) -> dict:
        """Element-weighted weight bit/value sparsity of the pre-quantized
        int8 params, once per engine (the probe's static factor).  Unlike
        ``deployment_estimate``'s per-kernel mean, these rates weight every
        int8 element equally — the same reduction the probe applies to
        activations, so the two factors are directly comparable."""
        if self._weight_profile is None:
            from repro.serving.probe import per_layer_weight_stats
            stacked, tail = per_layer_weight_stats(self.params,
                                                   self.cfg.num_layers)
            rows = (stacked if tail is None
                    else np.concatenate([stacked, tail[None, :]]))
            total = rows.sum(axis=0)
            n = max(float(total[1]), 1.0)
            per_n = np.maximum(stacked[:, 1], 1.0)
            self._weight_profile = {
                "bit_sparsity": float(total[0] / (7.0 * n)),
                "value_sparsity": float(total[2] / n),
                "per_layer_bit_sparsity":
                    (stacked[:, 0] / (7.0 * per_n)).tolist(),
                "tail_bit_sparsity":
                    (None if tail is None
                     else float(tail[0] / (7.0 * max(tail[1], 1.0)))),
            }
        return self._weight_profile
