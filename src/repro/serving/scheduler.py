"""Quasi-synchronous continuous-batching scheduler.

The paper's MAC array lets synchronization groups drift up to E steps apart
(inter-group elasticity) so heterogeneous-latency work units stop wasting
lock-step capacity.  Serving has the same problem one level up: a static
batch decodes until its *slowest* request finishes while finished slots burn
compute and arrivals wait for a full drain.

This scheduler is the request-level mirror of the array schedule:

  * slots ~ synchronization groups — each advances at its own sequence
    position (per-slot ``cache_len``), evicted the moment it finishes;
  * the admission queue ~ per-PE operand queues (depth = ``max_waiting``);
  * ``lead_window`` ~ the paper's E: an admissible request (arrived + free
    capacity) may be deferred at most E decode steps so that several
    admissions share one prefill sync, exactly as the array's weight buffer
    holds E+1 weight versions to amortize group re-sync.  E = 0 degenerates
    to admit-immediately (sync every step); E -> inf with ``n_slots``
    arrivals degenerates to static batching.

Admissibility is delegated to the cache manager
(``admissible_prefix``): the slab store admits one request per free slot
(worst-case reservation); the paged store admits by **free-block budget**
with prefix-sharing hits counted — the elastic unit shrinks from a whole
slot drain to a single block.

Prefill fusion buckets admissions by padded power-of-two prompt length
(``prefill_bucketing="pow2"``), so heterogeneous prompts share one prefill
sync and the engine compiles O(log S) prefill shape variants instead of one
per distinct length.  Recurrent-state families use ``"exact"`` buckets
(right padding would corrupt their state).

The scheduler is pure policy: it never touches device state, so the same
scheduler drives every execution layer (single-device or mesh-sharded —
``serving/executor.py``).  The engine's ``ServeLoop`` asks it each
iteration what to admit; prefills, eviction, preemption, and decode are
the loop's job, and all device work is the executor's.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional

from repro.serving.cache_manager import BaseCacheManager
from repro.serving.queue import Request, RequestQueue


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One priority class with optional latency service-level objectives.

    ``priority`` orders admission under the scheduler's ``"slo"`` policy
    (higher admits first; ties keep FIFO order).  ``ttft_target_s`` /
    ``itl_target_s`` are wall-clock targets: the scheduler folds the live
    p90 of each class's recent samples (the same ``telemetry.percentiles``
    rule the report uses) and, on a breach, turns the knob it owns —
    TTFT breach collapses the lead window to 0 (admit immediately, no
    deferred fusion), ITL breach throttles admission burst size (the
    decode batch stops growing until inter-token latency recovers)."""

    name: str = "default"
    priority: int = 0
    ttft_target_s: Optional[float] = None
    itl_target_s: Optional[float] = None


@dataclasses.dataclass
class SchedulerConfig:
    lead_window: int = 4          # E: max decode steps an admission may wait
    max_waiting: int = 256        # admission-queue depth (Q analogue)
    max_prefill_batch: int = 8    # admissions fused into one prefill call
    # prefill fusion buckets: "pow2" pads prompts up to the next power of
    # two so heterogeneous lengths share one prefill; "exact" fuses only
    # equal lengths; None = engine picks per family (pow2 where right
    # padding is safe, exact for recurrent state / extra prefill inputs)
    prefill_bucketing: Optional[str] = None
    # admission policy: "fifo" (the classic lead-window scheduler; ignores
    # request priorities) or "slo" (priority classes + live TTFT/ITL
    # percentile control — see :class:`SLOClass`)
    policy: str = "fifo"
    # name -> SLOClass for the "slo" policy; requests whose ``slo_class``
    # is not listed get priority 0 and no targets
    slo_classes: Optional[Dict[str, SLOClass]] = None
    # rolling window of wall-clock samples kept per class for the live
    # percentile control inputs
    slo_window: int = 64


def prefill_bucket_len(prompt_len: int, cache_T: Optional[int] = None) -> int:
    """Padded power-of-two prefill length for ``prompt_len`` (clamped to the
    cache capacity so a bucket never exceeds what prefill can hold)."""
    b = 1 << max(prompt_len - 1, 0).bit_length()
    if cache_T is not None:
        b = min(b, cache_T)
    return max(b, 1)


class QuasiSyncScheduler:
    def __init__(self, queue: RequestQueue, cache_mgr: BaseCacheManager,
                 cfg: SchedulerConfig = None, *, telemetry=None):
        from repro.serving.telemetry import NULL_TELEMETRY
        self.queue = queue
        self.cache_mgr = cache_mgr
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        if self.cfg.prefill_bucketing not in (None, "exact", "pow2"):
            raise ValueError(
                f"unknown prefill_bucketing "
                f"{self.cfg.prefill_bucketing!r}; expected 'pow2', 'exact' "
                f"or None (auto)")
        self.bucketing = self.cfg.prefill_bucketing or "exact"
        if self.cfg.policy not in ("fifo", "slo"):
            raise ValueError(f"unknown scheduler policy {self.cfg.policy!r};"
                             f" expected 'fifo' or 'slo'")
        self.pending_wait = 0     # decode steps the current admissible set waited
        self.n_syncs = 0
        self.n_decode_steps = 0
        self.n_committed_tokens = 0
        self.occupancy_sum = 0.0
        self.max_divergence = 0
        # chunked prefill (set by the serve loop): the effective prefill
        # length of a long prompt is its first chunk, so bucketing and
        # fusion group by that, not by the full prompt
        self.prefill_chunk: Optional[int] = None
        # live SLO control state: rolling wall-clock samples per class
        win = max(int(self.cfg.slo_window), 1)
        self._ttft_samples: Dict[str, collections.deque] = (
            collections.defaultdict(lambda: collections.deque(maxlen=win)))
        self._itl_samples: Dict[str, collections.deque] = (
            collections.defaultdict(lambda: collections.deque(maxlen=win)))

    # -- policy -------------------------------------------------------------

    def _bucket(self, prompt_len: int) -> int:
        if self.prefill_chunk is not None:
            prompt_len = min(prompt_len, self.prefill_chunk)
        if self.bucketing == "pow2":
            return prefill_bucket_len(prompt_len,
                                      getattr(self.cache_mgr, "cache_T", None))
        return prompt_len

    def _priority(self, req: Request) -> int:
        cls = (self.cfg.slo_classes or {}).get(req.slo_class)
        return cls.priority if cls is not None else 0

    def _breached(self, samples: Dict[str, collections.deque],
                  target_of) -> bool:
        """True when any class's live p90 exceeds its target — the
        report-only wall-clock percentiles become a control input here."""
        from repro.serving.telemetry import percentiles
        for name, cls in (self.cfg.slo_classes or {}).items():
            target = target_of(cls)
            if target is None:
                continue
            pct = percentiles(samples.get(name, ()), qs=(90,))
            if pct is not None and pct["p90"] > target:
                return True
        return False

    def _effective_lead_window(self) -> int:
        """E under live SLO control: a TTFT breach in any targeted class
        collapses the window to 0 (admit at the first opportunity; the
        fusion saving is what's costing first-token latency)."""
        if self.cfg.policy == "slo" and self._breached(
                self._ttft_samples, lambda c: c.ttft_target_s):
            return 0
        return self.cfg.lead_window

    def plan_admissions(self) -> List[List[Request]]:
        """Decide which WAITING requests to admit *now*.

        Returns prefill groups (same length bucket, fused into one prefill
        call), or [] to keep decoding and let admissible requests wait —
        bounded by the lead window E.  Under the "slo" policy the waiting
        set is ordered priority-first (stable: FIFO within a class) before
        the admissible prefix is sized, and live percentile breaches steer
        E and the admission burst size.
        """
        slo = self.cfg.policy == "slo"
        waiting = self.queue.peek()
        if slo and waiting:
            waiting = sorted(waiting, key=self._priority, reverse=True)
        admissible = self.cache_mgr.admissible_prefix(waiting)
        if admissible == 0:
            self.pending_wait = 0
            return []
        batch_empty = self.cache_mgr.n_active == 0
        fills_all_slots = admissible >= self.cache_mgr.n_free
        if not (batch_empty or fills_all_slots
                or self.pending_wait >= self._effective_lead_window()):
            # elastic deferral: keep the batch running, admissions ride the
            # next sync (<= E steps away)
            self.pending_wait += 1
            return []
        if (slo and not batch_empty and self._breached(
                self._itl_samples, lambda c: c.itl_target_s)):
            # ITL breach: inter-token latency scales with the decode batch,
            # so stop growing it — admit the minimum burst and let the
            # percentile window recover before resuming full admission
            admissible = 1
        self.pending_wait = 0
        self.n_syncs += 1
        self.telemetry.instant("admission_sync", admitted=admissible,
                               n_free_slots=self.cache_mgr.n_free)
        if slo:
            admits = self.queue.pop_selected(waiting[:admissible])
        else:
            admits = self.queue.pop(admissible)
        groups: Dict[int, List[Request]] = {}
        for req in admits:
            groups.setdefault(self._bucket(req.prompt_len), []).append(req)
        out = []
        for _, reqs in sorted(groups.items()):
            for i in range(0, len(reqs), self.cfg.max_prefill_batch):
                out.append(reqs[i:i + self.cfg.max_prefill_batch])
        return out

    # -- live SLO control inputs --------------------------------------------

    def observe_ttft(self, slo_class: str, ttft_s: float) -> None:
        """Feed one first-token wall latency into the class's rolling
        window (called by the loop as each first token commits)."""
        self._ttft_samples[slo_class].append(float(ttft_s))

    def observe_itl(self, slo_class: str, itl_s: float) -> None:
        """Feed one inter-token wall gap into the class's rolling window."""
        self._itl_samples[slo_class].append(float(itl_s))

    def set_lead_window(self, lead_window: int) -> None:
        """Shrink/grow E at runtime (degradation ladder: sustained pool
        pressure trades admission fusion for fewer preemptions)."""
        self.cfg = dataclasses.replace(self.cfg,
                                       lead_window=max(int(lead_window), 0))

    # -- metrics ------------------------------------------------------------

    def observe_decode_step(self, n_committed: Optional[int] = None):
        """Record one batched decode/verify step.  ``n_committed`` is the
        number of tokens actually COMMITTED this step across all slots —
        under speculative decoding a slot commits 1..K+1 tokens per step,
        so throughput accounting must count commits, not assume one token
        per active slot.  ``None`` keeps the classic 1-per-active-slot
        rule (the non-speculative decode step)."""
        self.n_decode_steps += 1
        self.n_committed_tokens += (self.cache_mgr.n_active
                                    if n_committed is None else n_committed)
        self.occupancy_sum += self.cache_mgr.n_active / self.cache_mgr.n_slots
        self.max_divergence = max(self.max_divergence,
                                  self.cache_mgr.divergence())

    @property
    def slot_utilization(self) -> float:
        """Mean fraction of occupied slots per decode step — the serving
        analogue of the array simulator's PE utilization."""
        if self.n_decode_steps == 0:
            return 0.0
        return self.occupancy_sum / self.n_decode_steps

    @property
    def committed_tokens_per_step(self) -> float:
        """Mean tokens committed per batched step (> n_active mean under
        speculation with a positive acceptance rate)."""
        if self.n_decode_steps == 0:
            return 0.0
        return self.n_committed_tokens / self.n_decode_steps
