"""Quasi-synchronous serving subsystem (continuous batching).

Request-level mirror of the paper's quasi-sync MAC array: slots ~
synchronization groups, the admission queue ~ operand queues, and the
scheduler's lead window ~ the inter-group elasticity parameter E.
See docs/serving.md for the full correspondence.
"""

from repro.serving.cache_manager import CacheManager
from repro.serving.engine import (GenerationResult, RequestResult,
                                  ServeConfig, ServeReport, ServingEngine)
from repro.serving.queue import Request, RequestQueue, RequestState
from repro.serving.scheduler import QuasiSyncScheduler, SchedulerConfig

__all__ = [
    "CacheManager",
    "GenerationResult",
    "QuasiSyncScheduler",
    "Request",
    "RequestQueue",
    "RequestResult",
    "RequestState",
    "ServeConfig",
    "ServeReport",
    "ServingEngine",
    "SchedulerConfig",
]
