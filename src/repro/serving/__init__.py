"""Quasi-synchronous serving subsystem (continuous batching).

Request-level mirror of the paper's quasi-sync MAC array: slots ~
synchronization groups, the admission queue ~ operand queues, and the
scheduler's lead window ~ the inter-group elasticity parameter E.
See docs/serving.md for the full correspondence.
"""

from repro.serving.block_pool import (BlockPool, NoFreeBlocks,
                                      PagedCacheManager)
from repro.serving.cache_manager import (BaseCacheManager, CacheManager,
                                         make_cache_manager)
from repro.serving.engine import (GenerationResult, RequestResult,
                                  ServeConfig, ServeLoop, ServeReport,
                                  ServingEngine)
from repro.serving.executor import (Executor, MeshExecutor,
                                    SingleDeviceExecutor, make_executor,
                                    make_serving_mesh)
from repro.serving.faults import (NULL_INJECTOR, DeviceOOM, DrafterFault,
                                  FaultInjector, InjectedFault, StepFault,
                                  StepTimeout, TransientStepFault)
from repro.serving.frontdoor import (FrontDoor, FrontDoorClient,
                                     FrontDoorServer, Replica, Router)
from repro.serving.probe import (NULL_PROBE, PROBE_METHODS, SparsityProbe,
                                 probe_supported)
from repro.serving.queue import Request, RequestQueue, RequestState
from repro.serving.scheduler import (QuasiSyncScheduler, SchedulerConfig,
                                     SLOClass)
from repro.serving.speculative import (Drafter, ModelDrafter,
                                       PromptLookupDrafter, make_drafter)
from repro.serving.telemetry import (SCHEMA_VERSION, MetricsLogger,
                                     StreamSummary, Telemetry, Tracer,
                                     percentiles, read_jsonl, reduce_stream)

__all__ = [
    "BaseCacheManager",
    "BlockPool",
    "CacheManager",
    "DeviceOOM",
    "Drafter",
    "DrafterFault",
    "Executor",
    "FaultInjector",
    "FrontDoor",
    "FrontDoorClient",
    "FrontDoorServer",
    "GenerationResult",
    "InjectedFault",
    "MeshExecutor",
    "MetricsLogger",
    "ModelDrafter",
    "NULL_INJECTOR",
    "NULL_PROBE",
    "NoFreeBlocks",
    "PROBE_METHODS",
    "PagedCacheManager",
    "PromptLookupDrafter",
    "QuasiSyncScheduler",
    "Replica",
    "Request",
    "RequestQueue",
    "RequestResult",
    "RequestState",
    "Router",
    "SCHEMA_VERSION",
    "SLOClass",
    "ServeConfig",
    "ServeLoop",
    "ServeReport",
    "ServingEngine",
    "SchedulerConfig",
    "SingleDeviceExecutor",
    "SparsityProbe",
    "StepFault",
    "StepTimeout",
    "StreamSummary",
    "TransientStepFault",
    "Telemetry",
    "Tracer",
    "make_cache_manager",
    "make_drafter",
    "make_executor",
    "make_serving_mesh",
    "percentiles",
    "probe_supported",
    "read_jsonl",
    "reduce_stream",
]
