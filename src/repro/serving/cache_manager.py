"""Slot-based cache manager: fixed-capacity per-slot KV / recurrent state.

Owns ONE pooled decode cache of ``n_slots`` slots (the batch axis of every
cache leaf, located via ``api.cache_batch_axes``) plus the per-slot sequence
positions.  Works for every family on the ``models/api.py`` surface —
attention KV caches (dense/moe/vlm/audio) and O(1) recurrent state
(RWKV/Zamba) alike, because slot surgery is expressed as pytree ops over the
family's own cache structure.

A slot is the serving analogue of one PE-column (synchronization group) in
the quasi-sync array: it owns private state and advances at its own sequence
position while the pool steps as one batched unit.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import api


class CacheManager:
    def __init__(self, cfg, n_slots: int, cache_T: int):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.cfg = cfg
        self.n_slots = n_slots
        self.cache_T = cache_T
        self.cache = api.zeros_cache(cfg, n_slots, cache_T)
        self.lengths = np.zeros(n_slots, np.int32)   # per-slot seq position
        self._free: List[int] = list(range(n_slots - 1, -1, -1))
        self._occupied = np.zeros(n_slots, bool)
        # One compiled insert covers every (slot, src_index) pair; recompiles
        # only per distinct prefill batch shape.
        self._insert = jax.jit(
            lambda pool, src, slot, i: api.slot_insert(cfg, pool, src, slot, i))

    # -- slot accounting ----------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free)

    def fits(self, prompt_len: int, max_new_tokens: int) -> bool:
        """Does prompt + generation fit in one slot's capacity?"""
        return prompt_len + max_new_tokens <= self.cache_T

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError("no free slot")
        slot = self._free.pop()
        self._occupied[slot] = True
        return slot

    def free(self, slot: int):
        if not self._occupied[slot]:
            raise ValueError(f"slot {slot} is not occupied")
        self._occupied[slot] = False
        self.lengths[slot] = 0
        self._free.append(slot)

    # -- cache surgery ------------------------------------------------------

    def insert(self, slot: int, src_cache, length: int, src_index: int = 0):
        """Install request ``src_index`` of a prefill cache (padded to this
        pool's cache_T) into ``slot`` and set its sequence position."""
        if not self._occupied[slot]:
            raise ValueError(f"slot {slot} must be alloc()ed before insert")
        self.cache = self._insert(self.cache, src_cache,
                                  jnp.int32(slot), jnp.int32(src_index))
        self.lengths[slot] = length

    def update(self, new_cache):
        """Adopt the cache returned by a batched decode step."""
        self.cache = new_cache

    def advance(self, slots):
        """Bump the sequence position of the given slots by one token."""
        for s in slots:
            self.lengths[s] += 1

    def cache_len_vector(self) -> jnp.ndarray:
        """(n_slots,) per-slot positions for ``decode_step``.  Free slots sit
        at 0: their writes land in a region fully overwritten by the next
        ``insert`` (prefill caches are padded to cache_T), so they never
        leak into an admitted request."""
        return jnp.asarray(self.lengths)

    # -- introspection ------------------------------------------------------

    def divergence(self) -> int:
        """Spread of active-slot positions (the quasi-sync E analogue)."""
        active = self.lengths[self._occupied]
        if active.size == 0:
            return 0
        return int(active.max() - active.min())
