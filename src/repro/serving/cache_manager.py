"""Decode-cache managers: slot accounting base + the slab backing store.

Two backing stores sit behind one slot-level interface (``alloc`` / ``free``
/ ``insert`` / ``advance`` / ``cache_len_vector`` / ``divergence``):

  * **slab** (:class:`CacheManager`, this module) — ONE pooled decode cache
    of ``n_slots`` slots, each a fixed worst-case ``cache_T`` region.  Works
    for every family on the ``models/api.py`` surface — attention KV caches
    and O(1) recurrent state alike — because slot surgery is expressed as
    pytree ops over the family's own cache structure.
  * **paged** (:class:`repro.serving.block_pool.PagedCacheManager`) —
    fixed-size KV blocks allocated on demand through per-slot block tables,
    with automatic prefix sharing and copy-on-write.  Position-indexed KV
    families only.

A slot is the serving analogue of one PE-column (synchronization group) in
the quasi-sync array: it owns private state and advances at its own sequence
position while the pool steps as one batched unit.  ``make_cache_manager``
is the facade the engine uses to pick a store per ``ServeConfig``.
"""

from __future__ import annotations

from typing import List, Optional

import numpy as np
import jax.numpy as jnp


class BaseCacheManager:
    """Slot accounting shared by every backing store: occupancy, per-slot
    sequence positions, and the vectorized position bookkeeping that both
    ``advance`` and ``divergence`` read."""

    def __init__(self, cfg, n_slots: int):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        self.cfg = cfg
        self.n_slots = n_slots
        self.lengths = np.zeros(n_slots, np.int32)   # per-slot seq position
        self._free_slots: List[int] = list(range(n_slots - 1, -1, -1))
        self._occupied = np.zeros(n_slots, bool)

    # -- slot accounting ----------------------------------------------------

    @property
    def n_free(self) -> int:
        return len(self._free_slots)

    @property
    def n_active(self) -> int:
        return self.n_slots - len(self._free_slots)

    def alloc(self, slot: Optional[int] = None) -> int:
        """Claim a free slot (LIFO order), or — with ``slot`` — claim that
        specific slot (a drafter's cache mirrors the target pool, so its
        slots must align with the target's, not with this manager's own
        free-list order)."""
        if not self._free_slots:
            raise RuntimeError("no free slot")
        if slot is None:
            slot = self._free_slots.pop()
        elif slot in self._free_slots:
            self._free_slots.remove(slot)
        else:
            raise RuntimeError(f"slot {slot} is not free")
        self._occupied[slot] = True
        return slot

    def free(self, slot: int):
        if not self._occupied[slot]:
            raise ValueError(f"slot {slot} is not occupied")
        self._occupied[slot] = False
        self.lengths[slot] = 0
        self._free_slots.append(slot)

    def advance(self, slots, counts=None):
        """Bump the sequence position of the given slots — by one token
        each (the classic decode step) or by per-slot ``counts`` (tokens
        COMMITTED by a speculative verify step, 1..K+1 per slot).  One
        vectorized scatter-add, not a per-slot Python loop."""
        idx = np.asarray(list(slots), np.intp)
        if counts is None:
            np.add.at(self.lengths, idx, 1)
        else:
            np.add.at(self.lengths, idx,
                      np.asarray(list(counts), np.int32))

    def cache_len_vector(self) -> jnp.ndarray:
        """(n_slots,) per-slot positions for ``decode_step``.  Free slots sit
        at 0: their writes land in regions never read for an admitted
        request (overwritten by the next ``insert`` in the slab store,
        pointed at the trash block in the paged store)."""
        return jnp.asarray(self.lengths)

    def divergence(self) -> int:
        """Spread of active-slot positions (the quasi-sync E analogue) —
        reads the same vectorized ``lengths``/``_occupied`` state that
        ``advance`` maintains."""
        active = self.lengths[self._occupied]
        if active.size == 0:
            return 0
        return int(active.max() - active.min())

    def admissible_prefix(self, requests) -> int:
        """How many front-of-queue requests could be admitted right now.
        The slab rule is one free slot per request; the paged store
        overrides this with its free-block budget."""
        return min(len(requests), self.n_free)


class CacheManager(BaseCacheManager):
    """Slab store: fixed-capacity per-slot KV / recurrent state.

    All device work (cache allocation, the jitted+donating slot insert, and
    — on a mesh — sharding) goes through the ``executor``; constructing the
    manager directly without one builds a default single-device executor.
    """

    def __init__(self, cfg, n_slots: int, cache_T: int, executor=None,
                 telemetry=None):
        from repro.serving.telemetry import NULL_TELEMETRY
        super().__init__(cfg, n_slots)
        self.cache_T = cache_T
        self.telemetry = telemetry if telemetry is not None else NULL_TELEMETRY
        if executor is None:
            from repro.serving.executor import make_executor
            executor = make_executor(cfg)
        self.executor = executor
        self.cache = executor.zeros_cache(n_slots, cache_T)

    def fits(self, prompt_len: int, max_new_tokens: int) -> bool:
        """Does prompt + generation fit in one slot's capacity?"""
        return prompt_len + max_new_tokens <= self.cache_T

    # -- cache surgery ------------------------------------------------------

    def insert(self, slot: int, src_cache, length: int, src_index: int = 0,
               tokens=None):
        """Install request ``src_index`` of a prefill cache (padded to this
        pool's cache_T) into ``slot`` and set its sequence position.
        ``tokens`` is accepted for interface parity with the paged store
        (which needs the prompt for prefix sharing) and ignored here."""
        if not self._occupied[slot]:
            raise ValueError(f"slot {slot} must be alloc()ed before insert")
        # executor op: jitted once per executor (one compiled insert covers
        # every (slot, src_index) pair), pool buffer donated in place
        with self.telemetry.span("slot_insert", slot=slot, length=length):
            self.cache = self.executor.slot_insert(self.cache, src_cache,
                                                   slot, src_index)
        self.lengths[slot] = length

    def update(self, new_cache):
        """Adopt the cache returned by a batched decode step."""
        self.cache = new_cache


def make_cache_manager(cfg, n_slots: int, cache_T: int, *,
                       backend: str = "slab", block_size: int = 16,
                       num_blocks: Optional[int] = None,
                       executor=None, telemetry=None,
                       faults=None) -> BaseCacheManager:
    """Facade: build the backing store selected by ``backend``, with its
    device ops routed through ``executor`` (None -> single-device), its
    spans on ``telemetry`` (None -> no-op), and — paged only — its pool
    allocations checked against the ``faults`` injector (None -> no-op)."""
    if backend == "slab":
        return CacheManager(cfg, n_slots, cache_T, executor=executor,
                            telemetry=telemetry)
    if backend == "paged":
        from repro.serving.block_pool import PagedCacheManager
        return PagedCacheManager(cfg, n_slots, cache_T,
                                 block_size=block_size, num_blocks=num_blocks,
                                 executor=executor, telemetry=telemetry,
                                 faults=faults)
    raise ValueError(f"unknown cache_backend {backend!r}; "
                     f"expected 'slab' or 'paged'")
