"""Seeded, deterministic fault injection for the serving stack.

The serving loop's correctness story is token-identity under scheduling
perturbation (preempt-and-replay, speculation, paging).  This module adds
the missing half of that story: *fault* perturbation.  A ``FaultInjector``
is threaded through the stack exactly like ``Telemetry`` (ServeConfig ->
ServeLoop -> executor / cache managers / block pool / drafter), with
``NULL_INJECTOR`` as the zero-overhead default, and fires deterministic
faults at named sites:

========  ==============================================================
site      effect
========  ==============================================================
step      transient exception raised before an executor decode/verify
          dispatch (retry-safe: the donated cache is untouched)
prefill   transient exception raised before a prefill dispatch
oom       simulated device OOM on a cache op (slot/paged insert, CoW
          block copy)
pool      forced block-pool exhaustion on ``BlockPool.alloc``
nan       NaN logits injected for a slot inside the jitted decode /
          verify step (exercises the fused NaN guard)
drafter   drafter failure during ``propose_all``
slow      latency spike (sleep) before a decode dispatch, for the
          wall-clock watchdog
cancel    chaos-monkey cancellation of a live request
========  ==============================================================

Faults fire either at a fixed ``rates[site]`` probability per check
(seeded ``random.Random``, so a given seed replays the same schedule for
a fixed call sequence) or at explicit ``schedule`` points ``(site, n)``
meaning "fire on the n-th check of that site" (0-based).  Both can be
bounded by ``max_faults``.

Every fired fault is appended to ``injector.injected`` and, when the
injector is bound to a ServeLoop, emitted as a telemetry ``fault`` record
with ``injected=True`` — the chaos suite asserts the stream accounts for
every injection.
"""

from __future__ import annotations

import random
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple


class InjectedFault(RuntimeError):
    """Base class for every injector-raised fault (recoverable by design)."""

    site = "generic"


class TransientStepFault(InjectedFault):
    """Transient executor failure before a step dispatch (retry-safe)."""

    site = "step"


class DeviceOOM(InjectedFault):
    """Simulated device allocator failure on a cache op."""

    site = "oom"


class DrafterFault(InjectedFault):
    """Simulated drafter failure during proposal."""

    site = "drafter"


class StepTimeout(RuntimeError):
    """A dispatched step exceeded the wall-clock watchdog budget."""


class StepFault(RuntimeError):
    """Wrapper for a *real* (non-injected) executor failure.

    Carries the original exception as ``__cause__``; the serve loop
    treats it as non-retryable (the donated cache may be consumed) and
    goes straight to rebuild-and-replay recovery.
    """

    def __init__(self, site: str, cause: BaseException):
        super().__init__(f"{site}: {type(cause).__name__}: {cause}")
        self.site = site
        self.__cause__ = cause


class FaultInjector:
    """Deterministic fault source.

    Parameters
    ----------
    seed:
        Seeds the per-injector RNG; a fixed seed + fixed call sequence
        replays the identical fault schedule.
    rates:
        ``{site: probability}`` — each check of ``site`` fires with this
        probability.
    schedule:
        Explicit ``(site, n)`` pairs: fire on the n-th check (0-based)
        of ``site``.  Composes with ``rates``.
    max_faults:
        Stop firing after this many total injections (None = unbounded).
    slow_s:
        Sleep duration for ``slow`` site fires.
    """

    enabled = True

    def __init__(self, seed: int = 0,
                 rates: Optional[Dict[str, float]] = None,
                 schedule: Optional[Iterable[Tuple[str, int]]] = None,
                 *, max_faults: Optional[int] = None,
                 slow_s: float = 0.05):
        self.rng = random.Random(seed)
        self.rates = dict(rates or {})
        self.schedule = set(schedule or ())
        self.max_faults = max_faults
        self.slow_s = float(slow_s)
        #: every fired fault, in order: (site, check_index, ctx)
        self.injected: List[Tuple[str, int, dict]] = []
        self._checks: Dict[str, int] = {}
        self._cancelled: set = set()
        self._emit: Optional[Callable[..., None]] = None

    # -- wiring ---------------------------------------------------------
    def bind(self, emit: Optional[Callable[..., None]]) -> None:
        """Attach a telemetry callback called as ``emit(site=...)``."""
        self._emit = emit

    # -- core -----------------------------------------------------------
    def fire(self, site: str, **ctx) -> bool:
        """One check of ``site``; returns True when a fault should fire."""
        n = self._checks.get(site, 0)
        self._checks[site] = n + 1
        if self.max_faults is not None and len(self.injected) >= self.max_faults:
            return False
        hit = (site, n) in self.schedule
        rate = self.rates.get(site, 0.0)
        if not hit and rate > 0.0:
            hit = self.rng.random() < rate
        if hit:
            self.injected.append((site, n, ctx))
            if self._emit is not None:
                self._emit(site=site, **ctx)
        return hit

    # -- raising / side-effecting helpers -------------------------------
    def check(self, site: str, **ctx) -> None:
        """Raise the typed fault for ``site`` when a check fires."""
        if self.fire(site, **ctx):
            exc = {"step": TransientStepFault, "prefill": TransientStepFault,
                   "oom": DeviceOOM, "drafter": DrafterFault}.get(
                       site, InjectedFault)
            raise exc(f"injected {site} fault (check #{self._checks[site] - 1})")

    def delay(self, **ctx) -> None:
        """Sleep ``slow_s`` when a ``slow`` check fires (latency spike)."""
        if self.fire("slow", **ctx):
            time.sleep(self.slow_s)

    def nan_slots(self, slots: Sequence[int], **ctx) -> List[int]:
        """Subset of ``slots`` whose logits should be NaN'd this step."""
        return [s for s in slots if self.fire("nan", slot=int(s), **ctx)]

    def cancel_requests(self, request_ids: Sequence[str], **ctx) -> List[str]:
        """Subset of live ``request_ids`` to chaos-cancel (each at most once)."""
        out = []
        for rid in request_ids:
            if rid in self._cancelled:
                continue
            if self.fire("cancel", request_id=rid, **ctx):
                self._cancelled.add(rid)
                out.append(rid)
        return out


class _NullInjector(FaultInjector):
    """Disabled injector: every check is a strict no-op."""

    enabled = False

    def __init__(self):
        super().__init__(seed=0)

    def bind(self, emit) -> None:  # pragma: no cover - trivial
        pass

    def fire(self, site: str, **ctx) -> bool:
        return False

    def check(self, site: str, **ctx) -> None:
        pass

    def delay(self, **ctx) -> None:
        pass

    def nan_slots(self, slots, **ctx):
        return []

    def cancel_requests(self, request_ids, **ctx):
        return []


#: shared disabled injector — safe default everywhere a FaultInjector is
#: accepted; pinned a strict no-op by token-identity tests.
NULL_INJECTOR = _NullInjector()
