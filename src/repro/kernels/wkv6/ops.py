"""Public wrapper for the WKV6 kernel: model-layout plumbing + padding."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_kernel


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, log_w, u, state, *, chunk: int = 64,
         interpret: bool = False):
    """Model-layout entry point, drop-in for rwkv6.wkv_chunked.

    r/k/v/log_w (B, S, H, N); u (H, N); state (B, H, N, N) f32.
    Returns (out (B, S, H, N) f32, state (B, H, N, N) f32).
    """
    B, S, H, N = r.shape
    pad = (-S) % chunk
    rows = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))
                             ).transpose(0, 2, 1, 3).reshape(B * H, S + pad, N)
    # zero-padded tail: k rows are 0 => no state contribution; log_w 0 =>
    # decay 1 => state passes through unchanged; outputs beyond S sliced off
    out, s = wkv6_kernel(rows(r), rows(k), rows(v), rows(log_w),
                         jnp.tile(u, (B, 1)), state.reshape(B * H, N, N),
                         chunk=chunk, interpret=interpret)
    out = out.reshape(B, H, S + pad, N).transpose(0, 2, 1, 3)[:, :S]
    return out, s.reshape(B, H, N, N)
