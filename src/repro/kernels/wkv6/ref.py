"""Oracles for the WKV6 Pallas kernel: the model stack's step recurrence
(:func:`repro.models.rwkv6.wkv_sequential`) reshaped to kernel layout.

Kernel layout is rows R = batch*heads; the oracle maps rows onto the model's
head dimension (B=1, H=R) so the per-row bonus vector u stays per-head."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models import rwkv6


def wkv6_ref(r, k, v, log_w, u, state):
    """r/k/v/log_w (R, T, N); u (R, N); state (R, N, N) ->
    (out (R, T, N), state_out (R, N, N))."""
    R, T, N = r.shape
    to_model = lambda t: t.transpose(1, 0, 2)[None]     # (1, T, R, N)
    out, s = rwkv6.wkv_sequential(
        to_model(r), to_model(k), to_model(v), to_model(log_w),
        u, state[None])                                  # u: (H=R, N)
    return out[0].transpose(1, 0, 2), s[0]


def wkv6_chunked_ref(r, k, v, log_w, u, state, chunk: int = 32):
    """Second, independent oracle via the chunk-parallel jnp form."""
    to_model = lambda t: t.transpose(1, 0, 2)[None]
    out, s = rwkv6.wkv_chunked(
        to_model(r), to_model(k), to_model(v), to_model(log_w),
        u, state[None], chunk=chunk)
    return out[0].transpose(1, 0, 2), s[0]
