"""Pallas TPU kernel: chunked RWKV-6 WKV recurrence.  [arXiv:2404.05892]

Per (batch x head) row, per chunk of L timesteps (grid dims: rows parallel,
chunks sequential/"arbitrary"), with the (N, N) state carried in VMEM
scratch across chunk steps:

    lc_i   = sum_{s<i} log_w_s                  (per channel, <= 0)
    out_i  = (r_i * exp(lc_i)) . S              cross-chunk     (MXU)
           + sum_{j<i} (r_i . k_j * exp(lc_i - lc_{j+1})) v_j   (intra)
           + (r_i . u*k_i) v_i                  bonus
    S'     = diag(exp(lc_end)) S + sum_j (k_j exp(lc_end - lc_{j+1})) v_j^T

All pairwise decay exponents are <= 0 (numerically safe); the intra-chunk
pair tensor is (L, L, N) in VMEM (L=64, N=64 -> 1 MiB f32).  The state
update and cross-chunk terms are (L,N)x(N,N) MXU matmuls.

The layer-level win vs the pure-jnp chunked form: one VMEM-resident pass per
chunk (r/k/v/w streamed once from HBM, state never leaves VMEM), where the
XLA scan materializes the (L,L,N) pair tensor and carried state through HBM
each step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, out_ref, sout_ref,
            s_ref, *, n_chunks: int, chunk: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        s_ref[...] = s0_ref[0]

    rc = r_ref[0].astype(jnp.float32)          # (L, N)
    kc = k_ref[0].astype(jnp.float32)
    vc = v_ref[0].astype(jnp.float32)
    wc = w_ref[0].astype(jnp.float32)          # log-decay, <= 0
    u = u_ref[0].astype(jnp.float32)           # (N,)
    s = s_ref[...]                             # (N, N)

    lc = jnp.cumsum(wc, axis=0) - wc           # lc_i = sum_{s<i}
    lcs = lc + wc                              # lc_{i+1}
    lc_end = lcs[-1]                           # (N,)

    # cross-chunk: (r * exp(lc)) @ S
    r_dec = rc * jnp.exp(lc)
    out = jnp.dot(r_dec, s, preferred_element_type=jnp.float32)

    # intra-chunk pairs (strictly lower triangular)
    pair = jnp.exp(lc[:, None, :] - lcs[None, :, :])       # (L, L, N)
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = (lj < li)[:, :, None]
    a_mat = jnp.sum(rc[:, None, :] * kc[None, :, :]
                    * jnp.where(tri, pair, 0.0), axis=-1)  # (L, L)
    out = out + jnp.dot(a_mat, vc, preferred_element_type=jnp.float32)

    # bonus: current-token diagonal
    bonus = jnp.sum(rc * u[None, :] * kc, axis=-1)         # (L,)
    out = out + bonus[:, None] * vc
    out_ref[0] = out.astype(out_ref.dtype)

    # state update
    k_dec = kc * jnp.exp(lc_end[None, :] - lcs)
    s_new = jnp.exp(lc_end)[:, None] * s + jnp.dot(
        k_dec.T, vc, preferred_element_type=jnp.float32)
    s_ref[...] = s_new

    @pl.when(c == n_chunks - 1)
    def _done():
        sout_ref[0] = s_new


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret"))
def wkv6_kernel(r, k, v, log_w, u, state, *, chunk: int = 64,
                interpret: bool = False):
    """r/k/v/log_w: (R, T, N) with R = batch*heads; u: (R, N);
    state: (R, N, N) f32.  T % chunk == 0.  Returns (out (R,T,N) f32,
    state_out (R,N,N) f32)."""
    R, T, N = r.shape
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    kern = functools.partial(_kernel, n_chunks=n_chunks, chunk=chunk)
    grid = (R, n_chunks)
    seq_spec = pl.BlockSpec((1, chunk, N), lambda i, c: (i, c, 0))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, N), lambda i, c: (i, 0)),
                  pl.BlockSpec((1, N, N), lambda i, c: (i, 0, 0))],
        out_specs=[pl.BlockSpec((1, chunk, N), lambda i, c: (i, c, 0)),
                   pl.BlockSpec((1, N, N), lambda i, c: (i, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((R, T, N), jnp.float32),
                   jax.ShapeDtypeStruct((R, N, N), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((N, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="wkv6_chunked",
    )(r, k, v, log_w, u, state)
