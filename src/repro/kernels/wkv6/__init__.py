from repro.kernels.wkv6.ops import wkv6  # noqa: F401
from repro.kernels.wkv6 import ref  # noqa: F401
