"""Jitted public wrapper around the BitParticle matmul Pallas kernel.

Handles arbitrary leading batch dims, non-block-aligned shapes (zero padding
— zeros contribute nothing in either exact or approx mode), scale plumbing,
and the interpret-mode fallback used for CPU validation.

:func:`bp_matmul_sharded` is the mesh entry point: it wraps the same kernel
in ``shard_map`` over the active ("data","model") mesh, picking a tensor-
parallel strategy per call — output-column split (zero collectives),
split-K with an exact int32 psum combine, or replicated compute when
neither contraction dim divides — so ``matmul_backend="kernel"`` stays
valid verbatim on the mesh executor.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.bitparticle_matmul.kernel import bp_matmul_kernel


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _pick_block(dim: int, pref: int, align: int) -> int:
    """Block that minimizes padded work, not just the largest one.

    Among `align`-multiples <= pref, pick the block whose grid covers ``dim``
    with the least padding (ties break toward the larger block — fewer grid
    steps).  Always taking ``pref`` nearly doubles the FLOPs when a dim sits
    just past it: M=257 under pref=256 pads to 512, while block 128 pads to
    384.  (`align`-aligned in spirit — interpret mode relaxes hardware
    tiling.)"""
    if dim <= align:
        return align
    best_b, best_pad = align, _round_up(dim, align)
    for b in range(align, pref + 1, align):
        pad = _round_up(dim, b)
        if pad < best_pad or (pad == best_pad and b > best_b):
            best_b, best_pad = b, pad
    return best_b


@functools.partial(
    jax.jit,
    static_argnames=("approx", "block_m", "block_n", "block_k", "interpret"),
)
def bp_matmul(a_q, w_q, scale_a=None, scale_w=None, *, approx: bool = False,
              block_m: int = 256, block_n: int = 256, block_k: int = 256,
              interpret: bool = False):
    """BitParticle quantized matmul.

    a_q: (..., K) int8 activations; w_q: (K, N) int8 weights.
    scale_a: None | scalar | (...,) per-row f32; scale_w: None | (N,) f32.
    Returns f32 (..., N) if any scale given (fused dequant), else int32.
    """
    *lead, k = a_q.shape
    k2, n = w_q.shape
    assert k == k2, (a_q.shape, w_q.shape)
    m = 1
    for d in lead:
        m *= d
    a2 = a_q.reshape(m, k)

    fuse = scale_a is not None or scale_w is not None
    if scale_a is None:
        sa = jnp.ones((m, 1), jnp.float32)
    else:
        sa = jnp.broadcast_to(jnp.asarray(scale_a, jnp.float32).reshape(-1, 1)
                              if jnp.ndim(scale_a) > 0 else
                              jnp.full((m, 1), scale_a, jnp.float32), (m, 1))
    sw = (jnp.ones((1, n), jnp.float32) if scale_w is None
          else jnp.asarray(scale_w, jnp.float32).reshape(1, n))

    bm = _pick_block(m, block_m, 8)
    bn = _pick_block(n, block_n, 128)
    bk = _pick_block(k, block_k, 128)
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)

    a_pad = jnp.pad(a2, ((0, mp - m), (0, kp - k)))
    w_pad = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))
    sa_pad = jnp.pad(sa, ((0, mp - m), (0, 0)), constant_values=1.0)
    sw_pad = jnp.pad(sw, ((0, 0), (0, np_ - n)), constant_values=1.0)

    out = bp_matmul_kernel(
        a_pad, w_pad, sa_pad, sw_pad, approx=approx, fuse_dequant=fuse,
        block_m=bm, block_n=bn, block_k=bk, interpret=interpret,
    )
    return out[:m, :n].reshape(*lead, n)


def _matmul_strategy(lead, k: int, n: int, axes: dict):
    """(batch_axis, strategy) for one sharded matmul call.

    strategy: "col" — weight columns over "model", per-shard fused kernel,
    no collectives (the bit-exact fast path; applies whenever N divides);
    "splitk" — contraction dim over "model", int32 psum combine (exact:
    integer partial sums commute); "rep" — replicated compute.  The batch
    axis additionally splits the leading dim over "data" when it divides.
    """
    model = axes.get("model", 1)
    data = axes.get("data", 1)
    batch_axis = ("data" if lead and data > 1 and lead[0] % data == 0
                  else None)
    if model > 1 and n % model == 0:
        return batch_axis, "col"
    if model > 1 and k % model == 0:
        return batch_axis, "splitk"
    return batch_axis, "rep"


def bp_matmul_sharded(a_q, w_q, scale_a=None, scale_w=None, *,
                      approx: bool = False, interpret: bool = False, mesh):
    """BitParticle quantized matmul partitioned over an active mesh.

    Same numerics contract as :func:`bp_matmul` with scales (always returns
    the dequantized f32 result), but the kernel runs per shard inside
    ``shard_map`` over ``mesh``.  Strategy is chosen from the shapes (see
    :func:`_matmul_strategy`); both the column split and the split-K psum
    keep integer accumulation exact, so the result matches the unsharded
    kernel's dequant epilogue ``acc * scale_a * scale_w`` bit-for-bit.
    """
    from repro.distributed import sharding as shd

    *lead, k = a_q.shape
    k2, n = w_q.shape
    assert k == k2, (a_q.shape, w_q.shape)
    axes = shd.mesh_axes_dict(mesh)
    batch_axis, strategy = _matmul_strategy(lead, k, n, axes)

    sa = (jnp.ones((*lead, 1), jnp.float32) if scale_a is None
          else jnp.broadcast_to(jnp.asarray(scale_a, jnp.float32),
                                (*lead, 1)))
    sw = (jnp.ones((n,), jnp.float32) if scale_w is None
          else jnp.asarray(scale_w, jnp.float32).reshape(n))

    lead_spec = (batch_axis,) + (None,) * (len(lead) - 1) if lead else ()
    a_spec = P(*lead_spec, "model" if strategy == "splitk" else None)
    w_spec = P("model" if strategy == "splitk" else None,
               "model" if strategy == "col" else None)
    sa_spec = P(*lead_spec, None)
    sw_spec = P("model" if strategy == "col" else None)
    out_spec = P(*lead_spec, "model" if strategy == "col" else None)

    def run(aq, wq, sa, sw):
        if strategy == "splitk":
            acc = bp_matmul(aq, wq, approx=approx, interpret=interpret)
            acc = shd.combine_matmul_partials(acc, "model")
            # dequant epilogue after the exact int32 combine, in the same
            # order as the kernel's fused epilogue (acc * sa * sw)
            return acc.astype(jnp.float32) * sa * sw
        return bp_matmul(aq, wq, sa, sw, approx=approx, interpret=interpret)

    fn = shd.portable_shard_map(
        run, mesh=mesh, in_specs=(a_spec, w_spec, sa_spec, sw_spec),
        out_specs=out_spec)
    return fn(a_q, w_q, sa, sw)
