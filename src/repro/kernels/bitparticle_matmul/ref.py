"""Pure-jnp oracle for the BitParticle matmul Pallas kernel.

Two independent reference forms:

  * the *algebraic* form (``bp_matmul_ref``) — the same low-particle
    correction factorization the kernel uses, built on
    :mod:`repro.core.bp_matmul`;
  * the *elementwise* form (``bp_matmul_elementwise_oracle``) — literally
    multiplies every (a, w) pair through the 4x4 IR-matrix reconstruction of
    :mod:`repro.core.bitparticle` and sums over K.  O(M*K*N) memory: small
    shapes only, used to cross-validate the algebraic form in tests.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitparticle as bp
from repro.core import bp_matmul


def bp_matmul_ref(a_q, w_q, mode: str = "bp_exact"):
    """int32 reference: (M, K) int8 x (K, N) int8 -> (M, N) int32."""
    return bp_matmul.bp_matmul_int(a_q, w_q, mode)


def bp_matmul_elementwise_oracle(a_q, w_q, mode: str = "bp_exact"):
    """Bit-faithful elementwise oracle (hardware IR reconstruction per MAC)."""
    mul = bp.multiply_exact if mode == "bp_exact" else bp.multiply_approx
    prods = mul(a_q[:, :, None], w_q[None, :, :])  # (M, K, N) int32
    return jnp.sum(prods, axis=1)


def bp_matmul_dequant_ref(a_q, w_q, scale_a, scale_w, mode: str = "bp_exact"):
    """f32 reference with the fused dequant epilogue.

    scale_a: (M, 1) per-row activation scales; scale_w: (1, N) per-channel.
    """
    acc = bp_matmul_ref(a_q, w_q, mode).astype(jnp.float32)
    return acc * scale_a * scale_w
