from repro.kernels.bitparticle_matmul.ops import bp_matmul  # noqa: F401
from repro.kernels.bitparticle_matmul import ref  # noqa: F401
