"""Pallas TPU kernel: fused BitParticle W8A8 matmul (exact / approximate).

TPU mapping of the paper's MAC unit (DESIGN.md §2):

  * exact mode — BitParticle's exact particlized MAC is bit-identical to an
    integer multiply, so one int8 x int8 -> int32 MXU contraction per block.
  * approx mode — the IR-group drop (groups {0} and {1,4}) factorizes into
    signed low-particle matmuls computed *in the same VMEM pass*:

        acc = A@W - A0@Wlow4 - 4*(A1@W0)

    with A0 = s(|A| & 3), A1 = s(|A|>>2 & 3), W0 = s(|W| & 3),
    Wlow4 = s(|W| & 15).  All three contractions run on int8 MXU tiles.

Grid is (M/bm, N/bn, K/bk) with the K dimension innermost ("arbitrary"
semantics): an int32 accumulator lives in VMEM scratch across K steps, and on
the last K step the dequant epilogue (per-row activation scale x per-channel
weight scale) is applied in-register before the single HBM writeback.

Block defaults (256, 256, 256) keep the working set ≈ 3 x 64 KiB int8 inputs
+ 256 KiB int32 accumulator — comfortably inside a v5e core's 16 MiB VMEM
with double-buffered pipelines, and all dims are multiples of the (32, 128)
int8 tile and the 128-wide MXU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _int8_dot(a, w):
    """int8 x int8 -> int32 MXU contraction of (bm, bk) x (bk, bn)."""
    return jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )


def _signed_particles(x, mask):
    """sign(x) * (|x| & mask) as int8 (x is an int8 block)."""
    xi = x.astype(jnp.int32)
    s = jnp.sign(xi)
    return (s * (jnp.abs(xi) & mask)).astype(jnp.int8)


def _kernel(a_ref, w_ref, sa_ref, sw_ref, o_ref, acc_ref, *, n_k: int,
            approx: bool, fuse_dequant: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]  # (bm, bk) int8
    w = w_ref[...]  # (bk, bn) int8
    acc = _int8_dot(a, w)
    if approx:
        a0 = _signed_particles(a, 3)
        a1 = _signed_particles_shift2(a)
        w0 = _signed_particles(w, 3)
        wlow4 = _signed_particles(w, 15)
        acc = acc - _int8_dot(a0, wlow4) - 4 * _int8_dot(a1, w0)
    acc_ref[...] += acc

    @pl.when(k == n_k - 1)
    def _done():
        if fuse_dequant:
            o_ref[...] = (
                acc_ref[...].astype(jnp.float32) * sa_ref[...] * sw_ref[...]
            ).astype(o_ref.dtype)
        else:
            o_ref[...] = acc_ref[...]


def _signed_particles_shift2(x):
    """sign(x) * ((|x| >> 2) & 3) as int8."""
    xi = x.astype(jnp.int32)
    s = jnp.sign(xi)
    return (s * ((jnp.abs(xi) >> 2) & 3)).astype(jnp.int8)


@functools.partial(
    jax.jit,
    static_argnames=("approx", "fuse_dequant", "block_m", "block_n", "block_k",
                     "interpret"),
)
def bp_matmul_kernel(a_q, w_q, scale_a, scale_w, *, approx: bool = False,
                     fuse_dequant: bool = True, block_m: int = 256,
                     block_n: int = 256, block_k: int = 256,
                     interpret: bool = False):
    """Raw kernel invocation on pre-padded operands.

    a_q: (M, K) int8; w_q: (K, N) int8; scale_a: (M, 1) f32; scale_w: (1, N)
    f32.  M % block_m == K % block_k == N % block_n == 0 (use
    :mod:`.ops` for the padding wrapper).  Returns (M, N) f32 when
    ``fuse_dequant`` else int32.
    """
    m, k = a_q.shape
    k2, n = w_q.shape
    assert k == k2 and m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    n_k = k // block_k
    grid = (m // block_m, n // block_n, n_k)
    kern = functools.partial(_kernel, n_k=n_k, approx=approx,
                             fuse_dequant=fuse_dequant)
    out_dtype = jnp.float32 if fuse_dequant else jnp.int32
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
        name=f"bitparticle_matmul_{'approx' if approx else 'exact'}",
    )(a_q, w_q, scale_a, scale_w)
