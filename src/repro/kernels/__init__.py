"""Pallas TPU kernels for the perf-critical compute layers.

  bitparticle_matmul/  fused W8A8 matmul, BitParticle exact + approximate
                       (IR-group-drop) modes, int32 VMEM accumulators
  wkv6/                chunked RWKV-6 WKV recurrence (VMEM-resident state)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jitted
wrapper) and ref.py (pure-jnp oracle); all are validated in interpret mode.
"""
