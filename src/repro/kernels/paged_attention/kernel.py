"""Pallas TPU kernel: paged-attention decode over block-table-indexed KV.

One decode query per sequence attends over K/V stored in fixed-size blocks
(``block_size`` tokens each) scattered across a physical page pool; the
per-sequence **block table** maps logical block index -> physical page.  The
block tables and valid lengths ride in as *scalar prefetch* operands
(``pltpu.PrefetchScalarGridSpec``), so the page gather is expressed in the
``index_map`` of the K/V BlockSpecs — each grid step DMAs exactly one
physical page into VMEM, and no gathered (B, T, ...) copy of the cache is
ever materialized in HBM (the XLA fallback in :mod:`.ref` does materialize
one; that is the memory the kernel saves).

Grid is ``(B, KV_heads, n_pages)`` with the page dimension innermost
("arbitrary" semantics): the online-softmax state (m, l, acc) for one
(sequence, kv-head) lives in VMEM scratch across page steps, and the output
is written once on the last page step.  Positions ``pos <= lengths[b]`` are
valid (the just-written token's K/V included), matching
``models/attention.py::decode_attention``.  Unused table entries point at
page 0 (the pool's trash block); their scores are masked to -inf before the
softmax so they contribute exactly 0.

VMEM working set per step: one (block_size, D) K page + V page + the
(G, D) accumulator — a few KiB.  For compiled TPU use, prefer
``block_size`` a multiple of 8 and head dim a multiple of 128; interpret
mode (the CPU validation path) relaxes all tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref, *, block_size: int, n_pages: int):
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D), pre-scaled
    k = k_ref[0, :, 0].astype(jnp.float32)       # (block_size, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    pos = p * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    valid = pos <= len_ref[b]                    # (1, bs)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                          # (G, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    # masked entries exponentiate to exactly 0 (guarded against the
    # all-masked-page case where s - m_new could be 0 - 0)
    pexp = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + pexp.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (G, D)

    @pl.when(p == n_pages - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-37)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_kernel(q, k_pages, v_pages, block_tables, lengths, *,
                           interpret: bool = False):
    """q: (B, H, D); k_pages/v_pages: (N, block_size, KH, D);
    block_tables: (B, n_pages) int32 physical page ids; lengths: (B,) int32
    last valid position (inclusive).  Returns (B, H, D) in q.dtype."""
    B, H, D = q.shape
    N, bs, KH, _ = k_pages.shape
    G = H // KH
    n_pages = block_tables.shape[1]
    scale = D ** -0.5
    qr = (q.astype(jnp.float32) * scale).reshape(B, KH, G, D)

    kern = functools.partial(_kernel, block_size=bs, n_pages=n_pages)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block_tables, lengths
        grid=(B, KH, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, p, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, p, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),     # running max m
            pltpu.VMEM((G, 1), jnp.float32),     # running sum l
            pltpu.VMEM((G, D), jnp.float32),     # output accumulator
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_attention_decode",
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      qr, k_pages, v_pages)
    return out.reshape(B, H, D)
