"""Pallas TPU kernel: paged-attention decode over block-table-indexed KV.

One decode query per sequence attends over K/V stored in fixed-size blocks
(``block_size`` tokens each) scattered across a physical page pool; the
per-sequence **block table** maps logical block index -> physical page.  The
block tables and valid lengths ride in as *scalar prefetch* operands
(``pltpu.PrefetchScalarGridSpec``), so the page gather is expressed in the
``index_map`` of the K/V BlockSpecs — each grid step DMAs exactly one
physical page into VMEM, and no gathered (B, T, ...) copy of the cache is
ever materialized in HBM (the XLA fallback in :mod:`.ref` does materialize
one; that is the memory the kernel saves).

Grid is ``(B, KV_heads, n_pages)`` with the page dimension innermost
("arbitrary" semantics): the online-softmax state (m, l, acc) for one
(sequence, kv-head) lives in VMEM scratch across page steps, and the output
is written once on the last page step.  Positions ``pos <= lengths[b]`` are
valid (the just-written token's K/V included), matching
``models/attention.py::decode_attention``.  Unused table entries point at
page 0 (the pool's trash block); their scores are masked to -inf before the
softmax so they contribute exactly 0.

VMEM working set per step: one (block_size, D) K page + V page + the
(G, D) accumulator — a few KiB.  For compiled TPU use, prefer
``block_size`` a multiple of 8 and head dim a multiple of 128; interpret
mode (the CPU validation path) relaxes all tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, *refs,
            block_size: int, n_pages: int, return_state: bool):
    if return_state:
        o_ref, mo_ref, lo_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    b = pl.program_id(0)
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D), pre-scaled
    k = k_ref[0, :, 0].astype(jnp.float32)       # (block_size, D)
    v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (G, bs)
    pos = p * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    valid = pos <= len_ref[b]                    # (1, bs)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                          # (G, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    # masked entries exponentiate to exactly 0 (guarded against the
    # all-masked-page case where s - m_new could be 0 - 0)
    pexp = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_prev * corr + pexp.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        pexp, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)      # (G, D)

    @pl.when(p == n_pages - 1)
    def _done():
        if return_state:
            # hand the raw flash-decoding state to the caller: shards of a
            # split-KV mesh run combine (m, l, acc) across shards before
            # normalizing (sharding.combine_softmax_state)
            o_ref[0, 0] = acc_ref[...].astype(o_ref.dtype)
            mo_ref[0, 0] = m_ref[...]
            lo_ref[0, 0] = l_ref[...]
        else:
            o_ref[0, 0] = (acc_ref[...] /
                           jnp.maximum(l_ref[...], 1e-37)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "return_state"))
def paged_attention_kernel(q, k_pages, v_pages, block_tables, lengths, *,
                           interpret: bool = False,
                           return_state: bool = False):
    """q: (B, H, D); k_pages/v_pages: (N, block_size, KH, D);
    block_tables: (B, n_pages) int32 physical page ids; lengths: (B,) int32
    last valid position (inclusive).  Returns (B, H, D) in q.dtype.

    With ``return_state=True`` the normalization epilogue is skipped and the
    call returns the online-softmax partial state ``(acc, m, l)`` — acc
    (B, KH, G, D) f32 unnormalized, m/l (B, KH, G, 1) f32 — for a cross-
    shard split-KV combine.  A caller whose table covers only masked
    positions gets m = -inf, l = 0, acc = 0 (a neutral element)."""
    B, H, D = q.shape
    N, bs, KH, _ = k_pages.shape
    G = H // KH
    n_pages = block_tables.shape[1]
    scale = D ** -0.5
    qr = (q.astype(jnp.float32) * scale).reshape(B, KH, G, D)

    kern = functools.partial(_kernel, block_size=bs, n_pages=n_pages,
                             return_state=return_state)
    out_block = pl.BlockSpec((1, 1, G, D), lambda b, h, p, bt, ln: (b, h, 0, 0))
    state_block = pl.BlockSpec((1, 1, G, 1),
                               lambda b, h, p, bt, ln: (b, h, 0, 0))
    if return_state:
        out_shape = (jax.ShapeDtypeStruct((B, KH, G, D), jnp.float32),
                     jax.ShapeDtypeStruct((B, KH, G, 1), jnp.float32),
                     jax.ShapeDtypeStruct((B, KH, G, 1), jnp.float32))
        out_specs = (out_block, state_block, state_block)
    else:
        out_shape = jax.ShapeDtypeStruct((B, KH, G, D), q.dtype)
        out_specs = out_block
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                   # block_tables, lengths
        grid=(B, KH, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, p, bt, ln: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, p, bt, ln: (bt[b, p], 0, h, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),     # running max m
            pltpu.VMEM((G, 1), jnp.float32),     # running sum l
            pltpu.VMEM((G, D), jnp.float32),     # output accumulator
        ],
    )
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_attention_decode",
    )(jnp.asarray(block_tables, jnp.int32), jnp.asarray(lengths, jnp.int32),
      qr, k_pages, v_pages)
    if return_state:
        acc, m, l = out
        return acc, m, l
    return out.reshape(B, H, D)
