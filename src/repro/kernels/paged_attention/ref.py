"""XLA gather oracle for the paged-attention decode kernel.

Gathers each sequence's pages into a dense (B, T, KH, D) cache view and runs
the exact arithmetic of ``models/attention.py::decode_attention`` (same
einsum forms, same masking, same f32 softmax) — so it doubles as the proof
that block paging is a pure *storage* transform: on identical page contents
the oracle's output is the slab path's output.

This is also the CPU fallback behind the backend dispatch (and the path
taken when int8 KV scale pages are present — the Pallas kernel handles
float pages only).  It materializes the gathered cache copy per step; the
kernel exists to avoid exactly that HBM traffic on TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def gather_pages(pages, block_tables):
    """(N, bs, ...) pages + (B, P) tables -> (B, P*bs, ...) dense view."""
    g = pages[block_tables]                       # (B, P, bs, ...)
    B, P, bs = g.shape[:3]
    return g.reshape(B, P * bs, *g.shape[3:])


def paged_attention_xla(q, k_pages, v_pages, block_tables, lengths, *,
                        k_scale_pages=None, v_scale_pages=None):
    """q: (B, H, D); k_pages/v_pages: (N, bs, KH, D); block_tables: (B, P);
    lengths: (B,) last valid position (inclusive).  Optional int8-KV scale
    pages: (N, bs, KH).  Returns (B, H, D) in q.dtype."""
    B, H, D = q.shape
    KH = k_pages.shape[2]
    G = H // KH
    k = gather_pages(k_pages, block_tables)       # (B, T, KH, D)
    v = gather_pages(v_pages, block_tables)
    T = k.shape[1]
    scale = D ** -0.5
    qr = (q.astype(jnp.float32) * scale).reshape(B, KH, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qr, k.astype(jnp.float32))
    if k_scale_pages is not None:
        ks = gather_pages(k_scale_pages, block_tables)     # (B, T, KH)
        s = s * jnp.transpose(ks, (0, 2, 1))[:, :, None, :]
    valid = (jnp.arange(T)[None, :] <= lengths[:, None])[:, None, None, :]
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if v_scale_pages is not None:
        vs = gather_pages(v_scale_pages, block_tables)
        p = p * jnp.transpose(vs, (0, 2, 1))[:, :, None, :]
    out = jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)
