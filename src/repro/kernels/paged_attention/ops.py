"""Public paged-attention entry point with backend dispatch.

Routes through the same trace-time backend switch as the BitParticle matmul
(``core.bp_matmul.resolve_matmul_backend``), so the serving engine's
``use_matmul_backend`` scoping covers the attention kernel too:

  ``auto``              Pallas kernel on TPU, XLA gather elsewhere.
  ``kernel``            force the compiled Pallas kernel.
  ``kernel_interpret``  the kernel under the Pallas interpreter (CPU
                        validation — the parity oracle for tests).
  ``xla``               the dense-gather reference (:mod:`.ref`).

Under an active mesh trace the kernel runs inside ``shard_map``: the page
pool is replicated (see ``models/api.py::paged_cache_logical_axes``), so
the block-table page dim is split over "model" when it divides — each shard
runs online softmax over its local KV split and the (m, l, acc) partial
state is combined across shards (``sharding.combine_softmax_state``) —
and the batch dim over "data" when it divides.  A bare ``pallas_call``
must never trace under GSPMD (it would see one shard of its operands), so
the mesh path always wraps, even when no axis divides (replicated compute).

int8 KV scale pages always take the XLA path (the kernel gathers float
pages only); when that demotes an explicit kernel request the downgrade is
recorded once via ``bp_matmul.note_backend_fallback`` instead of happening
silently.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.bp_matmul import note_backend_fallback, resolve_matmul_backend
from repro.distributed import sharding as shd
from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_xla


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    k_scale_pages=None, v_scale_pages=None,
                    backend: str = None):
    """Paged decode attention; see :func:`.ref.paged_attention_xla` for the
    argument contract.  ``backend`` overrides the process/trace default."""
    b = resolve_matmul_backend(backend)
    if b != "xla" and (k_scale_pages is not None or v_scale_pages is not None):
        note_backend_fallback(
            "paged_attention: int8 KV scale pages -> xla gather oracle "
            "(the kernel gathers float pages only)")
        b = "xla"
    if b == "xla":
        return paged_attention_xla(
            q, k_pages, v_pages, block_tables, lengths,
            k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages)
    interpret = b == "kernel_interpret"
    mesh = shd.current_mesh()
    if mesh is not None:
        return _paged_attention_sharded(
            q, k_pages, v_pages, block_tables, lengths,
            interpret=interpret, mesh=mesh)
    return paged_attention_kernel(q, k_pages, v_pages, block_tables, lengths,
                                  interpret=interpret)


def _paged_attention_sharded(q, k_pages, v_pages, block_tables, lengths, *,
                             interpret: bool, mesh):
    """shard_map-partitioned paged-attention kernel over an active mesh.

    KV split: block-table page dim over "model" when divisible — lengths
    are rebased per shard (``length - shard * pages_local * block_size``)
    so the kernel's inclusive ``pos <= length`` mask stays globally
    correct (far shards see a negative length = everything masked, which
    yields the neutral (m=-inf, l=0, acc=0) state).  Batch over "data"
    when divisible.  Page pools ride in replicated.
    """
    axes = shd.mesh_axes_dict(mesh)
    model = axes.get("model", 1)
    data = axes.get("data", 1)
    B, H, D = q.shape
    bs = k_pages.shape[1]
    n_pages = block_tables.shape[1]
    batch_axis = "data" if (data > 1 and B % data == 0) else None
    kv_split = model > 1 and n_pages % model == 0
    pages_local = n_pages // model if kv_split else n_pages

    bt = jnp.asarray(block_tables, jnp.int32)
    ln = jnp.asarray(lengths, jnp.int32)

    def run(q_l, kp, vp, bt_l, ln_l):
        if kv_split:
            shard = jax.lax.axis_index("model")
            ln_shard = ln_l - shard * (pages_local * bs)
            acc, m, l = paged_attention_kernel(
                q_l, kp, vp, bt_l, ln_shard, interpret=interpret,
                return_state=True)
            out = shd.combine_softmax_state(acc, m, l, "model")
            return out.reshape(q_l.shape).astype(q_l.dtype)
        return paged_attention_kernel(q_l, kp, vp, bt_l, ln_l,
                                      interpret=interpret)

    fn = shd.portable_shard_map(
        run, mesh=mesh,
        in_specs=(P(batch_axis, None, None),
                  P(None, None, None, None),
                  P(None, None, None, None),
                  P(batch_axis, "model" if kv_split else None),
                  P(batch_axis)),
        out_specs=P(batch_axis, None, None))
    return fn(q, k_pages, v_pages, bt, ln)
