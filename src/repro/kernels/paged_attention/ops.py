"""Public paged-attention entry point with backend dispatch.

Routes through the same trace-time backend switch as the BitParticle matmul
(``core.bp_matmul.resolve_matmul_backend``), so the serving engine's
``use_matmul_backend`` scoping covers the attention kernel too:

  ``auto``              Pallas kernel on TPU, XLA gather elsewhere.
  ``kernel``            force the compiled Pallas kernel.
  ``kernel_interpret``  the kernel under the Pallas interpreter (CPU
                        validation — the parity oracle for tests).
  ``xla``               the dense-gather reference (:mod:`.ref`).

int8 KV scale pages always take the XLA path (the kernel gathers float
pages only).  Under an active mesh trace (the serving ``MeshExecutor``)
``resolve_matmul_backend`` itself falls back to ``xla``: the kernel is a
single-device program until it grows a ``shard_map`` batch partition, while
the gather oracle partitions natively under GSPMD.
"""

from __future__ import annotations

from repro.core.bp_matmul import resolve_matmul_backend
from repro.kernels.paged_attention.kernel import paged_attention_kernel
from repro.kernels.paged_attention.ref import paged_attention_xla


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    k_scale_pages=None, v_scale_pages=None,
                    backend: str = None):
    """Paged decode attention; see :func:`.ref.paged_attention_xla` for the
    argument contract.  ``backend`` overrides the process/trace default."""
    b = resolve_matmul_backend(backend)
    if b == "xla" or k_scale_pages is not None or v_scale_pages is not None:
        return paged_attention_xla(
            q, k_pages, v_pages, block_tables, lengths,
            k_scale_pages=k_scale_pages, v_scale_pages=v_scale_pages)
    return paged_attention_kernel(q, k_pages, v_pages, block_tables, lengths,
                                  interpret=(b == "kernel_interpret"))
