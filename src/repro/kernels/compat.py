"""Version shims shared by the Pallas kernels."""

from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 named this TPUCompilerParams; newer releases renamed it
try:
    CompilerParams = pltpu.CompilerParams
except AttributeError:
    CompilerParams = pltpu.TPUCompilerParams
