#!/usr/bin/env bash
# Tuned launcher for every PYTHONPATH=src entry point (benchmarks, examples,
# pytest).  Wraps the child in the allocator / logging / XLA environment the
# serving benchmarks assume, so numbers taken through it are comparable:
#
#   ./run.sh benchmarks/serving_throughput.py --tiny
#   ./run.sh --devices 8 benchmarks/sharded_serving.py --tiny
#   ./run.sh -m pytest -q tests/test_telemetry.py
#
# --devices N forces N virtual CPU devices (XLA host-platform device count)
# BEFORE jax initializes — required for mesh runs on a CPU-only box.  Flags
# already present in a caller's XLA_FLAGS win over ours.
set -euo pipefail

usage() {
    sed -n '2,10p' "$0" | sed 's/^# \{0,1\}//'
    exit 2
}

devices=""
while [[ $# -gt 0 ]]; do
    case "$1" in
        --devices) [[ $# -ge 2 ]] || usage; devices="$2"; shift 2 ;;
        --devices=*) devices="${1#--devices=}"; shift ;;
        -h|--help) usage ;;
        *) break ;;
    esac
done
[[ $# -gt 0 ]] || usage

# tcmalloc beats glibc malloc on the fragmented host-side allocation pattern
# of a serving loop (per-step numpy staging buffers); skip silently when the
# library isn't installed
for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
          /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4; do
    if [[ -z "${LD_PRELOAD:-}" && -e "$so" ]]; then
        export LD_PRELOAD="$so"
        break
    fi
done
# silence tcmalloc's large-alloc warnings (weight + KV-cache buffers trip it)
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD="${TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD:-60000000000}"
# mute TF/XLA C++ chatter that would interleave with benchmark CSV output
export TF_CPP_MIN_LOG_LEVEL="${TF_CPP_MIN_LOG_LEVEL:-4}"

export XLA_FLAGS="${XLA_FLAGS:-}"
if [[ -n "$devices" ]]; then
    case "$XLA_FLAGS" in
        *--xla_force_host_platform_device_count=*) ;;   # caller pinned it
        *) export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=$devices" ;;
    esac
fi

repo_root="$(cd "$(dirname "${BASH_SOURCE[0]}")" && pwd)"
export PYTHONPATH="$repo_root/src${PYTHONPATH:+:$PYTHONPATH}"

exec /usr/bin/env python3 "$@"
