"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod 16x16 mesh:

    compute term    = dot_FLOPs_per_device / 197 TFLOP/s
    memory term     = HBM bytes per device / 819 GB/s
    collective term = collective bytes per device / 50 GB/s (per-link)

FLOPs and collective bytes are the trip-count-aware HLO-derived numbers
(launch/hlo_analysis.py); the memory term uses an analytic per-device HBM
traffic model (params + optimizer states + saved activations + caches —
XLA's bytes-accessed also undercounts loop bodies), cross-checked against
compiled memory_analysis sizes.  MODEL_FLOPS = 6ND (train) / 2ND(+attn)
(serve), active params for MoE.
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs.base import SHAPES, get_arch
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


TP_DEGREE = 16   # "model" mesh axis size on both production meshes


def analytic_hbm_bytes_per_device(arch_id: str, shape_name: str,
                                  n_devices: int = 256) -> float:
    """First-order per-device HBM traffic for one step.

    Variant-aware: ``@int8``/``@int8kv`` halve weight bytes (int8 storage);
    ``@int8kv`` additionally halves KV-cache bytes.  Serve-path weights are
    TP-sharded (each chip streams its 1/16 shard once); train-path params
    stream fully per chip after the FSDP gather (fwd + remat + bwd) on top
    of the local optimizer-state traffic.
    """
    base, _, variant = arch_id.partition("@")
    cfg = get_arch(base)
    shape = SHAPES[shape_name]
    n_params = cfg.param_count()
    n_active = cfg.param_count(active_only=True)
    wbytes = 1 if variant in ("int8", "int8kv") else 2
    cbytes = 1 if variant == "int8kv" else 2
    d = cfg.d_model
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        # gathered weights stream through HBM fwd + remat + bwd
        gathered = n_params * 2 * 3
        # local shards: opt states m, v, master read+write (f32) + grads
        local = (n_params * 4 * 6 + n_params * 2 * 2) / n_devices
        # activations: residual stream saved per layer (bf16), write + read
        act = 2 * B * S * d * cfg.num_layers * 2 * 2 / n_devices
        return gathered + local + act
    if shape.kind == "prefill":
        act = B * S * d * cfg.num_layers * 2 * 2 / n_devices
        cache = _cache_bytes(cfg, B, S, cbytes) / n_devices
        return n_active * wbytes / TP_DEGREE + act + cache
    # decode: every (active) weight shard read once + cache read + write
    cache = _cache_bytes(cfg, B, S, cbytes)
    return n_active * wbytes / TP_DEGREE + cache / n_devices


def _cache_bytes(cfg, B, T, cbytes: int = 2) -> float:
    hd = cfg.resolved_head_dim
    if cfg.family in ("dense", "moe", "vlm"):
        return 2.0 * cfg.num_layers * B * T * cfg.num_kv_heads * hd * cbytes
    if cfg.family == "audio":
        return (2.0 * cfg.num_layers * B * (T + T // 4)
                * cfg.num_kv_heads * hd * cbytes)
    if cfg.family == "ssm":
        n = cfg.rwkv_head_dim
        h = cfg.d_model // n
        return cfg.num_layers * B * h * n * n * 4.0
    if cfg.family == "hybrid":
        n_sup = cfg.num_layers // cfg.attn_every
        kv = 2.0 * n_sup * B * T * cfg.num_kv_heads * hd * cbytes
        ssm = cfg.num_layers * B * (2 * cfg.d_model // cfg.ssm_head_dim) \
            * cfg.ssm_head_dim * cfg.ssm_state * 4.0
        return kv + ssm
    raise ValueError(cfg.family)


def load_records(mesh: str = "pod16x16"):
    out = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        out[(rec["arch"], rec["shape"])] = rec
    return out


def roofline_row(rec):
    arch, shape = rec["arch"], rec["shape"]
    base = arch.partition("@")[0]
    n_dev = rec["n_devices"]
    flops_dev = rec["dot_flops_per_device"]
    flops_int_dev = rec.get("dot_flops_int_per_device", 0.0)
    coll_dev = sum(rec["collective_bytes"].values())
    hbm_dev = analytic_hbm_bytes_per_device(arch, shape, n_dev)
    # int8 contractions run at 2x the MXU rate
    t_compute = (flops_dev / PEAK_FLOPS_BF16
                 + flops_int_dev / (2 * PEAK_FLOPS_BF16))
    t_memory = hbm_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    # recompute MODEL_FLOPS fresh (param-count bookkeeping may be fixed
    # after an artifact was written)
    from repro.models import api as model_api
    model_flops = model_api.model_flops(get_arch(base), SHAPES[shape])
    flops_dev = flops_dev + flops_int_dev
    useful_ratio = model_flops / (flops_dev * n_dev) if flops_dev else 0.0
    # roofline fraction: useful model FLOPs per chip-second at the bound
    frac = (model_flops / n_dev / PEAK_FLOPS_BF16) / bound if bound else 0.0
    # peak_memory is XLA's heap-simulation peak (arguments included in
    # buffer liveness)
    peak_mem = (rec["memory_analysis"].get("peak_memory") or 0)
    return {
        "arch": arch, "shape": shape,
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": flops_dev * n_dev,
        "useful_flops_ratio": useful_ratio,
        "roofline_fraction": frac,
        "mem_per_device_gib": peak_mem / 2**30,
        "fits_16gib": peak_mem < 16 * 2**30,
    }


def run():
    recs = load_records("pod16x16")
    all_rows = [roofline_row(r) for r in recs.values() if r.get("ok")]
    rows = [r for r in all_rows if "@" not in r["arch"]]
    variant_rows = [r for r in all_rows if "@" in r["arch"]]
    failures = [(a, s) for (a, s), r in recs.items() if not r.get("ok")]
    multi = load_records("pod2x16x16")
    multi_ok = sum(1 for r in multi.values()
                   if r.get("ok") and "@" not in r["arch"])
    rows.sort(key=lambda r: r["roofline_fraction"])
    # pair each variant with its baseline for the §Perf before/after table
    base_by_key = {(r["arch"], r["shape"]): r for r in rows}
    perf_pairs = []
    for v in variant_rows:
        b = base_by_key.get((v["arch"].partition("@")[0], v["shape"]))
        if b:
            perf_pairs.append({"cell": f"{v['arch']} {v['shape']}",
                               "before": {k: b[k] for k in
                                          ("compute_s", "memory_s",
                                           "collective_s",
                                           "roofline_fraction")},
                               "after": {k: v[k] for k in
                                         ("compute_s", "memory_s",
                                          "collective_s",
                                          "roofline_fraction")}})
    return {
        "rows": rows,
        "variant_rows": variant_rows,
        "perf_pairs": perf_pairs,
        "n_cells_single_pod_ok": len(rows),
        "n_cells_multi_pod_ok": multi_ok,
        "failures": failures,
        "worst_3_roofline": [(r["arch"], r["shape"],
                              round(r["roofline_fraction"], 4))
                             for r in rows[:3]],
        "most_collective_bound": [
            (r["arch"], r["shape"], round(r["collective_s"], 4))
            for r in sorted(rows, key=lambda x: -x["collective_s"])[:3]],
    }


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute_s | memory_s | collective_s | dominant |"
           " useful_ratio | roofline_frac | mem/dev GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    body = "".join(
        f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
        f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
        f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
        f"{r['mem_per_device_gib']:.2f} |\n"
        for r in sorted(rows, key=lambda x: (x["arch"], x["shape"])))
    return hdr + body
