"""Mesh-sharded serving vs single-device at the same workload.

Runs the continuous-batching engine over a request stream twice — on the
default single-device executor and on a ``("data", "model")`` mesh
(``MeshExecutor``: weights TP over "model", slab KV cache sharded per the
decode recipe) — and reports per-step decode latency, throughput, and the
token-identity check (greedy outputs MUST match across executors; the
acceptance bar is 0 mismatches).

Virtual CPU devices need ``XLA_FLAGS`` set before jax initializes, so the
measurement runs in a WORKER SUBPROCESS (``--worker``); the parent (the CLI
or ``benchmarks/run.py``, whose process has already initialized jax
single-device) parses the worker's JSON.  On real TPU slices the worker
runs against the physical devices unchanged.

On virtual CPU devices the mesh numbers measure dispatch + emulated
collective overhead, not real scaling — the benchmark is a correctness +
plumbing smoke there (CI), and a scaling probe on real hardware.

    PYTHONPATH=src python benchmarks/sharded_serving.py [--tiny]
    PYTHONPATH=src python benchmarks/sharded_serving.py --mesh 2x4
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

if __package__ in (None, ""):  # ran as a script: make `benchmarks.` importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

_DEVICE_ENV = "--xla_force_host_platform_device_count"


def _measure(tiny: bool, mesh_shape, seed: int, backend: str,
             n_requests: int, rate: float) -> dict:
    """Worker-side measurement (jax already initialized with enough
    devices)."""
    import numpy as np
    import jax
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import (Request, SchedulerConfig, ServeConfig,
                               ServingEngine)

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2 if tiny else 4, d_model=64 if tiny else 128,
        d_ff=128 if tiny else 256, vocab_size=256, head_dim=16,
        matmul_mode="bp_exact")
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    prompt_len = 8 if tiny else 16
    max_new_hi = 6 if tiny else 12
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (n_requests, prompt_len), 2, cfg.vocab_size),
        np.int32)
    max_news = rng.integers(2, max_new_hi + 1, size=n_requests).tolist()
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    sched = SchedulerConfig(lead_window=2)
    cache_T = prompt_len + max_new_hi + 4

    def reqs():
        return [Request(prompt=prompts[i], max_new_tokens=int(max_news[i]),
                        arrival_time=float(arrivals[i]))
                for i in range(n_requests)]

    def cell(shape):
        engine = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=max_new_hi, temperature=0.0,
            cache_backend=backend, block_size=4, mesh_shape=shape))
        engine.serve(reqs()[:2], n_slots=4, cache_T=cache_T,
                     sched_cfg=sched)                      # warmup compile
        rep = engine.serve(reqs(), n_slots=4, cache_T=cache_T,
                           sched_cfg=sched)
        toks = [list(r.tokens) for r in
                sorted(rep.results, key=lambda r: r.request_id)]
        return {
            "mesh_shape": list(shape) if shape else None,
            "decode_steps": int(rep.steps),
            "decode_s": float(rep.decode_s),
            "per_step_ms": float(1e3 * rep.decode_s / max(rep.steps, 1)),
            "prefill_s": float(rep.prefill_s),
            "decode_tokens_per_s": float(rep.decode_tokens_per_s),
            "slot_utilization": float(rep.slot_utilization),
        }, toks

    single, ref_toks = cell(None)
    sharded, mesh_toks = cell(tuple(mesh_shape))
    mismatches = sum(a != b for a, b in zip(ref_toks, mesh_toks))
    return {
        "backend": backend,
        "n_requests": n_requests,
        "n_devices": len(jax.devices()),
        "cells": [single, sharded],
        "single_per_step_ms": single["per_step_ms"],
        "sharded_per_step_ms": sharded["per_step_ms"],
        "sharded_vs_single_step_ratio": (
            sharded["per_step_ms"] / max(single["per_step_ms"], 1e-9)),
        "token_mismatches": int(mismatches),
    }


def run(tiny: bool = False, mesh_shape=(2, 4), seed: int = 0,
        backend: str = "slab", n_requests: int = None,
        rate: float = 0.5) -> dict:
    """Spawn the worker with enough virtual devices and parse its JSON.
    (Callable from ``benchmarks/run.py``, whose jax is already initialized
    single-device — device count is locked at first backend init.)"""
    if n_requests is None:
        n_requests = 6 if tiny else 16
    n_dev = int(mesh_shape[0]) * int(mesh_shape[1])
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith(_DEVICE_ENV)]
    env["XLA_FLAGS"] = " ".join(flags + [f"{_DEVICE_ENV}={n_dev}"])
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, os.path.abspath(__file__), "--worker",
           "--mesh", f"{mesh_shape[0]}x{mesh_shape[1]}",
           "--seed", str(seed), "--backend", backend,
           "--requests", str(n_requests), "--rate", str(rate)]
    if tiny:
        cmd.append("--tiny")
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                         env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded serving worker failed:\n{out.stdout}\n{out.stderr}")
    return json.loads(out.stdout.splitlines()[-1])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size (seconds, not minutes)")
    ap.add_argument("--mesh", default="2x4",
                    help="mesh shape DATAxMODEL (e.g. 2x4)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="slab", choices=["slab", "paged"])
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=0.5)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    mesh_shape = tuple(int(d) for d in args.mesh.lower().split("x"))

    if args.worker:
        r = _measure(args.tiny, mesh_shape, args.seed, args.backend,
                     args.requests or (6 if args.tiny else 16), args.rate)
        print(json.dumps(r))
        return 0

    r = run(tiny=args.tiny, mesh_shape=mesh_shape, seed=args.seed,
            backend=args.backend, n_requests=args.requests, rate=args.rate)
    from benchmarks.common import save_artifact
    path = save_artifact("BENCH_sharded", r)
    single, sharded = r["cells"]
    print(f"backend={r['backend']} requests={r['n_requests']} "
          f"devices={r['n_devices']}")
    print(f"single:  {single['decode_steps']} steps, "
          f"{single['per_step_ms']:.2f} ms/step, "
          f"{single['decode_tokens_per_s']:.1f} tok/s")
    print(f"mesh {tuple(sharded['mesh_shape'])}: "
          f"{sharded['decode_steps']} steps, "
          f"{sharded['per_step_ms']:.2f} ms/step, "
          f"{sharded['decode_tokens_per_s']:.1f} tok/s")
    print(f"sharded/single per-step ratio: "
          f"{r['sharded_vs_single_step_ratio']:.2f}x "
          f"(virtual-CPU meshes emulate collectives — correctness smoke, "
          f"not a scaling claim)")
    print(f"token mismatches: {r['token_mismatches']}")
    print(f"artifact: {path}")
    if r["token_mismatches"]:
        print("ERROR: sharded outputs diverged from single-device",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
