"""Mesh-sharded serving: kernel-vs-oracle cells at the same workload.

Runs the continuous-batching engine over one request stream through a
(matmul backend x executor) grid — the XLA oracle and the Pallas kernel
path (interpret mode on CPU), each on the default single-device executor
and on a ``("data", "model")`` mesh (``MeshExecutor``: weights TP over
"model", slab KV cache sharded per the decode recipe, Pallas kernels
shard_map-partitioned) — and reports per-step decode latency percentiles
(p50/p90/p99 pooled over decode+verify steps, gated by
``benchmarks/compare.py``), throughput, and the token-identity check
(greedy outputs MUST match across every cell; the acceptance bar is 0
mismatches).

Virtual CPU devices need ``XLA_FLAGS`` set before jax initializes, so the
measurement runs in a WORKER SUBPROCESS (``--worker``); the parent (the CLI
or ``benchmarks/run.py``, whose process has already initialized jax
single-device) parses the worker's JSON.  On real TPU slices the worker
runs against the physical devices unchanged.

On virtual CPU devices the mesh numbers measure dispatch + emulated
collective overhead (and the kernel cells pay the Pallas interpreter), not
real scaling — the benchmark is a correctness + plumbing smoke there (CI),
and a scaling probe on real hardware.

    PYTHONPATH=src python benchmarks/sharded_serving.py [--tiny]
    PYTHONPATH=src python benchmarks/sharded_serving.py --mesh 2x4
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

if __package__ in (None, ""):  # ran as a script: make `benchmarks.` importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

_DEVICE_ENV = "--xla_force_host_platform_device_count"

#: (matmul_backend, use_mesh) grid; the first two cells keep the historic
#: BENCH_sharded layout (single then mesh on the resolved default backend)
#: so compare.py baselines stay meaningful across the kernel-cell addition.
_GRID = (("xla", False), ("xla", True),
         ("kernel_interpret", False), ("kernel_interpret", True))


def _measure(tiny: bool, mesh_shape, seed: int, backend: str,
             n_requests: int, rate: float) -> dict:
    """Worker-side measurement (jax already initialized with enough
    devices)."""
    import tempfile

    import numpy as np
    import jax
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import (Request, SchedulerConfig, ServeConfig,
                               ServingEngine, Telemetry, percentiles,
                               read_jsonl)

    base_cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2 if tiny else 4, d_model=64 if tiny else 128,
        d_ff=128 if tiny else 256, vocab_size=256, head_dim=16,
        matmul_mode="bp_exact")
    rng = np.random.default_rng(seed)
    prompt_len = 8 if tiny else 16
    max_new_hi = 6 if tiny else 12
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (n_requests, prompt_len), 2,
        base_cfg.vocab_size), np.int32)
    max_news = rng.integers(2, max_new_hi + 1, size=n_requests).tolist()
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    sched = SchedulerConfig(lead_window=2)
    cache_T = prompt_len + max_new_hi + 4

    def reqs():
        return [Request(prompt=prompts[i], max_new_tokens=int(max_news[i]),
                        arrival_time=float(arrivals[i]))
                for i in range(n_requests)]

    def cell(matmul_backend, shape, tmp):
        cfg = base_cfg.replace(matmul_backend=matmul_backend)
        params = api.init(jax.random.PRNGKey(0), cfg)
        engine = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=max_new_hi, temperature=0.0,
            cache_backend=backend, block_size=4, mesh_shape=shape))
        engine.serve(reqs()[:2], n_slots=4, cache_T=cache_T,
                     sched_cfg=sched)                      # warmup compile
        metrics_path = os.path.join(
            tmp, f"{matmul_backend}_{'mesh' if shape else 'single'}.jsonl")
        tel = Telemetry(metrics_path=metrics_path)
        import dataclasses
        engine.serve_cfg = dataclasses.replace(engine.serve_cfg,
                                               telemetry=tel)
        try:
            rep = engine.serve(reqs(), n_slots=4, cache_T=cache_T,
                               sched_cfg=sched)
        finally:
            tel.close()
        step_ms = [1e3 * r["wall_s"] for r in read_jsonl(metrics_path)
                   if r.get("kind") in ("decode", "verify")]
        toks = [list(r.tokens) for r in
                sorted(rep.results, key=lambda r: r.request_id)]
        return {
            "matmul_backend": matmul_backend,
            "mesh_shape": list(shape) if shape else None,
            "decode_steps": int(rep.steps),
            "decode_s": float(rep.decode_s),
            # gated: suffix-matched by benchmarks/compare.py
            "per_step_ms": percentiles(step_ms),
            "mean_step_ms": float(1e3 * rep.decode_s / max(rep.steps, 1)),
            "prefill_s": float(rep.prefill_s),
            "decode_tokens_per_s": float(rep.decode_tokens_per_s),
            "slot_utilization": float(rep.slot_utilization),
        }, toks

    cells, all_toks = [], []
    with tempfile.TemporaryDirectory(prefix="sharded_serving_") as tmp:
        for matmul_backend, use_mesh in _GRID:
            c, toks = cell(matmul_backend,
                           tuple(mesh_shape) if use_mesh else None, tmp)
            cells.append(c)
            all_toks.append(toks)
    mismatches = sum(sum(a != b for a, b in zip(all_toks[0], toks))
                     for toks in all_toks[1:])

    def mean_ms(matmul_backend, use_mesh):
        i = _GRID.index((matmul_backend, use_mesh))
        return cells[i]["mean_step_ms"]

    return {
        "backend": backend,
        "n_requests": n_requests,
        "n_devices": len(jax.devices()),
        "cells": cells,
        "single_per_step_ms": mean_ms("xla", False),
        "sharded_per_step_ms": mean_ms("xla", True),
        "sharded_vs_single_step_ratio": (
            mean_ms("xla", True) / max(mean_ms("xla", False), 1e-9)),
        "kernel_vs_oracle_mesh_ratio": (
            mean_ms("kernel_interpret", True)
            / max(mean_ms("xla", True), 1e-9)),
        "token_mismatches": int(mismatches),
    }


def run(tiny: bool = False, mesh_shape=(2, 4), seed: int = 0,
        backend: str = "slab", n_requests: int = None,
        rate: float = 0.5) -> dict:
    """Spawn the worker with enough virtual devices and parse its JSON.
    (Callable from ``benchmarks/run.py``, whose jax is already initialized
    single-device — device count is locked at first backend init.)"""
    if n_requests is None:
        n_requests = 6 if tiny else 16
    n_dev = int(mesh_shape[0]) * int(mesh_shape[1])
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith(_DEVICE_ENV)]
    env["XLA_FLAGS"] = " ".join(flags + [f"{_DEVICE_ENV}={n_dev}"])
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, os.path.abspath(__file__), "--worker",
           "--mesh", f"{mesh_shape[0]}x{mesh_shape[1]}",
           "--seed", str(seed), "--backend", backend,
           "--requests", str(n_requests), "--rate", str(rate)]
    if tiny:
        cmd.append("--tiny")
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                         env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"sharded serving worker failed:\n{out.stdout}\n{out.stderr}")
    return json.loads(out.stdout.splitlines()[-1])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size (seconds, not minutes)")
    ap.add_argument("--mesh", default="2x4",
                    help="mesh shape DATAxMODEL (e.g. 2x4)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--backend", default="slab", choices=["slab", "paged"])
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=0.5)
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    mesh_shape = tuple(int(d) for d in args.mesh.lower().split("x"))

    if args.worker:
        r = _measure(args.tiny, mesh_shape, args.seed, args.backend,
                     args.requests or (6 if args.tiny else 16), args.rate)
        print(json.dumps(r))
        return 0

    r = run(tiny=args.tiny, mesh_shape=mesh_shape, seed=args.seed,
            backend=args.backend, n_requests=args.requests, rate=args.rate)
    from benchmarks.common import save_artifact
    path = save_artifact("BENCH_sharded", r)
    print(f"backend={r['backend']} requests={r['n_requests']} "
          f"devices={r['n_devices']}")
    for c in r["cells"]:
        where = (f"mesh {tuple(c['mesh_shape'])}" if c["mesh_shape"]
                 else "single")
        p = c["per_step_ms"] or {}
        print(f"{c['matmul_backend']:>16s} / {where:<10s} "
              f"{c['decode_steps']:3d} steps, per-step ms "
              f"p50={p.get('p50', float('nan')):.2f} "
              f"p90={p.get('p90', float('nan')):.2f} "
              f"p99={p.get('p99', float('nan')):.2f}  "
              f"{c['decode_tokens_per_s']:.1f} tok/s")
    print(f"sharded/single per-step ratio (xla): "
          f"{r['sharded_vs_single_step_ratio']:.2f}x; "
          f"kernel/oracle on the mesh: "
          f"{r['kernel_vs_oracle_mesh_ratio']:.2f}x "
          f"(virtual-CPU meshes emulate collectives and the kernel cells "
          f"pay the Pallas interpreter — correctness smoke, not a scaling "
          f"claim)")
    print(f"token mismatches: {r['token_mismatches']}")
    print(f"artifact: {path}")
    if r["token_mismatches"]:
        print("ERROR: outputs diverged across backend/mesh cells",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
