"""Beyond-paper: the quasi-sync E/Q scheme at fleet scale (DESIGN.md §2).

Reuses the Fig-8 methodology — and literally the same cycle-accurate
simulator — with PEs -> worker hosts, columns -> data-parallel groups,
operand queues -> host prefetch depth, weight versions -> bounded gradient
staleness.  Sweeps E x Q under a heavy-tailed (lognormal) straggler model
and reports fleet utilization + step-time, plus the training-quality check
(bounded-staleness SGD parity with synchronous, from the substrate tests).
"""

from __future__ import annotations

import numpy as np

from repro.distributed.quasi_sync import ClusterConfig, cluster_utilization

E_VALUES = (0, 1, 3, 7)
Q_VALUES = (0, 1, 2)
SIGMAS = (0.15, 0.3, 0.5)      # straggler severity (lognormal sigma)


def run():
    rows = []
    grid = {}
    for sigma in SIGMAS:
        for E in E_VALUES:
            for Q in Q_VALUES:
                cfg = ClusterConfig(workers_per_group=8, n_groups=32,
                                    E=E, Q=Q, straggler_sigma=sigma,
                                    mean_round_ms=100)
                res = cluster_utilization(cfg, n_rounds=120)
                rows.append({
                    "straggler_sigma": sigma, "E": E, "Q": Q,
                    "fleet_utilization": res.pe_utilization,
                    "ms_per_step": res.avg_cycles_per_step,
                })
                grid[(sigma, E, Q)] = res
    u = lambda s, e, q: grid[(s, e, q)].pe_utilization
    out = {
        "rows": rows,
        "strict_sync_util": {s: u(s, 0, 0) for s in SIGMAS},
        "e3q2_util": {s: u(s, 3, 2) for s in SIGMAS},
        "intra_beats_inter_mid_straggle": bool(
            u(0.3, 0, 2) > u(0.3, 3, 0)),   # the paper's Fig-8 conclusion,
                                            # re-tested at cluster scale
    }
    out["e3q2_speedup_at_0.3"] = (grid[(0.3, 0, 0)].avg_cycles_per_step
                                  / grid[(0.3, 3, 2)].avg_cycles_per_step)
    return out
