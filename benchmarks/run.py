"""Benchmark harness: one module per paper table/figure (+ roofline).

Prints ``name,us_per_call,derived`` CSV and writes JSON artifacts to
experiments/bench/.  Usage:

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig11 t3   # substring filter
"""

from __future__ import annotations

import sys

from benchmarks.common import save_artifact, timed

BENCHMARKS = [
    # (name, import path, headline-metric extractor)
    ("fig1_sparsity", "benchmarks.fig1_sparsity",
     lambda r: f"sign_mag_advantage={r['sign_mag_advantage']:.3f}"),
    ("table3_mac_unit", "benchmarks.table3_mac_unit",
     lambda r: f"bp60_area_gain={r['bp_exact_area_eff_gain_60pct']:.3f};"
               f"max_cycle_err={r['max_bp_modeled_cycle_error']:.3f}"),
    ("fig8_9_elasticity", "benchmarks.fig8_9_elasticity",
     lambda r: f"e3q2_util={r['e3q2_util_range'][0]:.3f}-"
               f"{r['e3q2_util_range'][1]:.3f}"),
    ("fig10_zero_filter", "benchmarks.fig10_zero_filter",
     lambda r: f"thr_gain@0.8={r['throughput_gain_at_0.8']:.3f}"),
    ("fig11_skipped", "benchmarks.fig11_skipped",
     lambda r: f"bp>serial_from_bs={r['bp_beats_bitserial_from_bs']}"),
    ("fig12_13_array", "benchmarks.fig12_13_array",
     lambda r: f"bp_vs_bitwave_area={r['bp_vs_bitwave_area_eff']:.3f};"
               f"approx_energy={r['approx_vs_exact_energy']:.3f}"),
    ("accuracy_approx", "benchmarks.accuracy_approx",
     lambda r: f"mlp_drop={r['mlp_acc_drop_exact_to_approx']:.3f}"),
    ("cluster_quasi_sync", "benchmarks.cluster_quasi_sync",
     lambda r: f"e3q2_speedup@0.3={r['e3q2_speedup_at_0.3']:.2f}x"),
    ("ablation_drop_groups", "benchmarks.ablation_drop_groups",
     lambda r: f"paper_err={r['paper_choice_max_error']};"
               f"3rd_blowup={r['third_group_error_blowup']:.1f}x"),
    ("roofline", "benchmarks.roofline",
     lambda r: f"cells_ok={r['n_cells_single_pod_ok']}"
               f"+{r['n_cells_multi_pod_ok']}mp"),
    ("paged_memory", "benchmarks.paged_memory",
     lambda r: f"concurrency_gain={r['admitted_concurrency_gain']:.2f}x;"
               f"mismatches={r['token_mismatches']}"),
    ("sharded_serving", "benchmarks.sharded_serving",
     lambda r: f"step_ratio={r['sharded_vs_single_step_ratio']:.2f}x;"
               f"mismatches={r['token_mismatches']}"),
    ("spec_decode", "benchmarks.spec_decode",
     lambda r: f"model_step_reduction={r['model_step_reduction']:.2f}x;"
               f"pl_accept={r['prompt_lookup_acceptance_rate']:.2f};"
               f"mismatches={r['token_mismatches']}"),
    ("production_mix", "benchmarks.production_mix",
     lambda r: f"p99_ms={r['per_step_ms']['p99']:.2f};"
               f"hw_samples={r['n_hw_samples']};"
               f"mismatches={r['token_mismatches']}"),
    ("frontdoor", "benchmarks.frontdoor",
     lambda r: f"affinity_gain={r['routing']['affinity_gain_blocks']};"
               f"slo_p90={r['slo']['interactive_p90_slo']:.1f};"
               f"mismatches={r['token_mismatches']}"),
    ("chaos_smoke", "benchmarks.chaos_smoke",
     lambda r: f"injected={r['n_injected_faults']};"
               f"recoveries={r['n_recoveries']};"
               f"mismatches={r['survivor_token_mismatches']};"
               f"leaked={r['pool_leaked_blocks']}"),
]


def main() -> None:
    filters = [a.lower() for a in sys.argv[1:]]
    print("name,us_per_call,derived")
    failures = 0
    for name, modpath, headline in BENCHMARKS:
        if filters and not any(f in name for f in filters):
            continue
        try:
            mod = __import__(modpath, fromlist=["run"])
            result, us = timed(mod.run)
            save_artifact(name, result)
            print(f"{name},{us:.0f},{headline(result)}", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name},-1,ERROR:{type(e).__name__}:{e}", flush=True)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
