"""Paged vs slab KV cache at a FIXED HBM budget (the memory-level Fig. 8/9).

A shared-system-prompt Poisson workload (every request = one long shared
prefix + a short unique suffix, heterogeneous output lengths) against a
reduced qwen2-family model.  Both backends get the same KV token budget:

  * **slab** — the budget buys ``budget // cache_T`` worst-case slots, so
    admission is governed by ``prompt + max_new`` reservations even though
    most requests finish early and most prompt bytes are identical;
  * **paged** — the same budget buys ``budget // block_size`` blocks; the
    shared prefix is stored ONCE (hash-trie prefix sharing) and per-request
    state grows block-by-block, so admitted concurrency is governed by
    *actual* residency.  LRU-backed preemption-and-requeue keeps outputs
    token-exact when the pool momentarily runs dry.

Headline: admitted concurrency (peak simultaneously-decoding requests) and
decode throughput at the same HBM spend — the acceptance bar is >= 2x
concurrency.  Greedy outputs are verified token-identical across backends.

    PYTHONPATH=src python benchmarks/paged_memory.py [--tiny]
    PYTHONPATH=src python benchmarks/paged_memory.py --budget-slots 2
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np
import jax

if __package__ in (None, ""):  # ran as a script: make `benchmarks.` importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def _poisson_arrivals(rng, n: int, rate: float) -> np.ndarray:
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.cumsum(gaps)


def run(tiny: bool = False, seed: int = 0, budget_slots: int = None,
        n_requests: int = None, rate: float = 1.0, block_size: int = 4):
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import (Request, SchedulerConfig, ServeConfig,
                               ServingEngine, percentiles)

    if budget_slots is None:
        budget_slots = 2 if tiny else 3      # HBM budget, in slab slots
    if n_requests is None:
        n_requests = 8 if tiny else 24
    sys_len = 16 if tiny else 32             # shared system prompt
    uniq_len = 4
    max_new_hi = 6 if tiny else 8
    margin = 4

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2 if tiny else 4, d_model=64 if tiny else 128,
        d_ff=128 if tiny else 256, vocab_size=256, head_dim=16)
    params = api.init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(seed)
    sys_prompt = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (sys_len,), 2,
                           cfg.vocab_size), np.int32)
    suffixes = np.asarray(
        jax.random.randint(jax.random.PRNGKey(2), (n_requests, uniq_len), 2,
                           cfg.vocab_size), np.int32)
    prompts = [np.concatenate([sys_prompt, suffixes[i]])
               for i in range(n_requests)]
    max_news = rng.integers(2, max_new_hi + 1, size=n_requests).tolist()
    arrivals = _poisson_arrivals(rng, n_requests, rate)

    prompt_len = sys_len + uniq_len
    cache_T = prompt_len + max_new_hi + margin
    budget_tokens = budget_slots * cache_T   # the fixed HBM budget
    num_blocks = 1 + budget_tokens // block_size   # +1: trash block

    def reqs():
        return [Request(prompt=prompts[i], max_new_tokens=int(max_news[i]),
                        arrival_time=float(arrivals[i]))
                for i in range(n_requests)]

    sched = SchedulerConfig(lead_window=2)

    def engine(backend):
        return ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=max_new_hi, temperature=0.0,
            cache_backend=backend, block_size=block_size))

    # slab: the budget buys `budget_slots` worst-case reservations
    slab_eng = engine("slab")
    slab_eng.serve(reqs()[:2], n_slots=budget_slots, cache_T=cache_T,
                   sched_cfg=sched)                       # warmup compile
    slab = slab_eng.serve(reqs(), n_slots=budget_slots, cache_T=cache_T,
                          sched_cfg=sched)

    # paged: same token budget in blocks; slots are cheap (block tables),
    # so concurrency is governed by actual block residency
    paged_slots = min(n_requests, 4 * budget_slots)
    paged_eng = engine("paged")
    paged_eng.serve(reqs()[:2], n_slots=paged_slots, cache_T=cache_T,
                    num_blocks=num_blocks, sched_cfg=sched)   # warmup
    paged = paged_eng.serve(reqs(), n_slots=paged_slots, cache_T=cache_T,
                            num_blocks=num_blocks, sched_cfg=sched)

    mismatches = 0
    for a, b in zip(sorted(slab.results, key=lambda r: r.request_id),
                    sorted(paged.results, key=lambda r: r.request_id)):
        if (len(a.tokens) != len(b.tokens)
                or (np.asarray(a.tokens) != np.asarray(b.tokens)).any()):
            mismatches += 1

    slab_ttft = [r.ttft_steps for r in slab.results
                 if r.ttft_steps is not None]
    paged_ttft = [r.ttft_steps for r in paged.results
                  if r.ttft_steps is not None]
    gain = paged.peak_active_slots / max(slab.peak_active_slots, 1)
    return {
        "n_requests": n_requests,
        "shared_prefix_len": int(sys_len),
        "unique_suffix_len": int(uniq_len),
        "arrival_rate_per_step": rate,
        "block_size": block_size,
        "hbm_budget_tokens": int(budget_tokens),
        "slab_slots": int(budget_slots),
        "paged_num_blocks": int(num_blocks),
        "slab_admitted_concurrency": int(slab.peak_active_slots),
        "paged_admitted_concurrency": int(paged.peak_active_slots),
        "admitted_concurrency_gain": float(gain),
        "slab_decode_steps": int(slab.steps),
        "paged_decode_steps": int(paged.steps),
        "step_speedup": float(slab.steps / max(paged.steps, 1)),
        "slab_per_step_ms": float(1e3 * slab.decode_s / max(slab.steps, 1)),
        "paged_per_step_ms": float(1e3 * paged.decode_s
                                   / max(paged.steps, 1)),
        "slab_tokens_per_s": float(slab.decode_tokens_per_s),
        "paged_tokens_per_s": float(paged.decode_tokens_per_s),
        "paged_prefix_hit_blocks": int(paged.prefix_hit_blocks),
        "paged_cow_blocks": int(paged.cow_blocks),
        "paged_preemptions": int(paged.n_preemptions),
        "paged_peak_blocks_in_use": int(paged.peak_blocks_in_use),
        "mean_ttft_slab": float(np.mean(slab_ttft)) if slab_ttft else None,
        "mean_ttft_paged": float(np.mean(paged_ttft)) if paged_ttft else None,
        "ttft_steps_pcts_slab": percentiles(slab_ttft),
        "ttft_steps_pcts_paged": percentiles(paged_ttft),
        "token_mismatches": mismatches,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size (seconds, not minutes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--budget-slots", type=int, default=None,
                    help="HBM budget expressed in slab slots")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="Poisson arrivals per decode step")
    ap.add_argument("--block-size", type=int, default=4)
    args = ap.parse_args(argv)

    r = run(tiny=args.tiny, seed=args.seed, budget_slots=args.budget_slots,
            n_requests=args.requests, rate=args.rate,
            block_size=args.block_size)

    from benchmarks.common import save_artifact
    path = save_artifact("BENCH_paged", r)

    print(f"requests={r['n_requests']} shared_prefix={r['shared_prefix_len']} "
          f"budget={r['hbm_budget_tokens']} KV tokens "
          f"(block_size={r['block_size']})")
    print(f"slab:   {r['slab_admitted_concurrency']} concurrent "
          f"({r['slab_slots']} worst-case slots), "
          f"{r['slab_decode_steps']} steps, "
          f"{r['slab_tokens_per_s']:8.1f} tok/s, "
          f"ttft {r['mean_ttft_slab']:.1f}")
    print(f"paged:  {r['paged_admitted_concurrency']} concurrent "
          f"({r['paged_num_blocks']} blocks), "
          f"{r['paged_decode_steps']} steps, "
          f"{r['paged_tokens_per_s']:8.1f} tok/s, "
          f"ttft {r['mean_ttft_paged']:.1f}")
    print(f"gain:   {r['admitted_concurrency_gain']:.2f}x admitted "
          f"concurrency, {r['step_speedup']:.2f}x fewer decode steps   "
          f"prefix hits={r['paged_prefix_hit_blocks']} "
          f"cow={r['paged_cow_blocks']} "
          f"preemptions={r['paged_preemptions']}   "
          f"token mismatches: {r['token_mismatches']}")
    print(f"artifact: {path}")
    if r["token_mismatches"]:
        print("ERROR: paged outputs diverged from slab", file=sys.stderr)
        return 1
    if r["admitted_concurrency_gain"] < 2.0:
        print("ERROR: < 2x admitted concurrency at fixed HBM budget",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
