"""Static vs continuous batching throughput (the serving-level Fig. 8/9).

Poisson request arrivals with heterogeneous output lengths against a reduced
qwen2-family model.  The static baseline batches requests in arrival waves of
``n_slots`` and decodes each wave in lock-step for max(max_new) steps — the
request-level analogue of a strict-sync (E0Q0) MAC array.  The continuous
engine evicts finished slots and admits waiting requests under a bounded lead
window E.  The same ``run()`` also simulates the paper's array at E0Q0 vs
E3Q2 so the utilization gains can be compared side by side.

    PYTHONPATH=src python benchmarks/serving_throughput.py [--tiny]
    PYTHONPATH=src python benchmarks/serving_throughput.py --lead-window 8
    PYTHONPATH=src python benchmarks/serving_throughput.py --telemetry DIR

``--telemetry DIR`` runs one extra (untimed) instrumented serve and writes
``DIR/serving_metrics.jsonl`` + ``DIR/serving_trace.json`` — the artifacts
CI uploads so a regressing run can be inspected in perfetto.
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp

if __package__ in (None, ""):  # ran as a script: make `benchmarks.` importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def _poisson_arrivals(rng, n: int, rate: float) -> np.ndarray:
    """Arrival times (decode-step clock) of a Poisson process with ``rate``
    requests per decode step."""
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.cumsum(gaps)


def _static_baseline(engine, prompts, max_news, n_slots, cache_T):
    """Arrival-ordered waves of ``n_slots``; each wave decodes until its
    slowest request finishes (lock-step), then fully drains before the next
    wave is admitted.  ``cache_T`` is pinned so every wave reuses one
    compiled prefill/decode shape (same as the continuous engine)."""
    tokens_by_req = {}
    useful = 0
    decode_s = 0.0
    steps = 0
    for lo in range(0, len(prompts), n_slots):
        hi = min(lo + n_slots, len(prompts))
        wave_max = int(max(max_news[lo:hi]))
        res = engine.generate({"tokens": jnp.asarray(prompts[lo:hi])},
                              max_new_tokens=wave_max, cache_T=cache_T)
        decode_s += res.decode_s
        steps += res.steps
        for j, i in enumerate(range(lo, hi)):
            out = np.asarray(res.tokens[j][:max_news[i]])
            tokens_by_req[i] = out
            useful += len(out)
    return {"tokens_by_req": tokens_by_req, "useful_tokens": useful,
            "decode_s": decode_s, "steps": steps,
            "tokens_per_s": useful / max(decode_s, 1e-9)}


def run(tiny: bool = False, seed: int = 0, lead_window: int = 4,
        n_slots: int = None, n_requests: int = None, rate: float = 0.5,
        telemetry_dir: str = None):
    import dataclasses

    from repro.configs.base import get_arch
    from repro.core.array_sim import ArrayConfig, run_experiment
    from repro.models import api
    from repro.serving import (Request, SchedulerConfig, ServeConfig,
                               ServingEngine, Telemetry, percentiles)

    if n_slots is None:
        n_slots = 2 if tiny else 4
    if n_requests is None:
        n_requests = 4 if tiny else 24
    prompt_len = 8 if tiny else 16
    max_new_hi = 6 if tiny else 32

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2 if tiny else 4, d_model=64 if tiny else 128,
        d_ff=128 if tiny else 256, vocab_size=256, head_dim=16)
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_new_tokens=max_new_hi,
                                       temperature=0.0))

    rng = np.random.default_rng(seed)
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1),
                           (n_requests, prompt_len), 2, cfg.vocab_size),
        np.int32)
    # heterogeneous output lengths: uniform in [1, max_new_hi]
    max_news = rng.integers(1, max_new_hi + 1, size=n_requests).tolist()
    arrivals = _poisson_arrivals(rng, n_requests, rate)

    cache_T = prompt_len + max_new_hi + engine.serve_cfg.cache_margin

    # warmup both compiled paths (prefill at wave + singleton batch, decode
    # at scalar + vector cache_len) so timing measures steady state
    engine.serve([Request(prompt=prompts[i], max_new_tokens=2,
                          arrival_time=0.0) for i in range(min(n_slots, 2))],
                 n_slots=n_slots, cache_T=cache_T)
    _static_baseline(engine, prompts[:n_slots], [2] * n_slots, n_slots,
                     cache_T)

    # best-of-N wall-clock for both paths: decode work is identical across
    # repeats (deterministic greedy), so min time is the noise-free estimate
    repeats = 2
    static = min((_static_baseline(engine, prompts, max_news, n_slots,
                                   cache_T) for _ in range(repeats)),
                 key=lambda s: s["decode_s"])

    def _serve_once():
        reqs = [Request(prompt=prompts[i], max_new_tokens=int(max_news[i]),
                        arrival_time=float(arrivals[i]))
                for i in range(n_requests)]
        return engine.serve(reqs, n_slots=n_slots, cache_T=cache_T,
                            sched_cfg=SchedulerConfig(lead_window=lead_window))

    report = min((_serve_once() for _ in range(repeats)),
                 key=lambda r: r.decode_s)

    # greedy outputs must be token-identical to the static engine
    id_by_rank = {r.request_id: i for i, r in enumerate(
        sorted(report.results, key=lambda r: r.request_id))}
    mismatches = 0
    for r in report.results:
        want = static["tokens_by_req"][id_by_rank[r.request_id]]
        if len(r.tokens) != len(want) or (r.tokens != want).any():
            mismatches += 1

    speedup = report.decode_tokens_per_s / static["tokens_per_s"]
    # deterministic scheduling-only gain: useful tokens per decode step
    # (immune to wall-clock noise; both paths run the same decode kernel)
    step_speedup = ((report.total_new_tokens / max(report.steps, 1))
                    / (static["useful_tokens"] / max(static["steps"], 1)))

    # the array-level analogue: strict sync (E0Q0) vs the paper's E3Q2
    acfg = dict(rows=4, cols=8) if tiny else {}
    sim_sync = run_experiment(seed, ArrayConfig(E=0, Q=0, **acfg),
                              64 if tiny else 256, 0.6)
    sim_elastic = run_experiment(seed, ArrayConfig(E=3, Q=2, **acfg),
                                 64 if tiny else 256, 0.6)

    ttfts = [r.ttft_steps for r in report.results
             if r.ttft_steps is not None]
    ttft_pcts = percentiles(ttfts)      # shared repo-wide percentile rule
    result = {
        "n_requests": n_requests,
        "n_slots": n_slots,
        "lead_window": lead_window,
        "arrival_rate_per_step": rate,
        "static_tokens_per_s": static["tokens_per_s"],
        "static_decode_steps": static["steps"],
        "static_per_step_ms": 1e3 * static["decode_s"]
                              / max(static["steps"], 1),
        "continuous_tokens_per_s": report.decode_tokens_per_s,
        "continuous_decode_steps": report.steps,
        "continuous_per_step_ms": 1e3 * report.decode_s
                                  / max(report.steps, 1),
        "continuous_slot_utilization": report.slot_utilization,
        "continuous_n_syncs": report.n_syncs,
        "continuous_max_divergence": report.max_divergence,
        "speedup": speedup,
        "step_speedup": step_speedup,
        "token_mismatches": mismatches,
        "mean_ttft_steps": float(np.mean(ttfts)) if ttfts else None,
        "ttft_steps_pcts": ttft_pcts,
        "ttft_wall_ms_pcts": (
            {k: v * 1e3 for k, v in report.ttft_wall.items()}
            if report.ttft_wall else None),
        "array_sim_util_E0Q0": sim_sync.pe_utilization,
        "array_sim_util_E3Q2": sim_elastic.pe_utilization,
        "array_sim_util_gain": (sim_elastic.pe_utilization
                                / max(sim_sync.pe_utilization, 1e-9)),
    }

    if telemetry_dir:
        # one extra UNTIMED serve with the sinks attached: the timed repeats
        # above stay sink-free, and CI gets a fresh single-run JSONL + trace
        metrics_path = os.path.join(telemetry_dir, "serving_metrics.jsonl")
        trace_path = os.path.join(telemetry_dir, "serving_trace.json")
        tel = Telemetry(metrics_path=metrics_path, trace_path=trace_path)
        saved_cfg = engine.serve_cfg
        engine.serve_cfg = dataclasses.replace(saved_cfg, telemetry=tel)
        try:
            _serve_once()
        finally:
            engine.serve_cfg = saved_cfg
            tel.close()
        result["telemetry_metrics"] = metrics_path
        result["telemetry_trace"] = trace_path
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size (seconds, not minutes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lead-window", type=int, default=4)
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=0.5,
                    help="Poisson arrivals per decode step")
    ap.add_argument("--telemetry", metavar="DIR", default=None,
                    help="also run one instrumented serve and write "
                         "DIR/serving_metrics.jsonl + DIR/serving_trace.json")
    args = ap.parse_args(argv)

    r = run(tiny=args.tiny, seed=args.seed, lead_window=args.lead_window,
            n_slots=args.slots, n_requests=args.requests, rate=args.rate,
            telemetry_dir=args.telemetry)

    from benchmarks.common import save_artifact
    path = save_artifact("BENCH_serving", r)

    print(f"requests={r['n_requests']} slots={r['n_slots']} "
          f"E={r['lead_window']} rate={r['arrival_rate_per_step']}/step")
    print(f"static:      {r['static_tokens_per_s']:8.1f} tok/s "
          f"({r['static_decode_steps']} lock-step decode steps)")
    print(f"continuous:  {r['continuous_tokens_per_s']:8.1f} tok/s "
          f"({r['continuous_decode_steps']} steps, "
          f"{r['continuous_per_step_ms']:.2f} ms/step, "
          f"{r['continuous_slot_utilization']*100:.0f}% slot util, "
          f"{r['continuous_n_syncs']} admission syncs)")
    if r.get("telemetry_metrics"):
        print(f"telemetry: {r['telemetry_metrics']} + {r['telemetry_trace']}")
    print(f"speedup:     {r['speedup']:.2f}x wall-clock, "
          f"{r['step_speedup']:.2f}x per-decode-step (deterministic)   "
          f"token mismatches vs static: {r['token_mismatches']}")
    print(f"array analogue: PE util E0Q0={r['array_sim_util_E0Q0']:.3f} "
          f"-> E3Q2={r['array_sim_util_E3Q2']:.3f} "
          f"({r['array_sim_util_gain']:.2f}x) — same elasticity lever, "
          f"one level down")
    print(f"artifact: {path}")
    if r["token_mismatches"]:
        print("ERROR: continuous batching diverged from static outputs",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
