"""CI regression gate: diff current BENCH_*.json against a previous run.

Usage::

    python benchmarks/compare.py PREVIOUS CURRENT [--threshold 0.15]

``PREVIOUS``/``CURRENT`` are either two BENCH_*.json files or two
directories of them (matched by filename).  Every numeric value whose
full dotted key ends in a registered metric suffix — ``per_step_ms``,
``per_step_ms.p50/p90/p99`` (lower is better) or ``tokens_per_s`` (higher
is better) — at any nesting depth — is compared; a relative change past
the threshold in the bad direction fails the gate (exit 1).

Provenance rules (the ``_meta`` block stamped by ``benchmarks/common.py``):

  * missing previous artifact  -> SKIP with a notice, exit 0 (first run on
    a fresh trajectory must not fail CI);
  * machine fingerprint differs (device kind / device count / jax version)
    -> SKIP with a notice, exit 0 — cross-hardware deltas are not
    regressions.  Hostname is provenance only, NOT part of the
    fingerprint: ephemeral CI runners get a fresh hostname per run but are
    the same machine class, and the threshold absorbs same-class noise.

Exit codes: 0 ok/skipped, 1 regression, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
from typing import Dict, List, Optional, Tuple

DEFAULT_THRESHOLD = 0.15

#: metric-key suffix -> direction ("lower" / "higher" is better).
#: Suffixes match against the FULL dotted key, so multi-segment suffixes
#: like ``per_step_ms.p99`` gate nested percentile blocks while bare
#: ``per_step_ms`` still gates scalar step times (a percentile leaf like
#: ``...per_step_ms.p99`` does NOT end in ``per_step_ms``, so the two
#: entries never double-count one value).
METRIC_SUFFIXES = {
    "per_step_ms": "lower",
    "per_step_ms.p50": "lower",
    "per_step_ms.p90": "lower",
    "per_step_ms.p99": "lower",
    "tokens_per_s": "higher",
}


def metric_direction(key: str) -> Optional[str]:
    """Direction for a flattened metric key, or None if not gated."""
    for suffix, direction in METRIC_SUFFIXES.items():
        if key.endswith(suffix):
            return direction
    return None

#: _meta fields that must match for a comparison to be meaningful
#: (hostname stays out: ephemeral CI runners rename per run)
FINGERPRINT_KEYS = ("device_kind", "device_count", "jax_version")


def collect_metrics(node, prefix: str = "") -> Dict[str, float]:
    """Flatten every gated metric in a JSON tree to ``path -> value``."""
    out: Dict[str, float] = {}
    if isinstance(node, dict):
        for k, v in node.items():
            if k == "_meta":
                continue
            out.update(collect_metrics(v, f"{prefix}{k}."))
    elif isinstance(node, list):
        for i, v in enumerate(node):
            out.update(collect_metrics(v, f"{prefix}{i}."))
    elif isinstance(node, (int, float)) and not isinstance(node, bool):
        key = prefix[:-1]
        if metric_direction(key) is not None:
            out[key] = float(node)
    return out


def fingerprint(payload: dict) -> Optional[Tuple]:
    meta = payload.get("_meta")
    if not isinstance(meta, dict):
        return None
    return tuple(meta.get(k) for k in FINGERPRINT_KEYS)


def compare_payloads(prev: dict, cur: dict, threshold: float,
                     name: str = "") -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes) for one artifact pair."""
    regressions: List[str] = []
    notes: List[str] = []
    fp_prev, fp_cur = fingerprint(prev), fingerprint(cur)
    if fp_prev is None or fp_cur is None:
        notes.append(f"{name}: SKIP (missing _meta provenance block)")
        return regressions, notes
    if fp_prev != fp_cur:
        notes.append(
            f"{name}: SKIP (machine fingerprint changed "
            f"{dict(zip(FINGERPRINT_KEYS, fp_prev))} -> "
            f"{dict(zip(FINGERPRINT_KEYS, fp_cur))}; cross-machine deltas "
            f"are not regressions)")
        return regressions, notes
    prev_m, cur_m = collect_metrics(prev), collect_metrics(cur)
    shared = sorted(set(prev_m) & set(cur_m))
    if not shared:
        notes.append(f"{name}: no shared gated metrics")
        return regressions, notes
    for key in shared:
        p, c = prev_m[key], cur_m[key]
        direction = metric_direction(key)
        if not math.isfinite(c):
            # NaN compares False against every threshold — without this
            # guard a NaN'd current metric would sail through as "ok"
            regressions.append(
                f"REGRESSION {name}:{key}: current value {c!r} is not "
                f"finite")
            continue
        if not math.isfinite(p) or p <= 0:
            # a zero/NaN baseline makes the relative delta meaningless
            # (division by zero / NaN); say so instead of silently
            # dropping the metric from the gate
            notes.append(
                f"{name}:{key}: SKIP (baseline {p!r} is not a positive "
                f"finite number; relative delta undefined)")
            continue
        rel = (c - p) / p
        bad = rel > threshold if direction == "lower" else rel < -threshold
        line = (f"{name}:{key}: {p:.6g} -> {c:.6g} "
                f"({rel * 100:+.1f}%, {direction} is better)")
        if bad:
            regressions.append("REGRESSION " + line)
        else:
            notes.append("ok " + line)
    return regressions, notes


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _pairs(prev: str, cur: str) -> List[Tuple[str, Optional[str], str]]:
    """(name, prev_path_or_None, cur_path) pairs for file or dir mode."""
    if os.path.isdir(cur):
        out = []
        for fn in sorted(os.listdir(cur)):
            if not (fn.startswith("BENCH_") and fn.endswith(".json")):
                continue
            pp = os.path.join(prev, fn) if os.path.isdir(prev) else None
            out.append((fn, pp if pp and os.path.exists(pp) else None,
                        os.path.join(cur, fn)))
        return out
    return [(os.path.basename(cur),
             prev if os.path.exists(prev) else None, cur)]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("previous", help="previous BENCH_*.json file or dir")
    ap.add_argument("current", help="current BENCH_*.json file or dir")
    ap.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD,
                    help="max tolerated relative regression (default 0.15)")
    args = ap.parse_args(argv)

    if not os.path.exists(args.current):
        print(f"compare: current artifact {args.current!r} not found",
              file=sys.stderr)
        return 2
    if not os.path.exists(args.previous):
        print(f"compare: SKIP — no previous artifact at {args.previous!r} "
              f"(first run of the trajectory)")
        return 0

    pairs = _pairs(args.previous, args.current)
    if not pairs:
        print("compare: no BENCH_*.json artifacts in current dir",
              file=sys.stderr)
        return 2
    all_regressions: List[str] = []
    for name, prev_path, cur_path in pairs:
        if prev_path is None:
            print(f"{name}: SKIP (no previous artifact)")
            continue
        regs, notes = compare_payloads(_load(prev_path), _load(cur_path),
                                       args.threshold, name=name)
        for line in notes:
            print(line)
        for line in regs:
            print(line)
        all_regressions.extend(regs)
    if all_regressions:
        print(f"\ncompare: FAILED — {len(all_regressions)} metric(s) "
              f"regressed past {args.threshold * 100:.0f}%")
        return 1
    print("\ncompare: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
