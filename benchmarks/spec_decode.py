"""Speculative decoding: decode-step reduction at verified token identity.

The serving-level Fig. 8/9: per-slot work per step becomes VARIABLE (1..K+1
committed tokens, like bit-sparsity-dependent MAC cycles) and the
quasi-sync machinery absorbs it.  One request stream runs through four
engines against a non-speculative greedy baseline:

  * drafter x backend grid — ``prompt_lookup`` (weight-free n-gram) and
    ``model`` x ``slab`` / ``paged``;
  * the model drafter here is SELF-speculation (draft = target weights):
    deterministic ~100% acceptance, so the step reduction approaches the
    (K+1)x bound and the harness pins ``spec steps < baseline steps`` as an
    acceptance bar (a real small drafter trades acceptance for draft cost —
    docs/performance.md);
  * prompts carry a repeated phrase so the n-gram drafter has something to
    look up (its acceptance on a randomly-initialized model stays modest —
    reported, not gated).

Every cell is verified TOKEN-IDENTICAL to the baseline (mismatches == 0 is
an error).  Writes experiments/bench/BENCH_spec.json.

    PYTHONPATH=src python benchmarks/spec_decode.py [--tiny]
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np
import jax

if __package__ in (None, ""):  # ran as a script: make `benchmarks.` importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def run(tiny: bool = False, seed: int = 0, n_requests: int = None,
        num_draft_tokens: int = 3, block_size: int = 4, rate: float = 0.7):
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import Request, SchedulerConfig, ServeConfig, \
        ServingEngine

    if n_requests is None:
        n_requests = 6 if tiny else 16
    max_new = 8 if tiny else 16
    phrase_len = 6
    margin = 4

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2 if tiny else 4, d_model=64 if tiny else 128,
        d_ff=128 if tiny else 256, vocab_size=256, head_dim=16)
    params = api.init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(seed)
    phrase = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (phrase_len,), 2,
                           cfg.vocab_size), np.int32)
    # repeated-phrase prompts: the n-gram drafter can actually look
    # something up, and the repeats stress prefix-block sharing too
    prompts = []
    for i in range(n_requests):
        uniq = np.asarray(
            jax.random.randint(jax.random.PRNGKey(2 + i), (4,), 2,
                               cfg.vocab_size), np.int32)
        prompts.append(np.concatenate([phrase, phrase, uniq, phrase]))
    max_news = rng.integers(max_new // 2, max_new + 1,
                            size=n_requests).tolist()
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n_requests))
    prompt_len = len(prompts[0])
    cache_T = prompt_len + max_new + margin

    def reqs():
        return [Request(prompt=prompts[i], max_new_tokens=int(max_news[i]),
                        arrival_time=float(arrivals[i]))
                for i in range(n_requests)]

    sched = SchedulerConfig(lead_window=2)

    def engine(backend, draft):
        serve_cfg = ServeConfig(max_new_tokens=max_new, temperature=0.0,
                                cache_backend=backend, block_size=block_size,
                                draft=draft,
                                num_draft_tokens=num_draft_tokens)
        kw = {}
        if draft == "model":
            kw = dict(draft_cfg=cfg, draft_params=params)  # self-speculation
        return ServingEngine(cfg, params, serve_cfg, **kw)

    def serve(eng, **kw):
        eng.serve(reqs()[:2], n_slots=4, cache_T=cache_T,
                  sched_cfg=sched, **kw)                   # warmup compile
        return eng.serve(reqs(), n_slots=4, cache_T=cache_T,
                         sched_cfg=sched, **kw)

    base = serve(engine("slab", "none"))
    base_order = [r.tokens for r in sorted(base.results,
                                           key=lambda r: r.request_id)]

    cells = {}
    total_mismatches = 0
    for backend in ("slab", "paged"):
        for draft in ("prompt_lookup", "model"):
            rep = serve(engine(backend, draft))
            toks = [r.tokens for r in sorted(rep.results,
                                             key=lambda r: r.request_id)]
            mism = sum(
                1 for a, b in zip(base_order, toks)
                if len(a) != len(b) or (np.asarray(a) != np.asarray(b)).any())
            total_mismatches += mism
            cells[f"{draft}_{backend}"] = {
                "decode_steps": int(rep.steps),
                "per_step_ms": float(1e3 * rep.decode_s
                                     / max(rep.steps, 1)),
                "step_reduction": float(base.steps / max(rep.steps, 1)),
                "drafted_tokens": int(rep.drafted_tokens),
                "accepted_tokens": int(rep.accepted_tokens),
                "acceptance_rate": float(rep.acceptance_rate),
                "committed_tokens_per_step": float(
                    rep.committed_tokens_per_step),
                "tokens_per_s": float(rep.decode_tokens_per_s),
                "ttft_wall_p50_ms": (rep.ttft_wall["p50"] * 1e3
                                     if rep.ttft_wall else None),
                "itl_wall_p50_ms": (rep.itl_wall["p50"] * 1e3
                                    if rep.itl_wall else None),
                "token_mismatches": int(mism),
            }

    model_cells = [cells["model_slab"], cells["model_paged"]]
    return {
        "n_requests": n_requests,
        "num_draft_tokens": num_draft_tokens,
        "block_size": block_size,
        "baseline_decode_steps": int(base.steps),
        "baseline_per_step_ms": float(1e3 * base.decode_s
                                      / max(base.steps, 1)),
        "baseline_tokens_per_s": float(base.decode_tokens_per_s),
        "cells": cells,
        # headline: deterministic self-speculation step reduction
        "model_step_reduction": float(min(c["step_reduction"]
                                          for c in model_cells)),
        "model_acceptance_rate": float(min(c["acceptance_rate"]
                                           for c in model_cells)),
        "prompt_lookup_acceptance_rate": float(
            cells["prompt_lookup_slab"]["acceptance_rate"]),
        "token_mismatches": int(total_mismatches),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size (seconds, not minutes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--num-draft-tokens", type=int, default=3)
    ap.add_argument("--block-size", type=int, default=4)
    args = ap.parse_args(argv)

    r = run(tiny=args.tiny, seed=args.seed, n_requests=args.requests,
            num_draft_tokens=args.num_draft_tokens,
            block_size=args.block_size)

    from benchmarks.common import save_artifact
    path = save_artifact("BENCH_spec", r)

    print(f"requests={r['n_requests']} K={r['num_draft_tokens']} "
          f"baseline={r['baseline_decode_steps']} decode steps")
    for name, c in r["cells"].items():
        print(f"{name:22s} steps={c['decode_steps']:4d} "
              f"({c['step_reduction']:.2f}x)  "
              f"accept={c['acceptance_rate']:.2f}  "
              f"commit/step={c['committed_tokens_per_step']:.2f}  "
              f"mismatches={c['token_mismatches']}")
    print(f"artifact: {path}")
    if r["token_mismatches"]:
        print("ERROR: speculative outputs diverged from greedy baseline",
              file=sys.stderr)
        return 1
    for name, c in r["cells"].items():
        if name.startswith("model") and \
                c["decode_steps"] >= r["baseline_decode_steps"]:
            print(f"ERROR: {name} did not reduce decode steps",
                  file=sys.stderr)
            return 1
    if r["model_acceptance_rate"] <= 0.0:
        print("ERROR: zero acceptance under self-speculation",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
