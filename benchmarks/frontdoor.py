"""Front-door serving benchmark: the full async stack under a Poisson
multi-tenant workload, driven over REAL HTTP against live replicas.

Legs:

  * **direct** — the same workload through plain ``engine.serve()``
    (slab, one-shot prefill): the token-identity reference and the
    baseline wall time.
  * **frontdoor_1r** — one paged replica with chunked prefill behind the
    HTTP server; per-step wall percentiles (gated), wall TTFT
    percentiles (gated via the ``*_per_step_ms`` suffix so
    ``benchmarks/compare.py`` picks them up), and queue-wait numbers
    from the replica's ``ServeReport``.
  * **frontdoor_2r** — the identical workload over two replicas, routed
    with prefix affinity vs seeded random: the affinity leg must land
    tenants on their home replica's prefix trie, so its pooled
    ``prefix_hit_blocks`` exceeds random routing's on the same trace.
  * **slo** — FIFO vs SLO-priority scheduling on one deterministic
    trace (direct serve, step clock): the high-priority class's p90
    TTFT must improve, with token identity across policies.

Greedy token identity is enforced across every leg (chunked prefill on
and off, slab and paged, through the server and direct) — a mismatch
exits non-zero.

    PYTHONPATH=src python benchmarks/frontdoor.py [--tiny]
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np

if __package__ in (None, ""):  # ran as a script: make `benchmarks.` importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import save_artifact


def _poisson_gaps(rng, n: int, rate: float) -> np.ndarray:
    """Inter-arrival gaps (seconds) of a Poisson process, ``rate`` req/s."""
    return rng.exponential(1.0 / rate, size=n)


def _build_workload(rng, cfg, *, n_requests, n_tenants, sys_len, tiers,
                    max_new_hi):
    import jax
    sys_prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (n_tenants, sys_len), 2,
                           cfg.vocab_size), np.int32)
    tenants = rng.integers(0, n_tenants, size=n_requests)
    suffix_lens = rng.choice(tiers, size=n_requests)
    prompts = []
    for i in range(n_requests):
        uniq = rng.integers(2, cfg.vocab_size, size=int(suffix_lens[i]))
        prompts.append(np.concatenate(
            [sys_prompts[int(tenants[i])],
             uniq.astype(np.int32)]).astype(np.int32))
    max_news = rng.integers(2, max_new_hi + 1, size=n_requests).tolist()
    slo = ["interactive" if rng.random() < 0.3 else "batch"
           for _ in range(n_requests)]
    return prompts, max_news, slo


def _drive_door(door_port, prompts, max_news, slo_classes, gaps,
                timeout_s=120.0):
    """Fire the workload at a live front door (one thread per in-flight
    request, Poisson-paced submission) and collect the responses in
    submission order."""
    import threading

    from repro.serving.frontdoor import FrontDoorClient
    client = FrontDoorClient("127.0.0.1", door_port, timeout_s=timeout_s)
    out = [None] * len(prompts)
    threads = []
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        def one(i=i, p=p):
            out[i] = client.generate(p, max_new_tokens=int(max_news[i]),
                                     slo_class=slo_classes[i])
        time.sleep(float(gaps[i]))
        th = threading.Thread(target=one, daemon=True)
        th.start()
        threads.append(th)
    for th in threads:
        th.join(timeout=timeout_s)
    wall_s = time.perf_counter() - t0
    if any(o is None for o in out):
        raise RuntimeError("front door dropped a request")
    return out, wall_s


def _step_ms(loop_stream):
    return [1e3 * r["wall_s"] for r in loop_stream
            if r.get("kind") in ("decode", "verify")]


def _warmup(fd, vocab, chunk, max_new=3):
    """One request per replica (router bypassed) so every engine compiles
    its prefill/decode/verify shapes BEFORE the timed window.  Returns the
    warmup request ids + per-replica stream marks so gated metrics can
    exclude the compile steps."""
    import threading

    from repro.serving import Request
    done, ids = [], set()
    for i, rep in enumerate(fd.replicas):
        evt = threading.Event()
        n = (chunk or 4) + 6          # long enough to exercise chunking
        p = (np.arange(n, dtype=np.int32) * (i + 3)) % (vocab - 2) + 2
        req = Request(prompt=p, max_new_tokens=max_new)
        ids.add(rep.submit(req, on_finish=lambda _r, e=evt: e.set()))
        done.append(evt)
    for evt in done:
        if not evt.wait(timeout=300):
            raise RuntimeError("warmup request did not finish")
    marks = {rep.name: len(rep.loop.stream) for rep in fd.replicas}
    return ids, marks


def run(tiny: bool = False, seed: int = 0):
    import jax
    jax.config.update("jax_default_matmul_precision", "float32")
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import (FrontDoor, Replica, Request, SchedulerConfig,
                               ServeConfig, SLOClass, ServingEngine,
                               SparsityProbe, percentiles)

    n_requests = 12 if tiny else 32
    n_tenants = 2 if tiny else 3
    sys_len = 16 if tiny else 24
    tiers = (2, 5, 9) if tiny else (4, 12, 24)
    max_new_hi = 5 if tiny else 12
    block_size = 4
    n_slots = 2 if tiny else 4
    # chunk covers the shared system prompt: prefix sharing deduplicates
    # the FIRST chunk's pages (later chunks ride the verify step into
    # private blocks), so chunking at the tenant-prefix boundary keeps
    # the whole system prompt shareable
    chunk = sys_len
    rate = 20.0 if tiny else 30.0      # requests/s at the front door

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2 if tiny else 4, d_model=64 if tiny else 128,
        d_ff=128 if tiny else 256, vocab_size=256, head_dim=16,
        matmul_mode="bp_exact")   # int8 dual factors: what the probe taps
    params = api.init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(seed)
    prompts, max_news, slo_classes = _build_workload(
        rng, cfg, n_requests=n_requests, n_tenants=n_tenants,
        sys_len=sys_len, tiers=tiers, max_new_hi=max_new_hi)
    gaps = _poisson_gaps(rng, n_requests, rate)
    cache_T = max(len(p) for p in prompts) + max_new_hi + 8
    # generous pool: LRU reclaim of cached prefix pages would turn the
    # routing comparison into a pool-pressure benchmark (paged_memory
    # covers that)
    num_blocks = 1 + (n_slots + 6) * cache_T // block_size

    def reqs(with_slo=False):
        return [Request(prompt=prompts[i], max_new_tokens=int(max_news[i]),
                        slo_class=slo_classes[i] if with_slo else "default")
                for i in range(n_requests)]

    def engine(backend="paged", prefill_chunk=chunk, probe=False):
        return ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=max_new_hi, temperature=0.0,
            cache_backend=backend, block_size=block_size,
            prefill_chunk=prefill_chunk,
            probe=SparsityProbe(probe_every=2) if probe else None))

    def door(n_replicas, policy, backend="paged", prefill_chunk=chunk,
             router_seed=0, probe=False):
        reps = [Replica(engine(backend, prefill_chunk, probe=probe),
                        name=f"r{i}",
                        n_slots=n_slots, cache_T=cache_T,
                        num_blocks=num_blocks if backend == "paged"
                        else None)
                for i in range(n_replicas)]
        # a loose imbalance bound: this benchmark demonstrates the prefix-
        # affinity win, so transient queue skew should not spill requests
        # off their prefix home
        return FrontDoor(reps, policy=policy, affinity_blocks=2,
                         max_imbalance=4 * n_slots, seed=router_seed)

    # -- direct baseline (slab, one-shot prefill): identity reference ------
    t0 = time.perf_counter()
    base = engine(backend="slab", prefill_chunk=None).serve(
        reqs(), n_slots=n_slots, cache_T=cache_T)
    direct_wall_s = time.perf_counter() - t0
    want = [r.tokens.tolist()
            for r in sorted(base.results, key=lambda r: r.request_id)]

    mismatches = 0

    def check_identity(responses):
        nonlocal mismatches
        mismatches += sum(1 for got, ref in zip(
            (o["tokens"] for o in responses), want) if got != ref)

    # -- 1 replica, paged + chunked prefill + cost probe, over HTTP ---------
    fd = door(1, "affinity", probe=True).start()
    try:
        warm_ids, marks = _warmup(fd, cfg.vocab_size, chunk)
        out1, wall_1r = _drive_door(fd.port, prompts, max_news, slo_classes,
                                    gaps)
    finally:
        reports = fd.stop()
    check_identity(out1)
    rep_1r = reports["r0"]
    stream_1r = list(fd.replicas[0].loop.stream)[marks["r0"]:]
    cost_hint_1r = float(fd.replicas[0].loop.cost_hint_cycles_per_token)
    ttfts_ms = [1e3 * r.ttft_wall_s for r in rep_1r.results
                if r.ttft_wall_s is not None
                and r.request_id not in warm_ids]

    # -- 1 replica, slab + one-shot prefill (identity through the door
    #    with chunking OFF rides the same check) ---------------------------
    fd = door(1, "affinity", backend="slab", prefill_chunk=None).start()
    try:
        _warmup(fd, cfg.vocab_size, None)
        out1s, _ = _drive_door(fd.port, prompts, max_news, slo_classes,
                               gaps)
    finally:
        fd.stop()
    check_identity(out1s)

    # -- 2 replicas: prefix affinity vs seeded random routing --------------
    routing = {}
    for policy in ("affinity", "random"):
        fd = door(2, policy).start()
        try:
            warm2, _ = _warmup(fd, cfg.vocab_size, chunk)
            out2, wall_2r = _drive_door(fd.port, prompts, max_news,
                                        slo_classes, gaps)
        finally:
            reports2 = fd.stop()
        check_identity(out2)
        routing[policy] = {
            "prefix_hit_blocks": sum(int(r.prefix_hit_blocks)
                                     for r in reports2.values()),
            "wall_s": wall_2r,
            "per_replica_requests": [
                sum(1 for q in r.results if q.request_id not in warm2)
                for r in reports2.values()],
        }
    affinity_gain = (routing["affinity"]["prefix_hit_blocks"]
                     - routing["random"]["prefix_hit_blocks"])
    if affinity_gain <= 0:
        raise RuntimeError(
            f"prefix-affinity routing must beat random on prefix hits: "
            f"affinity={routing['affinity']['prefix_hit_blocks']} "
            f"random={routing['random']['prefix_hit_blocks']}")

    # -- SLO policy vs FIFO on one deterministic trace (step clock) --------
    slo_cfg = SchedulerConfig(policy="slo", slo_classes={
        "interactive": SLOClass(name="interactive", priority=10),
        "batch": SLOClass(name="batch", priority=0)})
    slo_leg = {}
    toks = {}
    for policy, sched_cfg in (("fifo", SchedulerConfig()),
                              ("slo", slo_cfg)):
        trace = reqs(with_slo=True)
        engine().serve(trace, n_slots=n_slots, cache_T=cache_T,
                       num_blocks=num_blocks, sched_cfg=sched_cfg)
        per_class = {}
        for r in trace:
            per_class.setdefault(r.slo_class, []).append(r.ttft)
        slo_leg[policy] = {c: percentiles(v)
                           for c, v in sorted(per_class.items())}
        toks[policy] = [r.tokens for r in trace]
    if toks["fifo"] != toks["slo"]:
        raise RuntimeError("scheduling policy changed tokens")
    fifo_p90 = slo_leg["fifo"]["interactive"]["p90"]
    slo_p90 = slo_leg["slo"]["interactive"]["p90"]
    if slo_p90 > fifo_p90:
        raise RuntimeError(
            f"SLO policy must not worsen high-priority TTFT: "
            f"slo p90={slo_p90} fifo p90={fifo_p90}")

    if mismatches:
        raise RuntimeError(
            f"{mismatches} token mismatches between front-door legs and "
            f"direct serve")

    return {
        "n_requests": n_requests,
        "n_tenants": n_tenants,
        "n_slots": n_slots,
        "prefill_chunk": chunk,
        "block_size": block_size,
        "arrival_rate_per_s": rate,
        "direct": {"wall_s": direct_wall_s,
                   "tokens_per_s": base.decode_tokens_per_s},
        "frontdoor_1r": {
            # gated: suffix-matched by benchmarks/compare.py
            "per_step_ms": percentiles(_step_ms(stream_1r)),
            "tokens_per_s": rep_1r.decode_tokens_per_s,
            # gated via the *_per_step_ms suffix rule: wall TTFT (ms)
            # through the live server, queue wait included
            "ttft_per_step_ms": percentiles(ttfts_ms),
            "wall_s": wall_1r,
            "chunk_tokens": int(rep_1r.chunk_tokens),
            "queue_wait_s": rep_1r.queue_wait,
            "cost_hint_cycles_per_token": cost_hint_1r,
        },
        "frontdoor_2r": {
            "wall_s": routing["affinity"]["wall_s"],
            "speedup_vs_1r": wall_1r / routing["affinity"]["wall_s"],
        },
        "routing": {**routing, "affinity_gain_blocks": int(affinity_gain)},
        "slo": {**slo_leg,
                "interactive_p90_fifo": fifo_p90,
                "interactive_p90_slo": slo_p90},
        "token_mismatches": mismatches,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="small config for CI smoke")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    result = run(tiny=args.tiny, seed=args.seed)
    save_artifact("BENCH_frontdoor", result)
    print(f"direct wall: {result['direct']['wall_s']:.2f}s  "
          f"1r wall: {result['frontdoor_1r']['wall_s']:.2f}s  "
          f"2r wall: {result['frontdoor_2r']['wall_s']:.2f}s")
    print(f"prefix hits: affinity="
          f"{result['routing']['affinity']['prefix_hit_blocks']} "
          f"random={result['routing']['random']['prefix_hit_blocks']}")
    print(f"interactive TTFT p90 (steps): "
          f"fifo={result['slo']['interactive_p90_fifo']:.1f} "
          f"slo={result['slo']['interactive_p90_slo']:.1f}")


if __name__ == "__main__":
    main()
