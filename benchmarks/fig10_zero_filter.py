"""Fig. 10: zero-value filtering vs activation value sparsity at E3Q2,
bit sparsity 0.65, weight value sparsity 0 — avg cycles/step and the derived
throughput gain; plus the paper's four model-specific sparsity profiles."""

from __future__ import annotations

from repro.configs.cnn_zoo import ACT_VALUE_SPARSITY, BIT_SPARSITY
from repro.core.array_sim import ArrayConfig, run_experiment

SPARSITIES = (0.0, 0.2, 0.4, 0.6, 0.8)
N_STEPS = 256


def run():
    rows = []
    for vs in SPARSITIES:
        off = run_experiment(1, ArrayConfig(E=3, Q=2, zero_filter=False),
                             N_STEPS, 0.65, a_value_sparsity=vs)
        on = run_experiment(1, ArrayConfig(E=3, Q=2, zero_filter=True),
                            N_STEPS, 0.65, a_value_sparsity=vs)
        rows.append({
            "act_value_sparsity": vs,
            "cycles_per_step_off": off.avg_cycles_per_step,
            "cycles_per_step_on": on.avg_cycles_per_step,
            "cycle_reduction": 1 - on.avg_cycles_per_step
            / off.avg_cycles_per_step,
            "throughput_gain": off.avg_cycles_per_step
            / on.avg_cycles_per_step - 1,
        })
    # model-profile runs (paper: ResNet18 +7.9%, MobileNetV2 +0.1%,
    # AlexNet +30.4%, VGG16 +28.8%)
    models = {}
    for net, vs in ACT_VALUE_SPARSITY.items():
        bs = BIT_SPARSITY[net]
        off = run_experiment(2, ArrayConfig(E=3, Q=2, zero_filter=False),
                             N_STEPS, bs, a_value_sparsity=vs)
        on = run_experiment(2, ArrayConfig(E=3, Q=2, zero_filter=True),
                            N_STEPS, bs, a_value_sparsity=vs)
        models[net] = off.avg_cycles_per_step / on.avg_cycles_per_step - 1
    at80 = next(r for r in rows if r["act_value_sparsity"] == 0.8)
    return {"rows": rows, "model_throughput_gains": models,
            "cycle_reduction_at_0.8": at80["cycle_reduction"],   # paper 27.4%
            "throughput_gain_at_0.8": at80["throughput_gain"]}   # paper 37.7%
