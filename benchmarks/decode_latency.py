"""Decode-path latency microbenchmark: the repo's perf trajectory artifact.

Measures prefill latency and per-step decode latency of the inference fast
path across

  * matmul modes   — bf16, bp_exact, bp_approx
  * backends       — xla vs kernel_interpret (the Pallas kernel is only
                     *compiled* on TPU; interpret mode exercises the same
                     kernel program on CPU, so its absolute numbers are a
                     correctness/coverage signal, not a speed claim)
  * decode loops   — static fused (jitted multi-token lax.scan, sampling
                     folded into the step) vs the pre-PR legacy loop (one
                     jitted decode dispatch + a separate eager sampling
                     dispatch per token) vs continuous serve()

and writes everything to ``experiments/bench/BENCH_decode.json`` so each PR
accumulates a comparable perf point.

    PYTHONPATH=src python benchmarks/decode_latency.py --smoke
    PYTHONPATH=src python benchmarks/decode_latency.py --max-new 64 --repeats 3
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

if __package__ in (None, ""):  # ran as a script: make `benchmarks.` importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

from benchmarks.common import save_artifact


def _legacy_generate(engine, batch, max_new, cache_T):
    """The pre-PR static decode loop, reconstructed for comparison: one
    jitted decode dispatch plus a separate eager argmax dispatch per token,
    full (B, V) logits leaving the jitted step each time."""
    prompt = batch["tokens"]
    _, S = prompt.shape
    t0 = time.perf_counter()
    logits, cache = engine.executor.prefill(batch, cache_T)
    logits.block_until_ready()
    t1 = time.perf_counter()
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    for i in range(max_new - 1):
        step = {"tokens": tok[:, None], "cache": cache,
                "cache_len": jnp.int32(S + i)}
        logits, cache = engine.executor.decode_step(step)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    t2 = time.perf_counter()
    return t1 - t0, t2 - t1, len(out)


def _time_static(engine, batch, max_new, cache_T, repeats, legacy=False):
    """(prefill_s, decode_s, steps) — best-of-``repeats`` after a compile
    warmup call."""
    B = batch["tokens"].shape[0]

    def once():
        if legacy:
            pf, dc, steps = _legacy_generate(engine, batch, max_new, cache_T)
            return pf, dc, steps, B * steps
        res = engine.generate(batch, max_new_tokens=max_new, cache_T=cache_T)
        return res.prefill_s, res.decode_s, res.steps, res.tokens.size
    once()                                   # compile warmup
    runs = [once() for _ in range(repeats)]
    best = min(runs, key=lambda r: r[1])
    return best


def _time_continuous(engine, prompts, max_new, repeats):
    from repro.serving import Request
    B = prompts.shape[0]
    cache_T = prompts.shape[1] + max_new + engine.serve_cfg.cache_margin

    def once():
        reqs = [Request(prompt=prompts[i], max_new_tokens=max_new)
                for i in range(B)]
        rep = engine.serve(reqs, n_slots=B, cache_T=cache_T)
        # total_new_tokens, not B*steps: the first token of every request
        # comes from prefill, not a decode step
        return rep.prefill_s, rep.decode_s, max(rep.steps, 1), \
            rep.total_new_tokens
    once()                                   # compile warmup
    runs = [once() for _ in range(repeats)]
    return min(runs, key=lambda r: r[1])


def run(smoke: bool = False, max_new: int = None, repeats: int = None,
        with_interpret: bool = True, decode_chunk: int = 8, seed: int = 0):
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import ServeConfig, ServingEngine

    if max_new is None:
        # k*decode_chunk + 1: the fused path runs whole scan chunks (the
        # first token comes from prefill), measuring steady-state decode
        max_new = decode_chunk + 1 if smoke else 4 * decode_chunk + 1
    if repeats is None:
        repeats = 2 if smoke else 4
    B = 2 if smoke else 4
    prompt_len = 8 if smoke else 16

    cfg0 = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2 if smoke else 4, d_model=64 if smoke else 128,
        d_ff=128 if smoke else 256, vocab_size=256, head_dim=16)
    params = api.init(jax.random.PRNGKey(seed), cfg0)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed + 1), (B, prompt_len), 2, cfg0.vocab_size),
        np.int32)
    batch = {"tokens": jnp.asarray(prompts)}
    cache_T = prompt_len + max_new + 8

    cells = []
    backends_of = {
        "bf16": ["xla"],                     # no quantized contraction to fuse
        "bp_exact": ["xla"] + (["kernel_interpret"] if with_interpret else []),
        "bp_approx": ["xla"] + (["kernel_interpret"] if with_interpret else []),
    }
    for mode, backends in backends_of.items():
        for backend in backends:
            cfg = cfg0.replace(matmul_mode=mode, matmul_backend=backend)
            engine = ServingEngine(
                cfg, params, ServeConfig(max_new_tokens=max_new,
                                         decode_chunk=decode_chunk))
            for path, timing in (
                ("static_fused",
                 _time_static(engine, batch, max_new, cache_T, repeats)),
                ("static_legacy",
                 _time_static(engine, batch, max_new, cache_T, repeats,
                              legacy=True)),
                ("continuous",
                 _time_continuous(engine, prompts, max_new, repeats)),
            ):
                prefill_s, decode_s, steps, n_tokens = timing
                cells.append({
                    "mode": mode, "backend": backend, "path": path,
                    "prefill_s": prefill_s, "decode_s": decode_s,
                    "steps": steps, "tokens": n_tokens,
                    "per_step_ms": 1e3 * decode_s / max(steps, 1),
                    "decode_tokens_per_s": n_tokens / max(decode_s, 1e-9),
                })
                c = cells[-1]
                print(f"{mode:>9} {backend:>17} {path:>13}  "
                      f"prefill {1e3 * prefill_s:7.1f} ms  "
                      f"per-step {c['per_step_ms']:7.2f} ms  "
                      f"{c['decode_tokens_per_s']:8.0f} tok/s")

    # headline: fused-scan decode overhead vs the pre-PR per-token loop
    speedups = {}
    by = {(c["mode"], c["backend"], c["path"]): c for c in cells}
    for (mode, backend, path), c in by.items():
        if path != "static_fused":
            continue
        legacy = by.get((mode, backend, "static_legacy"))
        if legacy:
            speedups[f"{mode}/{backend}"] = (
                legacy["per_step_ms"] / max(c["per_step_ms"], 1e-9))
    for k, v in speedups.items():
        print(f"static per-step speedup vs legacy loop [{k}]: {v:.2f}x")

    payload = {
        "bench": "decode_latency",
        "jax_backend": jax.default_backend(),
        "config": {"smoke": smoke, "B": B, "prompt_len": prompt_len,
                   "max_new": max_new, "repeats": repeats,
                   "decode_chunk": decode_chunk,
                   "d_model": cfg0.d_model, "num_layers": cfg0.num_layers},
        "cells": cells,
        "static_per_step_speedup_vs_legacy": speedups,
    }
    path = save_artifact("BENCH_decode", payload)
    print("wrote", path)
    return payload


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", "--tiny", action="store_true",
                    help="tiny model / few steps (CI CPU smoke)")
    ap.add_argument("--max-new", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--decode-chunk", type=int, default=8)
    ap.add_argument("--no-interpret", action="store_true",
                    help="skip the kernel_interpret backend cells")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(smoke=args.smoke, max_new=args.max_new, repeats=args.repeats,
        with_interpret=not args.no_interpret,
        decode_chunk=args.decode_chunk, seed=args.seed)


if __name__ == "__main__":
    main()
