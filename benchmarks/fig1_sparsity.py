"""Fig. 1: bit-level sparsity of 8-bit quantized weights/activations,
sign-magnitude vs 2's-complement, plus value sparsity.

Tensors come from a real (reduced) model in this repo: weights from init +
a short training run distribution, activations from a forward pass with the
synthetic pipeline (post-GeLU/SiLU activations carry the value sparsity the
paper exploits with zero-value filtering).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core import quant, sparsity
from repro.models import api, layers


def run():
    cfg = get_arch("qwen2-1.5b").reduced()
    params = api.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 128), 0,
                                cfg.vocab_size)
    mod = api.module_for(cfg)
    hidden, _, _ = mod.forward(params, cfg, {"tokens": tokens})

    rows = []

    def add(name, x):
        q, _ = quant.quantize_per_tensor(jnp.asarray(x, jnp.float32))
        rows.append({
            "tensor": name,
            "bit_sparsity_sign_mag": float(
                sparsity.bit_sparsity_sign_magnitude(q)),
            "bit_sparsity_2s_comp": float(
                sparsity.bit_sparsity_twos_complement(q)),
            "value_sparsity": float(sparsity.value_sparsity(q)),
        })

    flat, _ = jax.tree_util.tree_flatten_with_path(params["layers"])
    picked = 0
    for path, leaf in flat:
        pname = "/".join(str(getattr(k, "key", k)) for k in path)
        if leaf.ndim >= 2 and pname.endswith("w") and picked < 6:
            add("weight:" + pname[-40:], leaf)
            picked += 1
    add("activation:final_hidden", hidden)
    relu_act = jax.nn.relu(jnp.asarray(hidden, jnp.float32))
    add("activation:post_relu", relu_act)

    # paper range check: sign-magnitude bit sparsity should exceed 2's-comp
    # and land in the 55-75% band for gaussian-ish tensors
    mean_sm = sum(r["bit_sparsity_sign_mag"] for r in rows) / len(rows)
    mean_tc = sum(r["bit_sparsity_2s_comp"] for r in rows) / len(rows)
    return {"rows": rows, "mean_sign_mag": mean_sm, "mean_2s_comp": mean_tc,
            "sign_mag_advantage": mean_sm - mean_tc}
