"""Fig. 11: Skipped-Calculations ratio (of the 49 single-bit products) for
Ideal / Bit-serial / BP-exact / BP-approx across bit sparsity, and the
"fraction of Ideal" table the paper quotes (74.5/84.0/92.0/97.7% for
BP-exact at 60-90% vs 71.4/76.9/83.3/90.9% for bit-serial)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitparticle as bp
from repro.core.sparsity import sample_with_bit_sparsity

BS_VALUES = (0.5, 0.52, 0.55, 0.6, 0.7, 0.8, 0.9)
N = 200_000


def run():
    rows = []
    frac_of_ideal = {"bp_exact": {}, "bit_serial": {}}
    for bs in BS_VALUES:
        ka, kw = jax.random.split(jax.random.PRNGKey(int(bs * 1000)))
        a = sample_with_bit_sparsity(ka, (N,), bs)
        w = sample_with_bit_sparsity(kw, (N,), bs)
        row = {"bit_sparsity": bs}
        for m in ("ideal", "bit_serial", "bp_exact", "bp_approx"):
            row[m] = float(jnp.mean(bp.skipped_calculations(a, w, m)))
        rows.append(row)
        for m in ("bp_exact", "bit_serial"):
            frac_of_ideal[m][bs] = row[m] / row["ideal"]
    crossover = None
    for r in rows:
        if r["bp_exact"] > r["bit_serial"]:
            crossover = r["bit_sparsity"]
            break
    return {
        "rows": rows,
        "bp_beats_bitserial_from_bs": crossover,          # paper: ~0.52
        "bp_exact_frac_of_ideal": {k: v for k, v in
                                   frac_of_ideal["bp_exact"].items()
                                   if k in (0.6, 0.7, 0.8, 0.9)},
        "bit_serial_frac_of_ideal": {k: v for k, v in
                                     frac_of_ideal["bit_serial"].items()
                                     if k in (0.6, 0.7, 0.8, 0.9)},
    }
