"""Shared benchmark plumbing: artifact dir, timing, CSV row protocol."""

from __future__ import annotations

import json
import os
import time

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "bench")


def save_artifact(name: str, payload) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6
