"""Shared benchmark plumbing: artifact dir, timing, run-metadata stamping.

Every BENCH_*.json artifact written through :func:`save_artifact` carries a
``_meta`` block (git sha, jax version, device kind/count, hostname, UTC
timestamp, artifact schema version).  ``benchmarks/compare.py`` — the CI
regression gate — uses it to refuse cross-machine comparisons instead of
reporting hardware differences as regressions.
"""

from __future__ import annotations

import json
import os
import socket
import subprocess
import time

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                            "bench")

#: Version of the BENCH_*.json envelope (the ``_meta`` block and how metric
#: keys are named).  Bump when compare.py's parsing assumptions change.
ARTIFACT_SCHEMA_VERSION = 1


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def run_metadata() -> dict:
    """Provenance stamped into every artifact.  jax imports lazily so
    host-only scripts (and compare.py itself) stay import-light."""
    meta = {
        "schema": ARTIFACT_SCHEMA_VERSION,
        "git_sha": _git_sha(),
        "hostname": socket.gethostname(),
        "timestamp_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
    }
    try:
        import jax
        devices = jax.devices()
        meta["jax_version"] = jax.__version__
        meta["device_kind"] = devices[0].device_kind if devices else "none"
        meta["device_count"] = len(devices)
    except Exception:
        meta["jax_version"] = "unavailable"
        meta["device_kind"] = "unknown"
        meta["device_count"] = 0
    return meta


def save_artifact(name: str, payload) -> str:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    if isinstance(payload, dict) and "_meta" not in payload:
        payload = dict(payload, _meta=run_metadata())
    path = os.path.join(ARTIFACT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6
