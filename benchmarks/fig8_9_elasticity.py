"""Figs 8-9: PE utilization and avg cycles/step over the E x Q x sparsity
grid, on the cycle-accurate quasi-sync simulator (zero-value filtering off,
exactly the paper's first experiment set)."""

from __future__ import annotations

from repro.core.array_sim import ArrayConfig, run_experiment

E_VALUES = (0, 1, 3, 7)
Q_VALUES = (0, 1, 2, 4)
BS_VALUES = (0.5, 0.6, 0.7, 0.8, 0.9)
N_STEPS = 256


def run():
    rows = []
    grid = {}
    for E in E_VALUES:
        for Q in Q_VALUES:
            for bs in BS_VALUES:
                res = run_experiment(0, ArrayConfig(E=E, Q=Q), N_STEPS, bs)
                rows.append({"E": E, "Q": Q, "bit_sparsity": bs,
                             "pe_utilization": res.pe_utilization,
                             "avg_cycles_per_step": res.avg_cycles_per_step})
                grid[(E, Q, bs)] = res
    # paper's three conclusions as derived metrics
    util = lambda e, q, b: grid[(e, q, b)].pe_utilization
    base_range = [util(0, 0, b) for b in BS_VALUES]
    best_range = [util(3, 2, b) for b in BS_VALUES]
    intra_beats_inter = sum(
        util(0, 2, b) > util(3, 0, b) for b in (0.5, 0.6, 0.7, 0.8))
    diminishing = (util(3, 0, 0.7) - util(1, 0, 0.7)) > (
        util(7, 0, 0.7) - util(3, 0, 0.7))
    return {
        "rows": rows,
        "baseline_util_range": [min(base_range), max(base_range)],
        "e3q2_util_range": [min(best_range), max(best_range)],
        "intra_beats_inter_at_typical_bs(/4)": intra_beats_inter,
        "diminishing_returns_confirmed": bool(diminishing),
    }
