"""Production-mix serving benchmark: the full observability stack under a
realistic multi-tenant load, gated on step-time percentiles.

Three tenants share per-tenant system prompts (exercising the paged
backend's prefix-sharing trie), user suffixes mix short / medium / long
prompts (exercising ragged grouped prefill), arrivals are Poisson, and the
serve runs with prompt-lookup speculation, full telemetry (metrics JSONL +
Chrome trace), and the hardware-cost ``SparsityProbe`` enabled — i.e. the
production configuration, not the stripped-down fast path.

The artifact (``BENCH_production_mix.json``) carries ``per_step_ms``
{p50, p90, p99} pooled over decode+verify steps and ``tokens_per_s`` —
both gated by ``benchmarks/compare.py`` — plus the run's measured-traffic
hardware estimate (mean bit sparsity, modeled cycles/MAC per method,
array utilization, Table III energy).

    PYTHONPATH=src python benchmarks/production_mix.py [--tiny]
    PYTHONPATH=src python benchmarks/production_mix.py --telemetry DIR

``--telemetry DIR`` keeps the run's metrics JSONL + trace + sparsity
profile under DIR (CI uploads them as artifacts); without it they land in
a temp dir used only to compute the percentiles.
"""

from __future__ import annotations

import argparse
import os
import sys
import tempfile

import numpy as np
import jax

if __package__ in (None, ""):  # ran as a script: make `benchmarks.` importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def _poisson_arrivals(rng, n: int, rate: float) -> np.ndarray:
    """Arrival times (decode-step clock) of a Poisson process with ``rate``
    requests per decode step."""
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.cumsum(gaps)


def run(tiny: bool = False, seed: int = 0, probe_every: int = 2,
        n_slots: int = None, n_requests: int = None, rate: float = 0.7,
        block_size: int = 8, telemetry_dir: str = None):
    import dataclasses
    import json

    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import (Request, SchedulerConfig, ServeConfig,
                               ServingEngine, SparsityProbe, Telemetry,
                               percentiles, read_jsonl, reduce_stream)

    if n_slots is None:
        n_slots = 3 if tiny else 6
    if n_requests is None:
        n_requests = 6 if tiny else 24
    n_tenants = 3
    sys_len = 8 if tiny else 16          # shared per-tenant system prompt
    # mixed prompt lengths: user suffixes drawn from three tiers
    tiers = (2, 4, 6) if tiny else (4, 12, 24)
    max_new_hi = 6 if tiny else 16
    margin = 4

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2 if tiny else 4, d_model=64 if tiny else 128,
        d_ff=128 if tiny else 256, vocab_size=256, head_dim=16,
        matmul_mode="bp_exact")   # int8 dual factors: what the probe taps
    params = api.init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(seed)
    sys_prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (n_tenants, sys_len), 2,
                           cfg.vocab_size), np.int32)
    # a short per-tenant phrase repeated inside every suffix gives the
    # prompt-lookup n-gram drafter something to actually match
    phrases = np.asarray(
        jax.random.randint(jax.random.PRNGKey(2), (n_tenants, 3), 2,
                           cfg.vocab_size), np.int32)
    tenants = rng.integers(0, n_tenants, size=n_requests)
    suffix_lens = rng.choice(tiers, size=n_requests)
    prompts = []
    for i in range(n_requests):
        t = int(tenants[i])
        uniq = rng.integers(2, cfg.vocab_size, size=int(suffix_lens[i]))
        prompts.append(np.concatenate(
            [sys_prompts[t], phrases[t], uniq.astype(np.int32),
             phrases[t]]).astype(np.int32))
    max_news = rng.integers(2, max_new_hi + 1, size=n_requests).tolist()
    arrivals = _poisson_arrivals(rng, n_requests, rate)

    max_prompt = max(len(p) for p in prompts)
    cache_T = max_prompt + max_new_hi + margin
    # generous pool: this benchmark measures the instrumented steady state,
    # not preemption churn (paged_memory covers pool pressure)
    num_blocks = 1 + (n_slots + 2) * cache_T // block_size

    def reqs():
        return [Request(prompt=prompts[i], max_new_tokens=int(max_news[i]),
                        arrival_time=float(arrivals[i]))
                for i in range(n_requests)]

    sched = SchedulerConfig(lead_window=3)
    probe = SparsityProbe(probe_every=probe_every)
    engine = ServingEngine(cfg, params, ServeConfig(
        max_new_tokens=max_new_hi, temperature=0.0,
        cache_backend="paged", block_size=block_size,
        draft="prompt_lookup", num_draft_tokens=3, probe=probe))

    # warmup with the probe already attached: compiles the probed step-fn
    # variants AND builds the host-side Monte-Carlo interpolation tables,
    # so the timed run measures the instrumented steady state
    engine.serve(reqs()[:2], n_slots=n_slots, cache_T=cache_T,
                 num_blocks=num_blocks, sched_cfg=sched)

    own_tmp = None
    if telemetry_dir is None:
        own_tmp = tempfile.TemporaryDirectory(prefix="production_mix_")
        telemetry_dir = own_tmp.name
        keep_paths = False
    else:
        keep_paths = True
    metrics_path = os.path.join(telemetry_dir, "production_mix_metrics.jsonl")
    trace_path = os.path.join(telemetry_dir, "production_mix_trace.json")
    profile_path = os.path.join(telemetry_dir, "sparsity_profile.json")

    tel = Telemetry(metrics_path=metrics_path, trace_path=trace_path)
    saved_cfg = engine.serve_cfg
    engine.serve_cfg = dataclasses.replace(saved_cfg, telemetry=tel)
    try:
        report = engine.serve(reqs(), n_slots=n_slots, cache_T=cache_T,
                              num_blocks=num_blocks, sched_cfg=sched)
    finally:
        engine.serve_cfg = saved_cfg
        tel.close()

    records = read_jsonl(metrics_path)
    step_ms = [1e3 * r["wall_s"] for r in records
               if r.get("kind") in ("decode", "verify")]
    prefill_ms = [1e3 * r["wall_s"] for r in records
                  if r.get("kind") == "prefill"]
    summary = reduce_stream(records)

    # greedy identity vs the plain fast path: slab backend, no speculation,
    # no probe, no telemetry — the production mix must not change tokens
    plain = ServingEngine(cfg, params, ServeConfig(
        max_new_tokens=max_new_hi, temperature=0.0))
    base = plain.serve(reqs(), n_slots=n_slots, cache_T=cache_T,
                       sched_cfg=sched)
    mismatches = 0
    for a, b in zip(sorted(report.results, key=lambda r: r.request_id),
                    sorted(base.results, key=lambda r: r.request_id)):
        if (len(a.tokens) != len(b.tokens)
                or (np.asarray(a.tokens) != np.asarray(b.tokens)).any()):
            mismatches += 1

    if keep_paths:
        with open(profile_path, "w") as f:
            json.dump({"weights": engine.weight_sparsity_profile(),
                       "measured": report.hw_measured}, f, indent=2,
                      default=float)

    result = {
        "n_requests": n_requests,
        "n_tenants": n_tenants,
        "n_slots": n_slots,
        "probe_every": probe_every,
        "block_size": block_size,
        "arrival_rate_per_step": rate,
        "prompt_len_min": int(min(len(p) for p in prompts)),
        "prompt_len_max": int(max_prompt),
        # gated: suffix-matched by benchmarks/compare.py
        "per_step_ms": percentiles(step_ms),
        "tokens_per_s": report.decode_tokens_per_s,
        # informative (not gated)
        "prefill_ms_pcts": percentiles(prefill_ms),
        "decode_steps": int(report.steps),
        "n_syncs": int(report.n_syncs),
        "prefix_hit_blocks": int(report.prefix_hit_blocks),
        "drafted_tokens": int(report.drafted_tokens),
        "accepted_tokens": int(report.accepted_tokens),
        "acceptance_rate": (report.accepted_tokens
                            / max(report.drafted_tokens, 1)),
        "n_hw_samples": int(summary.n_hw_samples),
        "hw_measured": report.hw_measured,
        "token_mismatches": mismatches,
    }
    if keep_paths:
        result["telemetry_metrics"] = metrics_path
        result["telemetry_trace"] = trace_path
        result["sparsity_profile"] = profile_path
    if own_tmp is not None:
        own_tmp.cleanup()
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size (seconds, not minutes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--probe-every", type=int, default=2,
                    help="sample every k-th decode/verify step (0 = off)")
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=0.7,
                    help="Poisson arrivals per decode step")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--telemetry", metavar="DIR", default=None,
                    help="keep metrics JSONL + trace + sparsity profile "
                         "under DIR (otherwise a temp dir is used)")
    args = ap.parse_args(argv)

    r = run(tiny=args.tiny, seed=args.seed, probe_every=args.probe_every,
            n_slots=args.slots, n_requests=args.requests, rate=args.rate,
            block_size=args.block_size, telemetry_dir=args.telemetry)

    from benchmarks.common import save_artifact
    path = save_artifact("BENCH_production_mix", r)

    p = r["per_step_ms"] or {}
    print(f"requests={r['n_requests']} tenants={r['n_tenants']} "
          f"slots={r['n_slots']} rate={r['arrival_rate_per_step']}/step "
          f"prompts={r['prompt_len_min']}..{r['prompt_len_max']} tokens")
    print(f"steps: {r['decode_steps']} decode+verify, per-step ms "
          f"p50={p.get('p50', float('nan')):.2f} "
          f"p90={p.get('p90', float('nan')):.2f} "
          f"p99={p.get('p99', float('nan')):.2f}   "
          f"{r['tokens_per_s']:.1f} tok/s")
    print(f"speculation: {r['accepted_tokens']}/{r['drafted_tokens']} "
          f"drafts accepted ({r['acceptance_rate']*100:.0f}%)   "
          f"prefix hits: {r['prefix_hit_blocks']} blocks")
    hw = r["hw_measured"]
    if hw:
        cyc = hw["cycles"]
        print(f"hw probe: {r['n_hw_samples']} samples, "
              f"act_bs={hw['act_bit_sparsity']:.3f} "
              f"w_bs={hw['weight_bit_sparsity']:.3f} "
              f"util={hw['array_utilization']:.3f}, cycles/MAC "
              f"bp_exact={cyc['bp_exact']:.2f} "
              f"bp_approx={cyc['bp_approx']:.2f} "
              f"adas={cyc['adas']:.2f} bitwave={cyc['bitwave']:.2f}")
    if r.get("telemetry_metrics"):
        print(f"telemetry: {r['telemetry_metrics']} + "
              f"{r['telemetry_trace']} + {r['sparsity_profile']}")
    print(f"artifact: {path}")
    if r["token_mismatches"]:
        print("ERROR: production mix diverged from plain greedy outputs",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
