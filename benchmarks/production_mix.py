"""Production-mix serving benchmark: the full observability stack under a
realistic multi-tenant load, gated on step-time percentiles.

Three tenants share per-tenant system prompts (exercising the paged
backend's prefix-sharing trie), user suffixes mix short / medium / long
prompts (exercising ragged grouped prefill), arrivals are Poisson, and the
serve runs with prompt-lookup speculation, full telemetry (metrics JSONL +
Chrome trace), and the hardware-cost ``SparsityProbe`` enabled — i.e. the
production configuration, not the stripped-down fast path.

The artifact (``BENCH_production_mix.json``) carries ``per_step_ms``
{p50, p90, p99} pooled over decode+verify steps and ``tokens_per_s`` —
both gated by ``benchmarks/compare.py`` — plus the run's measured-traffic
hardware estimate (mean bit sparsity, modeled cycles/MAC per method,
array utilization, Table III energy).

The same mix also runs as a **mesh (tensor-parallel) leg**: the identical
workload on a ``("data", "model")`` ``MeshExecutor``, measured in a worker
subprocess with virtual CPU devices (``XLA_FLAGS`` must be set before jax
initializes — the ``benchmarks/sharded_serving.py`` harness pattern) and
saved as its own gated artifact (``BENCH_production_mix_mesh.json``) with
its own telemetry files (``production_mix_mesh_*``).

    PYTHONPATH=src python benchmarks/production_mix.py [--tiny]
    PYTHONPATH=src python benchmarks/production_mix.py --telemetry DIR
    PYTHONPATH=src python benchmarks/production_mix.py --mesh 2x4

``--telemetry DIR`` keeps the runs' metrics JSONL + trace + sparsity
profiles under DIR (CI uploads them as artifacts); without it they land in
a temp dir used only to compute the percentiles.  ``--mesh none`` skips
the mesh leg.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

import numpy as np

if __package__ in (None, ""):  # ran as a script: make `benchmarks.` importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))

_DEVICE_ENV = "--xla_force_host_platform_device_count"


def _poisson_arrivals(rng, n: int, rate: float) -> np.ndarray:
    """Arrival times (decode-step clock) of a Poisson process with ``rate``
    requests per decode step."""
    gaps = rng.exponential(1.0 / rate, size=n)
    return np.cumsum(gaps)


def run(tiny: bool = False, seed: int = 0, probe_every: int = 2,
        n_slots: int = None, n_requests: int = None, rate: float = 0.7,
        block_size: int = 8, telemetry_dir: str = None, mesh_shape=None,
        matmul_backend: str = None):
    import dataclasses

    import jax
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import (Request, SchedulerConfig, ServeConfig,
                               ServingEngine, SparsityProbe, Telemetry,
                               percentiles, read_jsonl, reduce_stream)

    if n_slots is None:
        n_slots = 3 if tiny else 6
    if n_requests is None:
        n_requests = 6 if tiny else 24
    n_tenants = 3
    sys_len = 8 if tiny else 16          # shared per-tenant system prompt
    # mixed prompt lengths: user suffixes drawn from three tiers
    tiers = (2, 4, 6) if tiny else (4, 12, 24)
    max_new_hi = 6 if tiny else 16
    margin = 4

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2 if tiny else 4, d_model=64 if tiny else 128,
        d_ff=128 if tiny else 256, vocab_size=256, head_dim=16,
        matmul_mode="bp_exact")   # int8 dual factors: what the probe taps
    if matmul_backend is not None:
        cfg = cfg.replace(matmul_backend=matmul_backend)
    params = api.init(jax.random.PRNGKey(0), cfg)

    rng = np.random.default_rng(seed)
    sys_prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1), (n_tenants, sys_len), 2,
                           cfg.vocab_size), np.int32)
    # a short per-tenant phrase repeated inside every suffix gives the
    # prompt-lookup n-gram drafter something to actually match
    phrases = np.asarray(
        jax.random.randint(jax.random.PRNGKey(2), (n_tenants, 3), 2,
                           cfg.vocab_size), np.int32)
    tenants = rng.integers(0, n_tenants, size=n_requests)
    suffix_lens = rng.choice(tiers, size=n_requests)
    prompts = []
    for i in range(n_requests):
        t = int(tenants[i])
        uniq = rng.integers(2, cfg.vocab_size, size=int(suffix_lens[i]))
        prompts.append(np.concatenate(
            [sys_prompts[t], phrases[t], uniq.astype(np.int32),
             phrases[t]]).astype(np.int32))
    max_news = rng.integers(2, max_new_hi + 1, size=n_requests).tolist()
    arrivals = _poisson_arrivals(rng, n_requests, rate)

    max_prompt = max(len(p) for p in prompts)
    cache_T = max_prompt + max_new_hi + margin
    # generous pool: this benchmark measures the instrumented steady state,
    # not preemption churn (paged_memory covers pool pressure)
    num_blocks = 1 + (n_slots + 2) * cache_T // block_size

    def reqs():
        return [Request(prompt=prompts[i], max_new_tokens=int(max_news[i]),
                        arrival_time=float(arrivals[i]))
                for i in range(n_requests)]

    sched = SchedulerConfig(lead_window=3)
    probe = SparsityProbe(probe_every=probe_every)
    engine = ServingEngine(cfg, params, ServeConfig(
        max_new_tokens=max_new_hi, temperature=0.0,
        cache_backend="paged", block_size=block_size,
        draft="prompt_lookup", num_draft_tokens=3, probe=probe,
        mesh_shape=tuple(mesh_shape) if mesh_shape else None))

    # warmup with the probe already attached: compiles the probed step-fn
    # variants AND builds the host-side Monte-Carlo interpolation tables,
    # so the timed run measures the instrumented steady state
    engine.serve(reqs()[:2], n_slots=n_slots, cache_T=cache_T,
                 num_blocks=num_blocks, sched_cfg=sched)

    own_tmp = None
    if telemetry_dir is None:
        own_tmp = tempfile.TemporaryDirectory(prefix="production_mix_")
        telemetry_dir = own_tmp.name
        keep_paths = False
    else:
        keep_paths = True
    stem = "production_mix_mesh" if mesh_shape else "production_mix"
    metrics_path = os.path.join(telemetry_dir, f"{stem}_metrics.jsonl")
    trace_path = os.path.join(telemetry_dir, f"{stem}_trace.json")
    profile_path = os.path.join(
        telemetry_dir, "sparsity_profile_mesh.json" if mesh_shape
        else "sparsity_profile.json")

    tel = Telemetry(metrics_path=metrics_path, trace_path=trace_path)
    saved_cfg = engine.serve_cfg
    engine.serve_cfg = dataclasses.replace(saved_cfg, telemetry=tel)
    try:
        report = engine.serve(reqs(), n_slots=n_slots, cache_T=cache_T,
                              num_blocks=num_blocks, sched_cfg=sched)
    finally:
        engine.serve_cfg = saved_cfg
        tel.close()

    records = read_jsonl(metrics_path)
    step_ms = [1e3 * r["wall_s"] for r in records
               if r.get("kind") in ("decode", "verify")]
    prefill_ms = [1e3 * r["wall_s"] for r in records
                  if r.get("kind") == "prefill"]
    summary = reduce_stream(records)

    # greedy identity vs the plain fast path: no speculation, no probe, no
    # telemetry — the production mix must not change tokens.  Single-device
    # the reference is the slab backend (slab vs paged is a pure storage
    # transform there, so this also gates cross-backend identity); on the
    # mesh leg the reference rides the same mesh AND the paged backend,
    # because a mesh reorders float reductions differently per executor and
    # cache layout (split-KV slab vs replicated pages), so near-tie
    # argmaxes on a random-init toy model may legitimately differ across
    # those — cross-executor identity is a separate invariant, covered by
    # tests/test_sharded_serving.py and tests/test_mesh_kernels.py on
    # their pinned workloads.
    if mesh_shape:
        plain = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=max_new_hi, temperature=0.0,
            cache_backend="paged", block_size=block_size,
            mesh_shape=tuple(mesh_shape)))
        base = plain.serve(reqs(), n_slots=n_slots, cache_T=cache_T,
                           num_blocks=num_blocks, sched_cfg=sched)
    else:
        plain = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=max_new_hi, temperature=0.0))
        base = plain.serve(reqs(), n_slots=n_slots, cache_T=cache_T,
                           sched_cfg=sched)
    mismatches = 0
    for a, b in zip(sorted(report.results, key=lambda r: r.request_id),
                    sorted(base.results, key=lambda r: r.request_id)):
        if (len(a.tokens) != len(b.tokens)
                or (np.asarray(a.tokens) != np.asarray(b.tokens)).any()):
            mismatches += 1

    if keep_paths:
        with open(profile_path, "w") as f:
            json.dump({"weights": engine.weight_sparsity_profile(),
                       "measured": report.hw_measured}, f, indent=2,
                      default=float)

    result = {
        "n_requests": n_requests,
        "n_tenants": n_tenants,
        "n_slots": n_slots,
        "mesh_shape": list(mesh_shape) if mesh_shape else None,
        "matmul_backend": engine.executor.matmul_backend,
        "probe_every": probe_every,
        "block_size": block_size,
        "arrival_rate_per_step": rate,
        "prompt_len_min": int(min(len(p) for p in prompts)),
        "prompt_len_max": int(max_prompt),
        # gated: suffix-matched by benchmarks/compare.py
        "per_step_ms": percentiles(step_ms),
        "tokens_per_s": report.decode_tokens_per_s,
        # informative (not gated)
        "prefill_ms_pcts": percentiles(prefill_ms),
        "decode_steps": int(report.steps),
        "n_syncs": int(report.n_syncs),
        "prefix_hit_blocks": int(report.prefix_hit_blocks),
        "drafted_tokens": int(report.drafted_tokens),
        "accepted_tokens": int(report.accepted_tokens),
        "acceptance_rate": (report.accepted_tokens
                            / max(report.drafted_tokens, 1)),
        "n_hw_samples": int(summary.n_hw_samples),
        "hw_measured": report.hw_measured,
        "token_mismatches": mismatches,
    }
    if keep_paths:
        result["telemetry_metrics"] = metrics_path
        result["telemetry_trace"] = trace_path
        result["sparsity_profile"] = profile_path
    if own_tmp is not None:
        own_tmp.cleanup()
    return result


def run_mesh_leg(mesh_shape, *, tiny: bool = False, seed: int = 0,
                 probe_every: int = 2, n_slots: int = None,
                 n_requests: int = None, rate: float = 0.7,
                 block_size: int = 8, telemetry_dir: str = None,
                 matmul_backend: str = None) -> dict:
    """Run the mix on a ``("data", "model")`` mesh in a worker subprocess.

    Virtual CPU devices need ``XLA_FLAGS`` set before jax initializes and
    the parent's jax is already initialized single-device, so the mesh leg
    reuses the ``benchmarks/sharded_serving.py`` worker harness: spawn this
    script with ``--worker``, parse its last-line JSON."""
    n_dev = int(mesh_shape[0]) * int(mesh_shape[1])
    env = dict(os.environ)
    flags = [f for f in env.get("XLA_FLAGS", "").split()
             if not f.startswith(_DEVICE_ENV)]
    env["XLA_FLAGS"] = " ".join(flags + [f"{_DEVICE_ENV}={n_dev}"])
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, os.path.abspath(__file__), "--worker",
           "--mesh", f"{mesh_shape[0]}x{mesh_shape[1]}",
           "--seed", str(seed), "--probe-every", str(probe_every),
           "--rate", str(rate), "--block-size", str(block_size)]
    if tiny:
        cmd.append("--tiny")
    if n_slots is not None:
        cmd += ["--slots", str(n_slots)]
    if n_requests is not None:
        cmd += ["--requests", str(n_requests)]
    if telemetry_dir is not None:
        cmd += ["--telemetry", telemetry_dir]
    if matmul_backend is not None:
        cmd += ["--matmul-backend", matmul_backend]
    out = subprocess.run(cmd, capture_output=True, text=True, timeout=1200,
                         env=env)
    if out.returncode != 0:
        raise RuntimeError(
            f"production-mix mesh worker failed:\n{out.stdout}\n{out.stderr}")
    return json.loads(out.stdout.splitlines()[-1])


def _print_summary(r, label=""):
    p = r["per_step_ms"] or {}
    where = (f"mesh {tuple(r['mesh_shape'])}" if r.get("mesh_shape")
             else "single-device")
    print(f"{label}{where}: requests={r['n_requests']} "
          f"tenants={r['n_tenants']} slots={r['n_slots']} "
          f"rate={r['arrival_rate_per_step']}/step "
          f"prompts={r['prompt_len_min']}..{r['prompt_len_max']} tokens")
    print(f"steps: {r['decode_steps']} decode+verify, per-step ms "
          f"p50={p.get('p50', float('nan')):.2f} "
          f"p90={p.get('p90', float('nan')):.2f} "
          f"p99={p.get('p99', float('nan')):.2f}   "
          f"{r['tokens_per_s']:.1f} tok/s")
    print(f"speculation: {r['accepted_tokens']}/{r['drafted_tokens']} "
          f"drafts accepted ({r['acceptance_rate']*100:.0f}%)   "
          f"prefix hits: {r['prefix_hit_blocks']} blocks")
    hw = r["hw_measured"]
    if hw:
        cyc = hw["cycles"]
        print(f"hw probe: {r['n_hw_samples']} samples, "
              f"act_bs={hw['act_bit_sparsity']:.3f} "
              f"w_bs={hw['weight_bit_sparsity']:.3f} "
              f"util={hw['array_utilization']:.3f}, cycles/MAC "
              f"bp_exact={cyc['bp_exact']:.2f} "
              f"bp_approx={cyc['bp_approx']:.2f} "
              f"adas={cyc['adas']:.2f} bitwave={cyc['bitwave']:.2f}")
    if r.get("telemetry_metrics"):
        print(f"telemetry: {r['telemetry_metrics']} + "
              f"{r['telemetry_trace']} + {r['sparsity_profile']}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size (seconds, not minutes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--probe-every", type=int, default=2,
                    help="sample every k-th decode/verify step (0 = off)")
    ap.add_argument("--slots", type=int, default=None)
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--rate", type=float, default=0.7,
                    help="Poisson arrivals per decode step")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--telemetry", metavar="DIR", default=None,
                    help="keep metrics JSONL + trace + sparsity profile "
                         "under DIR (otherwise a temp dir is used)")
    ap.add_argument("--mesh", default="2x4",
                    help="mesh shape DATAxMODEL for the tensor-parallel "
                         "leg, or 'none' to skip it")
    ap.add_argument("--matmul-backend", default=None,
                    help="matmul backend override for the mesh leg "
                         "(e.g. kernel_interpret)")
    ap.add_argument("--worker", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    mesh_shape = (None if args.mesh.lower() == "none"
                  else tuple(int(d) for d in args.mesh.lower().split("x")))

    if args.worker:
        r = run(tiny=args.tiny, seed=args.seed,
                probe_every=args.probe_every, n_slots=args.slots,
                n_requests=args.requests, rate=args.rate,
                block_size=args.block_size, telemetry_dir=args.telemetry,
                mesh_shape=mesh_shape, matmul_backend=args.matmul_backend)
        print(json.dumps(r, default=float))
        return 0

    r = run(tiny=args.tiny, seed=args.seed, probe_every=args.probe_every,
            n_slots=args.slots, n_requests=args.requests, rate=args.rate,
            block_size=args.block_size, telemetry_dir=args.telemetry)

    from benchmarks.common import save_artifact
    path = save_artifact("BENCH_production_mix", r)
    _print_summary(r)
    print(f"artifact: {path}")

    rc = 0
    if r["token_mismatches"]:
        print("ERROR: production mix diverged from plain greedy outputs",
              file=sys.stderr)
        rc = 1

    if mesh_shape is not None:
        rm = run_mesh_leg(mesh_shape, tiny=args.tiny, seed=args.seed,
                          probe_every=args.probe_every, n_slots=args.slots,
                          n_requests=args.requests, rate=args.rate,
                          block_size=args.block_size,
                          telemetry_dir=args.telemetry,
                          matmul_backend=args.matmul_backend)
        mesh_path = save_artifact("BENCH_production_mix_mesh", rm)
        print()
        _print_summary(rm, label="mesh leg · ")
        print(f"artifact: {mesh_path}")
        if rm["token_mismatches"]:
            print("ERROR: mesh production mix diverged from plain greedy "
                  "outputs", file=sys.stderr)
            rc = 1
    return rc


if __name__ == "__main__":
    sys.exit(main())
