"""Table III: average cycles/op, area & energy efficiency (normalized to
AdaS) for AdaS / BitWave / BP-exact / BP-approx across bit sparsity 50-90%.

Two cycle sources are reported: the paper's cited measurements, and our
first-principles Monte-Carlo models over the paper's data generator — the
delta column is the reproduction check (BP rows agree within ~8%).
"""

from __future__ import annotations

from repro.core import cost_model as cm


def run():
    cited = cm.table3("paper")
    modeled = cm.table3("model")
    rows = []
    for m in ("adas", "bitwave", "bp_exact", "bp_approx"):
        for i, bs in enumerate(cm.SPARSITY_LEVELS):
            rows.append({
                "unit": m, "bit_sparsity": bs,
                "cycles_cited": cited[m]["avg_cycles"][i],
                "cycles_modeled": modeled[m]["avg_cycles"][i],
                "cycles_delta_frac": (modeled[m]["avg_cycles"][i]
                                      - cited[m]["avg_cycles"][i])
                / cited[m]["avg_cycles"][i],
                "area_eff_norm_cited": cited[m]["area_eff"][i],
                "energy_eff_norm_cited": cited[m]["energy_eff"][i],
                "area_eff_norm_modeled": modeled[m]["area_eff"][i],
                "energy_eff_norm_modeled": modeled[m]["energy_eff"][i],
            })
    # headline reproduction checks (paper Section V-B)
    bp60_area = cited["bp_exact"]["area_eff"][1]      # 1.23 => +23% vs AdaS
    bp70_area = cited["bp_exact"]["area_eff"][2]      # 1.14 => +14%
    approx_vs_exact_area = (cited["bp_approx"]["area_eff"][1]
                            / cited["bp_exact"]["area_eff"][1] - 1)
    approx_vs_exact_energy = (cited["bp_approx"]["energy_eff"][1]
                              / cited["bp_exact"]["energy_eff"][1] - 1)
    max_bp_cycle_err = max(abs(r["cycles_delta_frac"]) for r in rows
                           if r["unit"].startswith("bp"))
    return {
        "rows": rows,
        "bp_exact_area_eff_gain_60pct": bp60_area - 1.0,
        "bp_exact_area_eff_gain_70pct": bp70_area - 1.0,
        "approx_area_gain_vs_exact": approx_vs_exact_area,      # paper ~23%
        "approx_energy_gain_vs_exact": approx_vs_exact_energy,  # paper ~18%
        "max_bp_modeled_cycle_error": max_bp_cycle_err,
    }
