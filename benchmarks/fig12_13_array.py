"""Figs 12-13: system-level area / energy efficiency of the BitParticle
accelerator vs BitWave and AdaS on the four CNNs, normalized to AdaS.

Mini-ZigZag flow per (accelerator, network):
  1. per-layer dataflow choice + spatial utilization (dataflow engine),
  2. cycles: temporal steps x avg-cycles-per-step from the cycle-accurate
     array simulator (BitParticle, with zero-value filtering) or cited
     per-op cycles (baselines — generous: they get our best-mapping
     utilization too, noted as a conservative choice for our claims),
  3. energy: MAC energy (Table III derived) + SRAM traffic + DRAM traffic,
  4. area: PE array + SRAM macro area.
"""

from __future__ import annotations

import math

import numpy as np

from repro.configs.cnn_zoo import (ACT_VALUE_SPARSITY, BIT_SPARSITY, NETWORKS)
from repro.core import cost_model as cm
from repro.core.array_sim import ArrayConfig, run_experiment
from repro.core.dataflow import analyze_traffic, choose_mapping

CLOCK = cm.CLOCK_HZ


def _accel_area_mm2(accel: str, unit: str) -> float:
    cfg = cm.ACCEL_CONFIGS[accel]
    pe = cfg.pe_count * cm.AREA_UM2[unit] * 1e-6
    sram_kb = (cfg.w_cache_bytes + cfg.a_cache_bytes + cfg.r_cache_bytes
               + cfg.metadata_bytes) / 1024
    return pe + sram_kb * cm.SRAM_MM2_PER_KB


def _bp_cycles_per_op(net: str, approx: bool) -> float:
    res = run_experiment(0, ArrayConfig(E=3, Q=2, zero_filter=True,
                                        approx=approx), 256,
                         BIT_SPARSITY[net],
                         a_value_sparsity=ACT_VALUE_SPARSITY[net])
    return res.avg_cycles_per_step


def _baseline_cycles_per_op(unit: str, net: str) -> float:
    xs = np.asarray(cm.SPARSITY_LEVELS)
    return float(np.interp(BIT_SPARSITY[net], xs,
                           np.asarray(cm.PAPER_AVG_CYCLES[unit])))


BATCH = 8   # inference batch (amortizes FC weight DRAM traffic)


def evaluate(accel_key: str, unit: str, net: str):
    import dataclasses
    layers = [dataclasses.replace(l, B=l.B * BATCH) for l in NETWORKS[net]()]
    acfg = cm.ACCEL_CONFIGS[accel_key]
    bs = BIT_SPARSITY[net]
    if unit.startswith("bp"):
        cpo = _bp_cycles_per_op(net, unit == "bp_approx")
    else:
        cpo = _baseline_cycles_per_op(unit, net)
    total_macs = total_cycles = 0
    e_mac = e_sram = e_dram = 0.0
    mac_pj = cm.mac_energy_pj(unit, bs)
    for layer in layers:
        m = choose_mapping(layer)
        total_macs += layer.total_macs
        # scale steps to this accelerator's PE count (512-slot steps)
        steps = m.steps * (512 / acfg.pe_count)
        total_cycles += steps * cpo
        t = analyze_traffic(layer, m, accel_key)
        e_sram += t.cache_energy_pj(accel_key)
        if acfg.metadata_bytes:   # AdaS per-op bit-index metadata reads
            e_sram += layer.total_macs * cm.sram_pj_per_byte(
                acfg.metadata_bytes)
        e_dram += t.dram_energy_pj()
        e_mac += layer.total_macs * mac_pj
    time_s = total_cycles / CLOCK
    energy_j = (e_mac + e_sram + e_dram) * 1e-12
    core_j = (e_mac + e_sram) * 1e-12
    tops = 2 * total_macs / time_s / 1e12
    area = _accel_area_mm2(accel_key, unit)
    return {"net": net, "unit": unit, "tops": tops,
            "area_mm2": area, "energy_j": energy_j,
            "area_eff": tops / area,
            "energy_eff": 2 * total_macs / energy_j / 1e12,
            "core_energy_eff": 2 * total_macs / core_j / 1e12}


def run():
    systems = [("bitparticle", "bp_exact"), ("bitparticle", "bp_approx"),
               ("bitwave", "bitwave"), ("adas", "adas")]
    rows = []
    per_net = {}
    for net in NETWORKS:
        base = evaluate("adas", "adas", net)
        for accel, unit in systems:
            r = evaluate(accel, unit, net)
            r["area_eff_norm"] = r["area_eff"] / base["area_eff"]
            r["energy_eff_norm"] = r["energy_eff"] / base["energy_eff"]
            r["core_energy_eff_norm"] = (r["core_energy_eff"]
                                         / base["core_energy_eff"])
            rows.append(r)
            per_net.setdefault(unit, {})[net] = r
    gm = lambda unit, key: float(np.exp(np.mean([
        np.log(per_net[unit][n][key]) for n in NETWORKS])))
    out = {
        "rows": rows,
        "geomean_area_eff_vs_adas": {u: gm(u, "area_eff_norm")
                                     for _, u in systems},
        "geomean_energy_eff_vs_adas": {u: gm(u, "energy_eff_norm")
                                       for _, u in systems},
        "geomean_core_energy_eff_vs_adas": {u: gm(u, "core_energy_eff_norm")
                                            for _, u in systems},
    }
    out["bp_vs_bitwave_area_eff"] = (
        out["geomean_area_eff_vs_adas"]["bp_exact"]
        / out["geomean_area_eff_vs_adas"]["bitwave"] - 1)       # paper 29.2%
    out["bp_vs_bitwave_energy_eff"] = (
        out["geomean_energy_eff_vs_adas"]["bp_exact"]
        / out["geomean_energy_eff_vs_adas"]["bitwave"] - 1)     # ~comparable
    out["approx_vs_exact_energy"] = (
        out["geomean_energy_eff_vs_adas"]["bp_approx"]
        / out["geomean_energy_eff_vs_adas"]["bp_exact"] - 1)    # paper 7.5%
    out["approx_vs_exact_area"] = (
        out["geomean_area_eff_vs_adas"]["bp_approx"]
        / out["geomean_area_eff_vs_adas"]["bp_exact"] - 1)      # paper 2.1%
    return out
