"""Chaos smoke: a seeded fault-injection serve as a CI gate.

Runs one fault-free reference serve and one serve under a seeded
``FaultInjector`` schedule (transient step faults, pool exhaustion,
simulated OOM, NaN logits, drafter failures, chaos cancellations) on the
paged + speculative path, then checks the robustness invariants that
``tests/test_faults.py`` pins in depth:

  * every request reaches a terminal state and ``serve()`` returns;
  * survivors are TOKEN-IDENTICAL to the fault-free run;
  * the block pool is leak-free after the queue drains;
  * every injected fault is visible in the telemetry stream.

    PYTHONPATH=src python benchmarks/chaos_smoke.py [--tiny]
    PYTHONPATH=src python benchmarks/chaos_smoke.py --telemetry DIR

``--telemetry DIR`` writes ``DIR/chaos_metrics.jsonl`` — the full step +
fault/retry/degrade/recover record stream CI uploads next to the other
bench artifacts.  Counters are reported in the artifact but no wall-clock
metric is gated: a chaos run's latency is injection noise by design.
"""

from __future__ import annotations

import argparse
import os
import sys

import numpy as np
import jax

if __package__ in (None, ""):  # ran as a script: make `benchmarks.` importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))


def run(tiny: bool = False, seed: int = 0, telemetry_dir: str = None):
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import (FaultInjector, Request, SchedulerConfig,
                               ServeConfig, ServingEngine, Telemetry)

    n_requests = 6 if tiny else 16
    prompt_len = 6 if tiny else 12
    max_new = 8 if tiny else 16
    n_slots = 2 if tiny else 4

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256, head_dim=16)
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = np.asarray(
        jax.random.randint(jax.random.PRNGKey(1),
                           (n_requests, prompt_len), 2, cfg.vocab_size),
        np.int32)
    rng = np.random.default_rng(seed)
    max_news = rng.integers(2, max_new + 1, size=n_requests).tolist()
    arrivals = np.cumsum(rng.exponential(2.0, size=n_requests))

    def requests():
        return [Request(prompt=prompts[i], max_new_tokens=int(max_news[i]),
                        arrival_time=float(arrivals[i]))
                for i in range(n_requests)]

    def serve_once(faults=None, telemetry=None):
        engine = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=max_new, temperature=0.0,
            cache_backend="paged", block_size=4,
            draft="prompt_lookup", num_draft_tokens=3,
            faults=faults, telemetry=telemetry,
            max_step_retries=1, max_recoveries=50))
        loop = engine.make_loop(requests(), n_slots=n_slots,
                                sched_cfg=SchedulerConfig(lead_window=2))
        return loop.run(), loop

    baseline, _ = serve_once()
    base_tokens = [list(r.tokens) for r in baseline.results]

    injector = FaultInjector(
        seed=seed,
        rates={"step": 0.05, "prefill": 0.05, "pool": 0.05, "oom": 0.03,
               "nan": 0.01, "drafter": 0.10, "cancel": 0.01},
        max_faults=10)
    tel = None
    if telemetry_dir:
        os.makedirs(telemetry_dir, exist_ok=True)
        tel = Telemetry(metrics_path=os.path.join(telemetry_dir,
                                                  "chaos_metrics.jsonl"))
    try:
        report, loop = serve_once(faults=injector, telemetry=tel)
    finally:
        if tel is not None:
            tel.close()

    mismatches = 0
    survivors = 0
    for i, res in enumerate(report.results):
        if res.finish_reason in ("eos", "length"):
            survivors += 1
            if list(res.tokens) != base_tokens[i]:
                mismatches += 1

    pool = loop.cm.pool
    leaked = int(pool.n_live) + int(
        (pool.num_blocks - 1) - pool.n_free)
    injected_records = sum(1 for r in loop.stream
                           if r["kind"] == "fault" and r.get("injected"))
    unaccounted = len(injector.injected) - injected_records

    result = {
        "n_requests": n_requests,
        "n_injected_faults": len(injector.injected),
        "injected_by_site": {
            site: sum(1 for s, _, _ in injector.injected if s == site)
            for site in sorted({s for s, _, _ in injector.injected})},
        "n_retries": report.n_retries,
        "n_recoveries": report.n_recoveries,
        "n_degrades": report.n_degrades,
        "n_cancelled": report.n_cancelled,
        "n_failed": report.n_failed,
        "n_survivors": survivors,
        "survivor_token_mismatches": mismatches,
        "pool_leaked_blocks": leaked,
        "unaccounted_injections": unaccounted,
    }
    if telemetry_dir:
        result["telemetry_metrics"] = os.path.join(telemetry_dir,
                                                   "chaos_metrics.jsonl")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke size (seconds, not minutes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry", metavar="DIR", default=None,
                    help="write DIR/chaos_metrics.jsonl (full fault/step "
                         "record stream)")
    args = ap.parse_args(argv)

    r = run(tiny=args.tiny, seed=args.seed, telemetry_dir=args.telemetry)

    from benchmarks.common import save_artifact
    path = save_artifact("BENCH_chaos", r)

    print(f"requests={r['n_requests']} injected={r['n_injected_faults']} "
          f"({r['injected_by_site']})")
    print(f"retries={r['n_retries']} recoveries={r['n_recoveries']} "
          f"degrades={r['n_degrades']} cancelled={r['n_cancelled']} "
          f"failed={r['n_failed']}")
    print(f"survivors: {r['n_survivors']}/{r['n_requests']} "
          f"(token mismatches: {r['survivor_token_mismatches']})")
    print(f"pool leaked blocks: {r['pool_leaked_blocks']}   "
          f"unaccounted injections: {r['unaccounted_injections']}")
    if r.get("telemetry_metrics"):
        print(f"telemetry: {r['telemetry_metrics']}")
    print(f"artifact: {path}")
    bad = (r["survivor_token_mismatches"] or r["pool_leaked_blocks"]
           or r["unaccounted_injections"])
    if bad:
        print("ERROR: chaos run violated a robustness invariant",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
