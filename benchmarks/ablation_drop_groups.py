"""Ablation (beyond paper): how far can IR-group dropping go?

The paper's approximate variant drops the two lowest-weight IR groups
(k in {0, 1}).  This ablation sweeps the knob — dropping the lowest
n in {0..4} anti-diagonal groups — and measures, from first principles:

  * worst-case and mean relative product error (exhaustive over magnitudes),
  * average cycles/MAC at typical bit sparsity,
  * skipped single-bit calculations (the Fig-11 metric),
  * end-model effect: logit MSE of a quantized matmul layer vs exact.

This quantifies the paper's "compelling trade-off" sentence: the first two
groups are nearly free (the paper's choice); the third costs ~16x more
error for <2% more cycles saved.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitparticle as bp
from repro.core.sparsity import sample_with_bit_sparsity


def _skipped(a, w, dropped):
    pa = (bp.particlize(jnp.abs(a)) != 0).astype(jnp.int32)
    pw = (bp.particlize(jnp.abs(w)) != 0).astype(jnp.int32)
    widths = jnp.asarray(bp.PARTICLE_WIDTHS, jnp.int32)
    pair = (pa * widths)[..., :, None] * (pw * widths)[..., None, :]
    keep = jnp.asarray(bp._DIAG_INDEX >= dropped, jnp.int32)
    return float(1.0 - jnp.mean(jnp.sum(pair * keep, axis=(-2, -1))
                                .astype(jnp.float32)) / 49.0)


def run():
    vals = jnp.arange(-127, 128)
    a, w = vals[:, None], vals[None, :]
    exact = (a * w).astype(jnp.int32)
    key = jax.random.PRNGKey(0)
    xs = sample_with_bit_sparsity(key, (100_000,), 0.65)
    ws = sample_with_bit_sparsity(jax.random.fold_in(key, 1), (100_000,), 0.65)

    # end-model probe: one quantized dense layer, logits vs exact
    xk = jax.random.normal(jax.random.fold_in(key, 2), (64, 256))
    wk = jax.random.normal(jax.random.fold_in(key, 3), (256, 64)) / 16
    xq = jnp.clip(jnp.round(xk / (jnp.abs(xk).max() / 127)), -127, 127)
    wq = jnp.clip(jnp.round(wk / (jnp.abs(wk).max() / 127)), -127, 127)
    ref_out = None

    rows = []
    for n_drop in range(5):
        dropped = tuple(range(n_drop))
        sa, ma = bp.to_sign_magnitude(a)
        sw, mw = bp.to_sign_magnitude(w)
        prod = bp.from_sign_magnitude(
            sa ^ sw, bp.magnitude_product_from_irs(ma, mw, dropped))
        err = jnp.abs(prod - exact)
        nz = jnp.abs(exact) > 0
        rel = jnp.where(nz, err / jnp.maximum(jnp.abs(exact), 1), 0.0)

        counts = bp.group_nonzero_counts(jnp.abs(xs), jnp.abs(ws))
        keep = np.array([k >= n_drop for k in range(bp.NUM_GROUPS)])
        cyc = float(jnp.mean(jnp.maximum(
            1, jnp.max(counts * jnp.asarray(keep, jnp.int32), axis=-1))
            .astype(jnp.float32)))

        # layer-level: elementwise dropped-product matmul
        sxa, mxa = bp.to_sign_magnitude(xq.astype(jnp.int32))
        swa, mwa = bp.to_sign_magnitude(wq.astype(jnp.int32))
        prod_l = bp.from_sign_magnitude(
            (sxa[:, :, None] ^ swa[None, :, :]),
            bp.magnitude_product_from_irs(mxa[:, :, None], mwa[None, :, :],
                                          dropped))
        out = jnp.sum(prod_l, axis=1).astype(jnp.float32)
        if n_drop == 0:
            ref_out = out
        logit_rel_mse = float(jnp.mean((out - ref_out) ** 2)
                              / jnp.maximum(jnp.mean(ref_out ** 2), 1e-9))

        rows.append({
            "dropped_groups": n_drop,
            "is_paper_exact": n_drop == 0,
            "is_paper_approx": n_drop == 2,
            "max_abs_error": int(err.max()),
            "mean_rel_error": float(rel.mean()),
            "avg_cycles_bs0.65": cyc,
            "skipped_calc_frac": _skipped(xs, ws, n_drop),
            "layer_logit_rel_mse": logit_rel_mse,
        })

    paper = rows[2]
    next_one = rows[3]
    return {
        "rows": rows,
        "paper_choice_max_error": paper["max_abs_error"],          # 81
        "third_group_error_blowup": (next_one["max_abs_error"]
                                     / max(paper["max_abs_error"], 1)),
        "third_group_cycle_gain": (paper["avg_cycles_bs0.65"]
                                   - next_one["avg_cycles_bs0.65"]),
    }
