"""Section III-B4 (adapted): accuracy cost of the approximate MAC variant.

CIFAR-10 is unavailable offline (DESIGN.md §7), so the accuracy delta is
measured on two in-repo tasks with REAL trained weights:

  (a) an MLP classifier on a nontrivial synthetic vision-like task
      (anisotropic gaussian clusters + nuisance dims), trained in f32, then
      evaluated with W8A8 inference in bp_exact vs bp_approx modes;
  (b) a reduced qwen2 LM briefly trained on the synthetic pipeline,
      evaluated as next-token accuracy + cross-entropy in bf16 / bp_exact /
      bp_approx inference.

The paper's figure (93.8% -> 90.2% on ResNet18/CIFAR-10) is the calibration
reference: the qualitative claim under test is that the approx variant costs
a small, bounded accuracy delta while exact-int8 matches fp.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core.bp_matmul import dense_apply
from repro.data.pipeline import DataConfig, make_batch
from repro.models import api


# --------------------------- (a) MLP classifier ---------------------------

def _make_cluster_data(key, n, d=48, n_classes=10, nuisance=16):
    kc, kx, kr = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (n_classes, d)) * 3.0
    y = jax.random.randint(kx, (n,), 0, n_classes)
    scales = 0.5 + jax.random.uniform(kr, (n_classes, d))
    x = centers[y] + jax.random.normal(jax.random.fold_in(kx, 1),
                                       (n, d)) * scales[y]
    noise = jax.random.normal(jax.random.fold_in(kx, 2), (n, nuisance)) * 2.0
    feats = jnp.concatenate([x, noise], axis=1)
    return feats / 3.0, y


def _mlp_forward(params, x, mode):
    h = x
    for i, layer in enumerate(params):
        h = dense_apply(h, layer["w"], mode) + layer["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h


def _train_mlp(key, x, y, dims=(64, 128, 64, 10), steps=1200, lr=1e-2):
    ks = jax.random.split(key, len(dims) - 1)
    params = [{"w": jax.random.normal(k, (a, b)) * (a ** -0.5),
               "b": jnp.zeros((b,))}
              for k, a, b in zip(ks, dims[:-1], dims[1:])]

    def loss(p, xb, yb):
        logits = _mlp_forward(p, xb, "bf16")
        return jnp.mean(-jax.nn.log_softmax(logits)[jnp.arange(len(yb)), yb])

    @jax.jit
    def step(p, mom, xb, yb):
        g = jax.grad(loss)(p, xb, yb)
        mom = jax.tree.map(lambda m, gw: 0.9 * m + gw, mom, g)
        p = jax.tree.map(lambda w, m: w - lr * m, p, mom)
        return p, mom

    mom = jax.tree.map(jnp.zeros_like, params)
    rng = np.random.default_rng(0)
    for _ in range(steps):
        idx = rng.integers(0, x.shape[0], 256)
        params, mom = step(params, mom, x[idx], y[idx])
    return params


def _mlp_accuracy(params, x, y, mode):
    logits = _mlp_forward(params, x, mode)
    return float(jnp.mean(jnp.argmax(logits, -1) == y))


# --------------------------- (b) LM perplexity ----------------------------

def _lm_eval(cfg, params, batch):
    loss, metrics = api.loss_fn(params, cfg, batch)
    return float(metrics["ce_loss"])


def run(lm_steps: int = 60):
    key = jax.random.PRNGKey(0)
    x_all, y_all = _make_cluster_data(key, 8000)   # shared cluster centers
    x_tr, y_tr = x_all[:6000], y_all[:6000]
    x_te, y_te = x_all[6000:], y_all[6000:]
    mlp = _train_mlp(jax.random.fold_in(key, 1), x_tr, y_tr)
    acc = {m: _mlp_accuracy(mlp, x_te, y_te, m)
           for m in ("bf16", "bp_exact", "bp_approx")}

    # -- LM: brief training, then mode comparison --------------------------
    cfg = get_arch("qwen2-1.5b").reduced().replace(num_layers=2, d_model=128,
                                                   d_ff=256, vocab_size=512)
    params = api.init(jax.random.fold_in(key, 2), cfg)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=128, global_batch=8)

    from repro.train import optimizer as opt_lib
    ocfg = opt_lib.OptimizerConfig(peak_lr=3e-3, warmup_steps=10,
                                   total_steps=lm_steps)
    state = opt_lib.init_state(params)

    @jax.jit
    def train_step(p, s, batch):
        (loss, _), g = jax.value_and_grad(
            lambda pp: api.loss_fn(pp, cfg, batch), has_aux=True)(p)
        p, s, _ = opt_lib.apply_updates(ocfg, p, s, g)
        return p, s, loss

    first = last = None
    for i in range(lm_steps):
        b = make_batch(dc, i)
        params, state, loss = train_step(
            params, state, {k: jnp.asarray(v) for k, v in b.items()})
        if i == 0:
            first = float(loss)
        last = float(loss)

    eval_batch = {k: jnp.asarray(v) for k, v in make_batch(dc, 10_000).items()}
    ce = {}
    for m in ("bf16", "bp_exact", "bp_approx"):
        ce[m] = _lm_eval(cfg.replace(matmul_mode=m), params, eval_batch)

    return {
        "mlp_accuracy": acc,
        "mlp_acc_drop_exact_to_approx": acc["bp_exact"] - acc["bp_approx"],
        "mlp_acc_drop_fp_to_exact": acc["bf16"] - acc["bp_exact"],
        "lm_train_loss_first_last": [first, last],
        "lm_eval_ce": ce,
        "lm_ce_delta_exact_to_approx": ce["bp_approx"] - ce["bp_exact"],
        "paper_reference": {"resnet18_cifar10_exact": 0.938,
                            "resnet18_cifar10_approx": 0.902},
    }
