"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + finiteness, and prefill->decode consistency.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_arch
from repro.models import api

jax.config.update("jax_default_matmul_precision", "float32")


def _batch_for(cfg, B=2, S=64, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        kv, kp = jax.random.split(key)
        batch["vision_embeds"] = jax.random.normal(kv, (B, S, cfg.d_model),
                                                   jnp.bfloat16)
        mask = jnp.zeros((B, S), bool).at[:, :8].set(True)
        batch["vision_mask"] = mask
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        batch["positions"] = jnp.stack([pos, pos, pos])
    if cfg.family == "audio":
        batch["src_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 7), (B, S // 4, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_smoke(arch_id):
    cfg = get_arch(arch_id).reduced()
    params = api.init(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: api.loss_fn(pp, cfg, b), has_aux=True)(p)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return loss, metrics, gnorm

    loss, metrics, gnorm = step(params, batch)
    assert np.isfinite(float(loss)), arch_id
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, arch_id
    # random init: loss should be near log(vocab)
    assert 0.5 * np.log(cfg.vocab_size) < float(loss) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_consistency(arch_id):
    """prefill(S) + decode(token S) must equal forward(S+1) last logits."""
    cfg = get_arch(arch_id).reduced()
    if cfg.num_experts:
        # capacity drops differ between teacher-forced and decode paths (a
        # real property of dropped-token MoE) — disable drops for this check
        cfg = cfg.replace(capacity_factor=16.0)
    params = api.init(jax.random.PRNGKey(1), cfg)
    B, S = 2, 17
    full = _batch_for(cfg, B, S + 1, jax.random.PRNGKey(2))
    if cfg.family == "vlm":   # text-only continuation for the consistency run
        full.pop("vision_embeds"), full.pop("vision_mask"), full.pop("positions")
    prompt = {k: (v[:, :S] if k == "tokens" else v) for k, v in full.items()}
    cache_T = 32

    logits_p, cache = jax.jit(
        lambda p, b: api.prefill(p, cfg, b, cache_T))(params, prompt)
    step_batch = {"tokens": full["tokens"][:, S:S + 1], "cache": cache,
                  "cache_len": jnp.int32(S)}
    logits_d, _ = jax.jit(lambda p, b: api.decode_step(p, cfg, b))(
        params, step_batch)

    mod = api.module_for(cfg)
    if cfg.family == "audio":
        from repro.models import encdec
        enc = encdec.encode(params, cfg, full["src_embeds"])
        cks, cvs = encdec.cross_kv(params, cfg, enc)
        from repro.models.layers import rope_angles, embed
        x = embed(params["embed"], full["tokens"])
        pos = jnp.broadcast_to(jnp.arange(S + 1)[None], (B, S + 1))
        cos, sin = rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
        x, _ = encdec._decode_stack(params, cfg, x, cos, sin, cks, cvs)
        from repro.models.causal_lm import logits_from_hidden
        logits_f = logits_from_hidden(params, cfg, x[:, -1:, :])[:, 0]
    else:
        x, _, _ = mod.forward(params, cfg, full)
        from repro.models.causal_lm import logits_from_hidden
        logits_f = logits_from_hidden(params, cfg, x[:, -1:, :])[:, 0]

    np.testing.assert_allclose(np.asarray(logits_d, np.float32),
                               np.asarray(logits_f, np.float32),
                               atol=0.12, rtol=0.05)
    assert logits_p.shape == (B, cfg.vocab_padded)


def test_decode_loop_matches_parallel_forward():
    """Token-by-token decode equals teacher-forced forward (dense family)."""
    cfg = get_arch("qwen2-1.5b").reduced()
    params = api.init(jax.random.PRNGKey(3), cfg)
    B, S0, n_new = 1, 8, 4
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S0 + n_new), 0,
                                cfg.vocab_size)
    cache_T = 16
    _, cache = api.prefill(params, cfg, {"tokens": tokens[:, :S0]}, cache_T)
    decode = jax.jit(lambda p, b: api.decode_step(p, cfg, b))
    logits_steps = []
    for i in range(n_new):
        logits, cache = decode(params, {"tokens": tokens[:, S0 + i:S0 + i + 1],
                                        "cache": cache,
                                        "cache_len": jnp.int32(S0 + i)})
        logits_steps.append(logits)
    mod = api.module_for(cfg)
    x, _, _ = mod.forward(params, cfg, {"tokens": tokens})
    from repro.models.causal_lm import logits_from_hidden
    ref = logits_from_hidden(params, cfg, x)
    for i, got in enumerate(logits_steps):
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref[:, S0 + i], np.float32),
                                   atol=0.12, rtol=0.05)


def test_quantized_modes_run():
    cfg = get_arch("qwen2-1.5b").reduced().replace(matmul_mode="bp_exact")
    params = api.init(jax.random.PRNGKey(0), cfg)
    loss, _ = api.loss_fn(params, cfg, _batch_for(cfg, 2, 16))
    assert np.isfinite(float(loss))
    cfg_a = cfg.replace(matmul_mode="bp_approx")
    loss_a, _ = api.loss_fn(params, cfg_a, _batch_for(cfg_a, 2, 16))
    assert np.isfinite(float(loss_a))
    # approx and exact should be close but not necessarily identical
    assert abs(float(loss) - float(loss_a)) < 0.3
