"""Serving observability: golden JSONL schema, Chrome-trace validity,
telemetry-on/off token identity, and the report-equals-stream-reduction
invariant (ServeReport is a pure fold over the metrics records)."""

import dataclasses
import json

import numpy as np
import pytest
import jax

from repro.configs.base import get_arch
from repro.models import api
from repro.serving import (Request, SchedulerConfig, ServeConfig,
                           ServingEngine, Telemetry, percentiles,
                           read_jsonl, reduce_stream)
from repro.serving.telemetry import (NULL_SPAN, NULL_TELEMETRY, SCHEMA_VERSION,
                                     STEP_SCHEMA)

jax.config.update("jax_default_matmul_precision", "float32")


def _dense_cfg(**kw):
    return get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16, **kw)


def _engine(cfg, backend="slab", max_new=8, block_size=4, draft="none",
            telemetry=None, seed=0):
    params = api.init(jax.random.PRNGKey(seed), cfg)
    return ServingEngine(cfg, params, ServeConfig(
        max_new_tokens=max_new, temperature=0.0, cache_backend=backend,
        block_size=block_size, draft=draft, num_draft_tokens=3,
        telemetry=telemetry))


def _prompts(cfg, B, S, seed=1):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (B, S), 2,
                           cfg.vocab_size), np.int32)


def _spec_prompts(cfg, n, seed=1):
    """Repeated-phrase prompts so the prompt-lookup drafter has material."""
    phrase = _prompts(cfg, 1, 4, seed=seed)[0]
    out = []
    for i in range(n):
        uniq = _prompts(cfg, 1, 2, seed=seed + 10 + i)[0]
        out.append(np.concatenate([phrase, phrase, uniq, phrase]))
    return out


def _mixed_serve(tmp_path, telemetry=True):
    """The acceptance-criteria workload: paged backend, speculative
    decoding, and a pool sized to force preemption-and-replay."""
    cfg = _dense_cfg()
    prompts = _spec_prompts(cfg, 3, seed=3)
    tel = None
    if telemetry:
        tel = Telemetry(metrics_path=str(tmp_path / "metrics.jsonl"),
                        trace_path=str(tmp_path / "trace.json"))
    eng = _engine(cfg, backend="paged", draft="prompt_lookup", telemetry=tel)
    reqs = [Request(prompt=prompts[i], max_new_tokens=8, arrival_time=0.0)
            for i in range(3)]
    report = eng.serve(reqs, n_slots=3, cache_T=28, num_blocks=10,
                       sched_cfg=SchedulerConfig(lead_window=2))
    if tel is not None:
        tel.close()
    return report


# ---------------------------------------------------------------------------
# Golden schema: every emitted record carries its kind's required keys
# ---------------------------------------------------------------------------

class TestMetricsSchema:
    def test_mixed_stream_matches_golden_schema(self, tmp_path):
        report = _mixed_serve(tmp_path)
        records = read_jsonl(str(tmp_path / "metrics.jsonl"))
        assert records, "metrics sink wrote nothing"
        kinds = {r["kind"] for r in records}
        # run header + prefill + verify steps must appear; the forced-dry
        # pool must have produced preempt records too
        assert {"run", "prefill", "verify"} <= kinds
        assert report.n_preemptions > 0 and "preempt" in kinds
        for r in records:
            required = STEP_SCHEMA[r["kind"]]
            missing = required - set(r)
            assert not missing, (r["kind"], missing)
            assert r["schema"] == SCHEMA_VERSION

    def test_plain_decode_and_reject_records(self, tmp_path):
        cfg = _dense_cfg()
        tel = Telemetry(metrics_path=str(tmp_path / "m.jsonl"))
        eng = _engine(cfg, telemetry=tel)
        ok = Request(prompt=_prompts(cfg, 1, 4)[0], max_new_tokens=3)
        big = Request(prompt=_prompts(cfg, 1, 4)[0], max_new_tokens=64)
        report = eng.serve([ok, big], n_slots=2, cache_T=8)
        tel.close()
        records = read_jsonl(str(tmp_path / "m.jsonl"))
        kinds = {r["kind"] for r in records}
        assert {"run", "prefill", "decode", "reject"} <= kinds
        assert report.n_rejected == 1
        run = next(r for r in records if r["kind"] == "run")
        assert run["cache_backend"] == "slab" and run["draft"] == "none"
        for r in records:
            assert STEP_SCHEMA[r["kind"]] <= set(r)

    def test_decode_record_values_are_consistent(self, tmp_path):
        cfg = _dense_cfg()
        tel = Telemetry(metrics_path=str(tmp_path / "m.jsonl"))
        eng = _engine(cfg, telemetry=tel)
        reqs = [Request(prompt=_prompts(cfg, 2, 4)[i], max_new_tokens=4)
                for i in range(2)]
        eng.serve(reqs, n_slots=2, cache_T=16)
        tel.close()
        for r in read_jsonl(str(tmp_path / "m.jsonl")):
            if r["kind"] != "decode":
                continue
            assert 0 <= r["active_slots"] <= r["n_slots"]
            assert r["occupancy"] == r["active_slots"] / r["n_slots"]
            assert r["wall_s"] >= r["phases"]["dispatch_s"] >= 0
            assert r["committed_tokens"] >= 1
            assert r["h2d_bytes"] > 0     # step inputs cross to the device
            assert r["d2h_bytes"] > 0     # sampled tokens cross back


# ---------------------------------------------------------------------------
# Chrome-trace validity
# ---------------------------------------------------------------------------

class TestTraceFile:
    def test_trace_parses_and_spans_nest(self, tmp_path):
        _mixed_serve(tmp_path)
        with open(tmp_path / "trace.json") as f:
            doc = json.load(f)
        events = doc["traceEvents"]
        assert isinstance(events, list) and events
        assert any(e["ph"] == "M" and e["name"] == "process_name"
                   for e in events)
        spans = [e for e in events if e["ph"] == "X"]
        assert spans, "no complete spans recorded"
        names = {e["name"] for e in spans}
        assert {"serve", "prefill", "verify", "commit", "preempt"} <= names
        for e in spans:
            assert e["dur"] >= 0 and e["ts"] >= 0
            assert isinstance(e["pid"], int)
        # emission order is span-END order on one thread: end stamps must
        # be monotonic, and any two spans either nest or are disjoint
        ends = [e["ts"] + e["dur"] for e in spans]
        assert all(b >= a - 1e-6 for a, b in zip(ends, ends[1:]))
        for i, a in enumerate(spans):
            for b in spans[i + 1:]:
                a0, a1 = a["ts"], a["ts"] + a["dur"]
                b0, b1 = b["ts"], b["ts"] + b["dur"]
                overlap = min(a1, b1) - max(a0, b0)
                if overlap > 1e-6:          # they intersect: must nest
                    assert (a0 <= b0 and b1 <= a1) or \
                           (b0 <= a0 and a1 <= b1), (a, b)

    def test_instant_events_marked(self, tmp_path):
        _mixed_serve(tmp_path)
        with open(tmp_path / "trace.json") as f:
            events = json.load(f)["traceEvents"]
        instants = [e for e in events if e["ph"] == "i"]
        assert any(e["name"] == "admission_sync" for e in instants)
        for e in instants:
            assert e["s"] == "t"


# ---------------------------------------------------------------------------
# Token identity: sinks must never perturb outputs
# ---------------------------------------------------------------------------

class TestTokenIdentity:
    @pytest.mark.parametrize("backend", ["slab", "paged"])
    @pytest.mark.parametrize("draft", ["none", "prompt_lookup"])
    def test_on_off_identical(self, tmp_path, backend, draft):
        cfg = _dense_cfg()
        prompts = _spec_prompts(cfg, 3, seed=5)

        def serve(tel):
            eng = _engine(cfg, backend=backend, draft=draft, telemetry=tel)
            reqs = [Request(prompt=prompts[i], max_new_tokens=6,
                            arrival_time=float(i)) for i in range(3)]
            kw = dict(num_blocks=10) if backend == "paged" else {}
            return eng.serve(reqs, n_slots=3, cache_T=26,
                             sched_cfg=SchedulerConfig(lead_window=2), **kw)

        off = serve(None)
        on = serve(Telemetry(
            metrics_path=str(tmp_path / f"{backend}_{draft}.jsonl"),
            trace_path=str(tmp_path / f"{backend}_{draft}.json")))
        for a, b in zip(sorted(off.results, key=lambda r: r.request_id),
                        sorted(on.results, key=lambda r: r.request_id)):
            assert a.finish_reason == b.finish_reason
            np.testing.assert_array_equal(a.tokens, b.tokens)
        assert off.steps == on.steps
        assert off.total_new_tokens == on.total_new_tokens

    def test_mixed_preempting_workload_identical(self, tmp_path):
        off = _mixed_serve(tmp_path / "off", telemetry=False)
        on = _mixed_serve(tmp_path / "on", telemetry=True)
        assert on.n_preemptions == off.n_preemptions > 0
        for a, b in zip(sorted(off.results, key=lambda r: r.request_id),
                        sorted(on.results, key=lambda r: r.request_id)):
            np.testing.assert_array_equal(a.tokens, b.tokens)


# ---------------------------------------------------------------------------
# Report == stream reduction (byte-equal floats, not approx)
# ---------------------------------------------------------------------------

class TestReportReduction:
    def test_report_equals_reduction_of_written_jsonl(self, tmp_path):
        report = _mixed_serve(tmp_path)
        s = reduce_stream(read_jsonl(str(tmp_path / "metrics.jsonl")))
        # exact equality: the reduction re-folds the very floats the sink
        # serialized, and JSON round-trips binary64 exactly
        assert report.prefill_s == s.prefill_s
        assert report.decode_s == s.decode_s
        assert report.steps == s.steps
        assert report.n_syncs == s.n_syncs
        assert report.n_rejected == s.n_rejected
        assert report.total_new_tokens == s.total_new_tokens
        assert report.slot_utilization == s.slot_utilization
        assert report.committed_tokens_per_step == s.committed_tokens_per_step
        assert report.max_divergence == s.max_divergence
        assert report.n_preemptions == s.n_preemptions
        assert report.drafted_tokens == s.drafted_tokens
        assert report.accepted_tokens == s.accepted_tokens
        assert report.peak_active_slots == s.peak_active_slots
        assert report.prefix_hit_blocks == s.prefix_hit_blocks
        assert report.cow_blocks == s.cow_blocks
        assert report.peak_blocks_in_use == s.peak_blocks_in_use

    def test_stream_values_are_plain_json_scalars(self, tmp_path):
        _mixed_serve(tmp_path)
        text = (tmp_path / "metrics.jsonl").read_text()
        for line in text.splitlines():
            rec = json.loads(line)
            assert json.dumps(rec)      # round-trips without default= hooks


# ---------------------------------------------------------------------------
# Disabled handle: strict no-op, no allocation in the hot path
# ---------------------------------------------------------------------------

class TestContextManager:
    def test_metrics_logger_flushes_on_exception(self, tmp_path):
        from repro.serving import MetricsLogger
        path = str(tmp_path / "m.jsonl")
        with pytest.raises(RuntimeError, match="boom"):
            with MetricsLogger(path) as sink:
                sink.log({"kind": "x", "v": 1})
                raise RuntimeError("boom")
        # the record written before the crash is durable and parseable
        assert read_jsonl(path) == [{"kind": "x", "v": 1}]

    def test_killed_serve_leaves_parseable_stream(self, tmp_path):
        """Kill a serve mid-step; the telemetry context manager must
        flush/close the sinks so every record written so far re-parses."""
        cfg = _dense_cfg()

        class Boom(Exception):
            pass

        def kill(loop):
            if loop.sched.n_decode_steps >= 2:
                raise Boom()

        path = tmp_path / "metrics.jsonl"
        with pytest.raises(Boom):
            with Telemetry(metrics_path=str(path)) as tel:
                eng = _engine(cfg, telemetry=tel)
                reqs = [Request(prompt=p, max_new_tokens=8,
                                arrival_time=0.0)
                        for p in _prompts(cfg, 3, 5)]
                loop = eng.make_loop(reqs, n_slots=2)
                loop.on_step_end = kill
                loop.run()
        records = read_jsonl(str(path))
        assert records, "no records survived the mid-serve kill"
        for r in records:
            assert STEP_SCHEMA[r["kind"]] <= set(r)
        # the partial stream still reduces (crash-forensics entry point)
        s = reduce_stream(records)
        assert s.steps >= 2


class TestDisabledTelemetry:
    def test_null_span_is_shared_singleton(self):
        tel = Telemetry()
        assert not tel.enabled
        assert tel.span("decode") is NULL_SPAN
        assert tel.span("anything", slot=3) is NULL_SPAN
        assert NULL_TELEMETRY.span("x") is NULL_SPAN
        with tel.span("decode"):
            pass                        # usable as a context manager

    def test_disabled_emit_and_flush_write_nothing(self, tmp_path):
        tel = Telemetry()
        tel.emit({"kind": "decode"})
        tel.instant("x")
        tel.flush()
        tel.close()
        assert list(tmp_path.iterdir()) == []

    def test_counters_accumulate_even_when_disabled(self):
        tel = Telemetry()
        tel.count("h2d_bytes", 128)
        tel.count("h2d_bytes", np.int64(64))
        assert tel.counters["h2d_bytes"] == 192


class TestPercentilesHelper:
    def test_empty_and_none_filtered(self):
        assert percentiles([]) is None
        assert percentiles([None, None]) is None

    def test_values(self):
        p = percentiles(list(range(1, 101)))
        assert set(p) == {"p50", "p90", "p99"}
        assert p["p50"] == pytest.approx(50.5)
        assert p["p50"] <= p["p90"] <= p["p99"]

    def test_custom_qs(self):
        p = percentiles([1.0, 2.0, None, 3.0], qs=(0, 100))
        assert p == {"p0": 1.0, "p100": 3.0}
