"""Quantized-matmul backend dispatch: the fused Pallas kernel (interpret mode
on CPU) against the pure-XLA oracle, from the single contraction up to the
full serving engine, plus the engine's construction-time weight
pre-quantization fast path."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core import bp_matmul, quant
from repro.models import api
from repro.models.layers import quantize_dense_params
from repro.serving import Request, ServeConfig, ServingEngine

jax.config.update("jax_default_matmul_precision", "float32")


def _cfg(backend="auto", mode="bp_exact"):
    return get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16,
        matmul_mode=mode, matmul_backend=backend)


def _prompts(cfg, B, S, seed=1):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (B, S), 2,
                           cfg.vocab_size), np.int32)


# ---------------------------------------------------------------------------
# Dispatch mechanics
# ---------------------------------------------------------------------------

def test_backend_resolution_and_scoping():
    assert bp_matmul.resolve_matmul_backend("xla") == "xla"
    assert bp_matmul.resolve_matmul_backend("kernel") == "kernel"
    # auto picks the kernel only on TPU; everywhere else the XLA oracle
    expect = "kernel" if jax.default_backend() == "tpu" else "xla"
    assert bp_matmul.resolve_matmul_backend("auto") == expect
    prev = bp_matmul.get_matmul_backend()
    with bp_matmul.use_matmul_backend("kernel_interpret"):
        assert bp_matmul.get_matmul_backend() == "kernel_interpret"
    assert bp_matmul.get_matmul_backend() == prev
    with pytest.raises(ValueError):
        bp_matmul.set_matmul_backend("cuda")


# ---------------------------------------------------------------------------
# Kernel vs XLA-oracle parity (non-block-aligned shapes, both modes)
# ---------------------------------------------------------------------------

RAGGED_SHAPES = [
    (5, 33, 17),     # everything ragged (padding path)
    (1, 130, 129),   # one past a block edge in K and N
    (24, 96, 40),    # aligned M, ragged N
]


@pytest.mark.parametrize("mode", ["bp_exact", "bp_approx"])
@pytest.mark.parametrize("m,k,n", RAGGED_SHAPES)
def test_quantized_matmul_kernel_matches_xla(m, k, n, mode):
    key = jax.random.PRNGKey(hash((m, k, n, mode)) % 2**31)
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (k, n), jnp.float32)
    w_q, w_scale = quant.quantize_per_channel(w, channel_axis=-1)
    w_scale = w_scale.reshape(-1)
    with bp_matmul.use_matmul_backend("xla"):
        want = bp_matmul.quantized_matmul(x, w_q, w_scale, mode)
    with bp_matmul.use_matmul_backend("kernel_interpret"):
        got = bp_matmul.quantized_matmul(x, w_q, w_scale, mode)
    # integer accumulators are identical; only the dequant-epilogue multiply
    # order differs, so agreement is to f32 rounding
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_quantized_matmul_kernel_leading_batch_dims():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (2, 3, 40), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (40, 9), jnp.float32)
    w_q, w_scale = quant.quantize_per_channel(w, channel_axis=-1)
    w_scale = w_scale.reshape(-1)
    with bp_matmul.use_matmul_backend("xla"):
        want = bp_matmul.quantized_matmul(x, w_q, w_scale, "bp_exact")
    with bp_matmul.use_matmul_backend("kernel_interpret"):
        got = bp_matmul.quantized_matmul(x, w_q, w_scale, "bp_exact")
    assert got.shape == (2, 3, 9)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Engine fast path: construction-time weight pre-quantization
# ---------------------------------------------------------------------------

def test_engine_prequantizes_weights_once():
    cfg = _cfg(backend="xla")
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, ServeConfig(max_new_tokens=4))

    def assert_int8_dense(node):
        if isinstance(node, dict):
            w = node.get("w")
            if w is not None and getattr(w, "ndim", 0) >= 2:
                assert w.dtype == jnp.int8, "dense kernel left in float"
                assert "w_scale" in node
            for v in node.values():
                assert_int8_dense(v)

    assert_int8_dense(engine.params)
    # deployment estimates come for free now that weights are int8-resident
    assert engine.deployment_estimate(n_mc=500) is not None

    # greedy outputs identical to an engine fed pre-quantized params
    # explicitly (construction-time quantization is the same transform)
    engine2 = ServingEngine(cfg, quantize_dense_params(params),
                            ServeConfig(max_new_tokens=4))
    prompts = _prompts(cfg, 2, 6)
    g1 = engine.generate({"tokens": jnp.asarray(prompts)})
    g2 = engine2.generate({"tokens": jnp.asarray(prompts)})
    np.testing.assert_array_equal(g1.tokens, g2.tokens)


def test_bf16_engine_params_left_untouched():
    cfg = _cfg(backend="xla", mode="bf16").replace(matmul_mode="bf16")
    params = api.init(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, ServeConfig(max_new_tokens=2))
    assert engine.params is params
    assert engine.deployment_estimate() is None


# ---------------------------------------------------------------------------
# End-to-end: serve() with the kernel backend forced vs the XLA backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["bp_exact", "bp_approx"])
def test_serve_kernel_backend_matches_xla(mode):
    params = api.init(jax.random.PRNGKey(0), _cfg(mode=mode))
    prompts = _prompts(_cfg(mode=mode), 3, 6)
    max_news = [5, 3, 5]
    outputs, logits = {}, {}
    for backend in ("xla", "kernel_interpret"):
        cfg = _cfg(backend=backend, mode=mode)
        engine = ServingEngine(cfg, params, ServeConfig(max_new_tokens=5))
        reqs = [Request(prompt=prompts[i], max_new_tokens=max_news[i],
                        arrival_time=float(i)) for i in range(3)]
        report = engine.serve(reqs, n_slots=2)
        outputs[backend] = [list(r.tokens) for r in
                            sorted(report.results,
                                   key=lambda r: r.request_id)]
        lg, _ = engine.executor.prefill({"tokens": jnp.asarray(prompts)}, 16)
        logits[backend] = np.asarray(lg, np.float32)
    # greedy-token-identical at fp32 matmul precision, logits close
    assert outputs["xla"] == outputs["kernel_interpret"]
    np.testing.assert_allclose(logits["kernel_interpret"], logits["xla"],
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Device-resident static decode loop
# ---------------------------------------------------------------------------

def test_generate_chunk_size_invariant():
    cfg = _cfg(backend="xla")
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, 2, 5)
    outs = []
    for chunk in (1, 3, 8):
        engine = ServingEngine(cfg, params,
                               ServeConfig(max_new_tokens=7,
                                           decode_chunk=chunk))
        outs.append(engine.generate({"tokens": jnp.asarray(prompts)}).tokens)
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])


def test_generate_temperature_chunk_invariant():
    cfg = _cfg(backend="xla")
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, 2, 5)
    outs = []
    for chunk in (1, 4):
        engine = ServingEngine(cfg, params,
                               ServeConfig(max_new_tokens=6, temperature=0.7,
                                           decode_chunk=chunk))
        outs.append(engine.generate({"tokens": jnp.asarray(prompts)},
                                    key=jax.random.PRNGKey(9)).tokens)
    # the PRNG fold sequence is indexed by the global step, so sampled
    # trajectories cannot depend on how steps are chunked into scans
    np.testing.assert_array_equal(outs[0], outs[1])


def test_generate_eos_truncation_matches_per_token_loop():
    cfg = _cfg(backend="xla", mode="bf16").replace(matmul_mode="bf16")
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg, 1, 5)
    probe = ServingEngine(cfg, params, ServeConfig(max_new_tokens=8))
    ref = np.asarray(probe.generate({"tokens": jnp.asarray(prompts)}).tokens)
    # pick the token emitted at step 2 as EOS: generation must stop there
    # even though the chunk would have carried on to step 7
    eos = int(ref[0, 2])
    stop = int(np.argmax(ref[0] == eos))
    engine = ServingEngine(cfg, params,
                           ServeConfig(max_new_tokens=8, eos_id=eos,
                                       decode_chunk=8))
    out = engine.generate({"tokens": jnp.asarray(prompts)})
    assert out.tokens.shape[1] == stop + 1
    np.testing.assert_array_equal(out.tokens[0], ref[0, :stop + 1])
    assert out.steps == stop + 1
