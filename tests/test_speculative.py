"""Speculative decoding subsystem: multi-token verify correctness, drafter
behavior, greedy token-identity across drafters x cache backends x matmul
modes (the headline property), paged rollback, and the accounting
satellites (committed-token throughput, acceptance counters, wall-clock
latency percentiles)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models import api
from repro.models.layers import quantize_dense_params
from repro.serving import (ModelDrafter, PromptLookupDrafter, Request,
                           ServeConfig, ServingEngine, make_drafter)

jax.config.update("jax_default_matmul_precision", "float32")


def _dense_cfg(**kw):
    base = dict(num_layers=2, d_model=64, d_ff=128, vocab_size=128,
                head_dim=16)
    base.update(kw)
    return get_arch("qwen2-1.5b").reduced().replace(**base)


def _prompts(cfg, B, S, seed=1):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (B, S), 2,
                           cfg.vocab_size), np.int32)


_PARAMS = {}


def _params(cfg, seed=0):
    key = (cfg, seed)
    if key not in _PARAMS:
        _PARAMS[key] = api.init(jax.random.PRNGKey(seed), cfg)
    return _PARAMS[key]


def _tokens_sorted(report):
    return [r.tokens for r in sorted(report.results,
                                     key=lambda r: r.request_id)]


# ---------------------------------------------------------------------------
# verify_step: one multi-token pass == K+1 sequential decode steps
# ---------------------------------------------------------------------------

class TestVerifyStep:
    @pytest.mark.parametrize("int8kv", [False, True])
    def test_slab_verify_matches_sequential_decode(self, int8kv):
        cfg = _dense_cfg(kv_cache_int8=int8kv)
        params = _params(cfg)
        B, S, T, K = 2, 5, 16, 3
        toks = _prompts(cfg, B, S)
        _, cache_seq = api.prefill(params, cfg, {"tokens": jnp.asarray(toks)},
                                   T)
        cache_ver = jax.tree.map(jnp.copy, cache_seq)
        feed = _prompts(cfg, B, K + 1, seed=7)
        # per-slot depths diverge: slot 1 sits one position deeper
        base_len = np.asarray([S, S], np.int32)
        seq_logits = []
        cache_len = base_len.copy()
        for j in range(K + 1):
            lg, cache_seq = api.decode_step(
                params, cfg, {"tokens": jnp.asarray(feed[:, j:j + 1]),
                              "cache": cache_seq,
                              "cache_len": jnp.asarray(cache_len)})
            seq_logits.append(np.asarray(lg))
            cache_len += 1
        ver_logits, cache_ver = api.verify_step(
            params, cfg, {"tokens": jnp.asarray(feed), "cache": cache_ver,
                          "cache_len": jnp.asarray(base_len)})
        ver_logits = np.asarray(ver_logits)
        for j in range(K + 1):
            np.testing.assert_allclose(ver_logits[:, j], seq_logits[j],
                                       rtol=2e-4, atol=2e-4)
        for a, b in zip(jax.tree.leaves(cache_ver),
                        jax.tree.leaves(cache_seq)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)

    def test_paged_verify_matches_slab_verify(self):
        cfg = _dense_cfg()
        params = _params(cfg)
        from repro.serving import PagedCacheManager
        B, S, K, bs = 2, 6, 3, 4
        cm = PagedCacheManager(cfg, n_slots=B, cache_T=16, block_size=bs,
                               num_blocks=24)
        toks = _prompts(cfg, B, S)
        _, src = api.prefill(params, cfg, {"tokens": jnp.asarray(toks)},
                             cm.prefill_T)
        slab_cache = jax.tree.map(jnp.copy, src)
        for i in range(B):
            cm.insert(cm.alloc(), src, S, src_index=i,
                      tokens=toks[i].tolist())
        feed = _prompts(cfg, B, K + 1, seed=9)
        lens = np.asarray([S, S], np.int32)
        assert cm.prepare_append([0, 1], [K + 1, K + 1]) is None
        paged_logits, _ = api.verify_step_paged(
            params, cfg, {"tokens": jnp.asarray(feed), "cache": cm.pages,
                          "cache_len": jnp.asarray(lens),
                          "block_tables": jnp.asarray(cm.tables)})
        slab_logits, _ = api.verify_step(
            params, cfg, {"tokens": jnp.asarray(feed), "cache": slab_cache,
                          "cache_len": jnp.asarray(lens)})
        np.testing.assert_allclose(np.asarray(paged_logits),
                                   np.asarray(slab_logits),
                                   rtol=2e-4, atol=2e-4)

    def test_write_kv_multi_row_overrun_drops_not_clamps(self):
        """A speculative tail past the cache capacity must be DROPPED, not
        clamped: dynamic_update_slice semantics would shift the window
        backward and corrupt committed K/V (regression, both cache_len
        forms)."""
        from repro.models import attention
        cache = jnp.arange(2 * 8 * 3, dtype=jnp.float32).reshape(2, 8, 3)
        new = -jnp.ones((2, 4, 3), jnp.float32)
        for cl in (jnp.int32(6), jnp.asarray([6, 6], jnp.int32)):
            out = np.asarray(attention.write_kv(cache, new, cl))
            np.testing.assert_array_equal(out[:, :6], np.asarray(cache)[:, :6])
            np.testing.assert_array_equal(out[:, 6:], -1.0)

    def test_recurrent_family_rejected(self):
        cfg = get_arch("rwkv6-7b").reduced().replace(
            num_layers=2, d_model=64, d_ff=128, vocab_size=128)
        assert not api.supports_verify(cfg)
        with pytest.raises(ValueError, match="verify"):
            api.verify_step(_params(cfg), cfg, {})
        # and the serving layer fails FAST, at loop construction
        engine = ServingEngine(cfg, _params(cfg),
                               ServeConfig(draft="prompt_lookup"))
        with pytest.raises(ValueError, match="verify"):
            engine.make_loop([Request(prompt=np.arange(2, 6),
                                      max_new_tokens=2)], n_slots=1)


# ---------------------------------------------------------------------------
# Drafters
# ---------------------------------------------------------------------------

class TestPromptLookup:
    def test_rightmost_ngram_match_proposes_continuation(self):
        d = PromptLookupDrafter(4, max_ngram=2, min_ngram=1)
        ctx = np.asarray([5, 6, 7, 8, 9, 5, 6], np.int64)
        # suffix (5, 6) re-occurs at position 0 -> propose what followed
        np.testing.assert_array_equal(d._lookup(ctx, 4), [7, 8, 9, 5])

    def test_prefers_longest_then_most_recent_match(self):
        d = PromptLookupDrafter(4, max_ngram=3, min_ngram=1)
        ctx = np.asarray([1, 2, 3, 9, 1, 2, 4, 1, 2], np.int64)
        # bigram (1, 2) matches at 0 and 4; rightmost (4) wins -> 4 follows
        np.testing.assert_array_equal(d._lookup(ctx, 2), [4, 1])

    def test_no_match_returns_empty(self):
        d = PromptLookupDrafter(4)
        assert d._lookup(np.asarray([1, 2, 3, 4], np.int64), 4).size == 0

    def test_propose_all_respects_caps(self):
        d = PromptLookupDrafter(4, max_ngram=1)
        req = Request(prompt=np.asarray([3, 4, 3, 4, 3], np.int32),
                      max_new_tokens=8)
        req.tokens = [4]
        out = d.propose_all({0: req}, {0: 2})
        assert len(out[0]) <= 2


class TestModelDrafter:
    def test_vocab_and_family_mismatch_rejected(self):
        cfg = _dense_cfg()
        params = _params(cfg)
        other = _dense_cfg(vocab_size=256)
        with pytest.raises(ValueError, match="vocab"):
            ServingEngine(cfg, params,
                          ServeConfig(draft="model", num_draft_tokens=2),
                          draft_cfg=other,
                          draft_params=_params(other)).serve(
                [Request(prompt=_prompts(cfg, 1, 4)[0], max_new_tokens=2)],
                n_slots=1)

    def test_draft_cache_tracks_target_positions(self):
        cfg = _dense_cfg()
        params = _params(cfg)
        engine = ServingEngine(cfg, params,
                               ServeConfig(max_new_tokens=6, draft="model",
                                           num_draft_tokens=2),
                               draft_cfg=cfg, draft_params=params)
        reqs = [Request(prompt=_prompts(cfg, 2, 5)[i], max_new_tokens=6)
                for i in range(2)]
        loop = engine.make_loop(reqs, n_slots=2)
        loop.submit_arrivals()
        for group in loop.sched.plan_admissions():
            loop.admit(group)
        for _ in range(2):
            loop.decode_once_spec()
            for slot in loop.active:
                # invariant: the draft cache covers exactly the committed
                # context (everything but the unfed last token)
                assert (loop.drafter.cm.lengths[slot]
                        == loop.cm.lengths[slot])

    def test_greedy_only(self):
        cfg = _dense_cfg()
        engine = ServingEngine(cfg, _params(cfg),
                               ServeConfig(temperature=0.5,
                                           draft="prompt_lookup"))
        with pytest.raises(ValueError, match="greedy"):
            engine.serve([Request(prompt=_prompts(cfg, 1, 4)[0],
                                  max_new_tokens=2)], n_slots=1)

    def test_unknown_drafter_rejected(self):
        cfg = _dense_cfg()
        engine = ServingEngine(cfg, _params(cfg), ServeConfig(draft="wat"))
        with pytest.raises(ValueError, match="unknown draft"):
            engine.serve([Request(prompt=_prompts(cfg, 1, 4)[0],
                                  max_new_tokens=2)], n_slots=1)


# ---------------------------------------------------------------------------
# Token identity: THE acceptance bar
# ---------------------------------------------------------------------------

def _spec_engine(cfg, params, *, draft, backend, K=3, block_size=4,
                 draft_cfg=None, draft_params=None):
    if draft == "model" and draft_cfg is None:
        draft_cfg, draft_params = cfg, params   # self-draft: acceptance ~1
    return ServingEngine(cfg, params,
                         ServeConfig(max_new_tokens=8, temperature=0.0,
                                     cache_backend=backend,
                                     block_size=block_size, draft=draft,
                                     num_draft_tokens=K),
                         draft_cfg=draft_cfg, draft_params=draft_params)


class TestTokenIdentity:
    @pytest.mark.parametrize("draft", ["prompt_lookup", "model"])
    @pytest.mark.parametrize("backend", ["slab", "paged"])
    def test_staggered_hetero_stream_matches_baseline(self, draft, backend):
        cfg = _dense_cfg()
        params = _params(cfg)
        prompts = _prompts(cfg, 5, 6)
        max_news = [8, 3, 8, 5, 1]

        def reqs():
            return [Request(prompt=prompts[i], max_new_tokens=max_news[i],
                            arrival_time=float(i)) for i in range(5)]

        base = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=8, cache_backend=backend,
            block_size=4)).serve(reqs(), n_slots=2)
        spec = _spec_engine(cfg, params, draft=draft,
                            backend=backend).serve(reqs(), n_slots=2)
        for a, b in zip(_tokens_sorted(base), _tokens_sorted(spec)):
            np.testing.assert_array_equal(a, b)
        if draft == "model":
            # self-draft: every draft is the target's own argmax stream
            assert spec.acceptance_rate > 0.9
            assert spec.steps < base.steps
            assert spec.committed_tokens_per_step > 1.0

    @pytest.mark.parametrize("mode", ["bp_exact", "bp_approx"])
    def test_quantized_modes_match_baseline(self, mode):
        cfg = _dense_cfg().replace(matmul_mode=mode, kv_cache_int8=True)
        params = quantize_dense_params(_params(_dense_cfg()))
        prompts = _prompts(cfg, 3, 6)

        def reqs():
            return [Request(prompt=prompts[i], max_new_tokens=6,
                            arrival_time=float(i)) for i in range(3)]

        base = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=6)).serve(reqs(), n_slots=2)
        spec = _spec_engine(cfg, params, draft="model",
                            backend="slab").serve(reqs(), n_slots=2)
        for a, b in zip(_tokens_sorted(base), _tokens_sorted(spec)):
            np.testing.assert_array_equal(a, b)
        assert spec.steps < base.steps

    def test_tiny_paged_pool_preemption_replay_matches(self):
        cfg = _dense_cfg()
        params = _params(cfg)
        rng = np.random.default_rng(0)
        prompts = [np.asarray(rng.integers(2, 128, size=8), np.int32)
                   for _ in range(3)]

        def reqs():
            return [Request(prompt=p, max_new_tokens=8, arrival_time=0.0)
                    for p in prompts]

        base = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=8)).serve(reqs(), n_slots=3, cache_T=24)
        spec = _spec_engine(cfg, params, draft="model",
                            backend="paged").serve(reqs(), n_slots=3,
                                                   cache_T=24, num_blocks=9)
        assert spec.n_preemptions > 0   # the pool is genuinely too small
        for a, b in zip(_tokens_sorted(base), _tokens_sorted(spec)):
            np.testing.assert_array_equal(a, b)

    def test_eos_mid_commit_stops_exactly(self):
        cfg = _dense_cfg()
        params = _params(cfg)
        prompts = _prompts(cfg, 2, 5)
        # run greedy once to find a token that actually appears, use it as
        # EOS so speculation commits across an EOS boundary
        probe = ServingEngine(cfg, params, ServeConfig(max_new_tokens=8))
        out = probe.serve([Request(prompt=prompts[0], max_new_tokens=8)],
                          n_slots=1)
        stream = _tokens_sorted(out)[0]
        eos = int(stream[min(2, len(stream) - 1)])

        def reqs():
            return [Request(prompt=prompts[i], max_new_tokens=8)
                    for i in range(2)]

        base = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=8, eos_id=eos)).serve(reqs(), n_slots=2)
        spec = _spec_engine(cfg, params, draft="model", backend="slab")
        spec.serve_cfg.eos_id = eos
        rep = spec.serve(reqs(), n_slots=2)
        for a, b in zip(_tokens_sorted(base), _tokens_sorted(rep)):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# Paged rollback
# ---------------------------------------------------------------------------

class TestPagedRollback:
    def test_release_tail_frees_private_draft_blocks(self):
        from repro.serving import PagedCacheManager
        cfg = _dense_cfg(d_model=32, d_ff=64, vocab_size=64, head_dim=8,
                         num_heads=2, num_kv_heads=1)
        cm = PagedCacheManager(cfg, n_slots=2, cache_T=16, block_size=4,
                               num_blocks=16)
        specs = api.cache_specs(cfg, 1, cm.prefill_T)
        src = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)
        slot = cm.alloc()
        cm.insert(slot, src, 5, tokens=list(range(2, 7)))
        live0 = cm.pool.n_live
        # speculative span of 4 tokens from position 5 needs blocks 1..2
        assert cm.prepare_append([slot], [4]) is None
        assert cm.pool.n_live > live0
        cm.advance([slot], [1])             # only 1 token committed (pos 5)
        cm.release_tail(slot)
        assert cm.pool.n_live == live0      # draft-span blocks returned
        assert int(cm._n_blocks_of[slot]) == 2  # ceil(6 / 4)

    def test_release_tail_never_touches_shared_blocks(self):
        from repro.serving import PagedCacheManager
        cfg = _dense_cfg(d_model=32, d_ff=64, vocab_size=64, head_dim=8,
                         num_heads=2, num_kv_heads=1)
        cm = PagedCacheManager(cfg, n_slots=2, cache_T=16, block_size=4,
                               num_blocks=16)
        specs = api.cache_specs(cfg, 1, cm.prefill_T)
        src = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs)
        prompt = list(range(2, 10))         # 2 full shared blocks
        sa, sb = cm.alloc(), cm.alloc()
        cm.insert(sa, src, 8, tokens=prompt)
        cm.insert(sb, src, 8, tokens=prompt)
        shared = [int(b) for b in cm.tables[sb, :2]]
        assert shared == [int(b) for b in cm.tables[sa, :2]]
        before = [np.asarray(cm.pages["k"][:, b]).copy() for b in shared]
        # speculative append + full rejection on slot b
        assert cm.prepare_append([sb], [5]) is None
        cm.advance([sb], [1])
        cm.release_tail(sb)
        # the shared prefix blocks are still shared and bit-identical
        assert [int(b) for b in cm.tables[sb, :2]] == shared
        for b, want in zip(shared, before):
            np.testing.assert_array_equal(np.asarray(cm.pages["k"][:, b]),
                                          want)
        assert cm.pool.refcount[shared[0]] == 2

    def test_serve_leaves_no_live_blocks(self):
        cfg = _dense_cfg()
        params = _params(cfg)
        engine = _spec_engine(cfg, params, draft="model", backend="paged")
        reqs = [Request(prompt=_prompts(cfg, 3, 6)[i], max_new_tokens=6,
                        arrival_time=float(i)) for i in range(3)]
        loop = engine.make_loop(reqs, n_slots=2)
        loop.run()
        assert loop.cm.pool.n_live == 0     # nothing leaked


# ---------------------------------------------------------------------------
# Executor contract
# ---------------------------------------------------------------------------

class TestVerifyExecutor:
    def test_verify_step_aliases_cache_in_hlo(self):
        """The verify dispatch keeps the decode step's donation contract:
        every cache leaf aliases an output (no second cache-sized copy per
        speculative step)."""
        cfg = _dense_cfg()
        engine = ServingEngine(cfg, _params(cfg), ServeConfig(
            max_new_tokens=4, draft="prompt_lookup", num_draft_tokens=3))
        cache = engine.executor.zeros_cache(4, 64)
        step = {"tokens": jnp.zeros((4, 4), jnp.int32),
                "cache_len": jnp.zeros((4,), jnp.int32)}
        fn = engine.executor.verify_sample_fn()
        lowered = fn.lower(cache, step)
        n_aliased = lowered.as_text().count("tf.aliasing_output")
        assert n_aliased >= len(jax.tree.leaves(cache))

    def test_verify_returns_token_grid_only(self):
        cfg = _dense_cfg()
        engine = ServingEngine(cfg, _params(cfg), ServeConfig(
            max_new_tokens=4, draft="prompt_lookup", num_draft_tokens=3))
        cache = engine.executor.zeros_cache(2, 32)
        step = {"tokens": jnp.zeros((2, 4), jnp.int32),
                "cache_len": jnp.asarray([5, 7], jnp.int32)}
        toks, new_cache = engine.executor.verify_sample_fn()(cache, step)
        assert toks.shape == (2, 4) and toks.dtype == jnp.int32
        assert jax.tree.structure(new_cache) == jax.tree.structure(
            api.cache_specs(cfg, 2, 32))


# ---------------------------------------------------------------------------
# Accounting satellites
# ---------------------------------------------------------------------------

class TestAccounting:
    def test_committed_tokens_and_wall_percentiles(self):
        cfg = _dense_cfg()
        params = _params(cfg)
        engine = _spec_engine(cfg, params, draft="model", backend="slab")
        reqs = [Request(prompt=_prompts(cfg, 3, 6)[i], max_new_tokens=8)
                for i in range(3)]
        rep = engine.serve(reqs, n_slots=3)
        total = sum(len(r.tokens) for r in rep.results)
        assert rep.total_new_tokens == total
        # committed-token accounting: steps * committed/step == decode-side
        # commits (total minus the per-request prefill token)
        decode_commits = total - len(reqs)
        assert rep.steps * rep.committed_tokens_per_step == pytest.approx(
            decode_commits)
        assert rep.accepted_tokens <= rep.drafted_tokens
        assert 0.0 <= rep.acceptance_rate <= 1.0
        assert rep.draft == "model"
        for key in ("p50", "p90", "p99"):
            assert rep.ttft_wall[key] >= 0.0
            assert rep.itl_wall[key] >= 0.0
        assert rep.ttft_wall["p50"] <= rep.ttft_wall["p99"]
        for r in rep.results:
            assert r.ttft_wall_s is not None and r.ttft_wall_s >= 0.0

    def test_decode_tokens_per_s_single_rule(self):
        # the two paths share one tokens/s implementation
        from repro.serving.engine import tokens_per_second
        assert tokens_per_second(10, 2.0) == pytest.approx(5.0)
        assert tokens_per_second(10, 2.0, steps=5) == pytest.approx(5.0)
        # steps == 0: report over total wall time, not a blind 0
        assert tokens_per_second(4, 0.0, prefill_s=2.0,
                                 steps=0) == pytest.approx(2.0)

    def test_classic_path_accounting_unchanged(self):
        cfg = _dense_cfg()
        params = _params(cfg)
        engine = ServingEngine(cfg, params, ServeConfig(max_new_tokens=5))
        rep = engine.serve([Request(prompt=_prompts(cfg, 1, 5)[0],
                                    max_new_tokens=5)], n_slots=1)
        assert rep.draft == "none"
        assert rep.drafted_tokens == 0 and rep.acceptance_rate == 0.0
        assert rep.committed_tokens_per_step == pytest.approx(1.0)
