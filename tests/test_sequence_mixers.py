"""Equivalence tests: chunked-parallel vs step-recurrent sequence mixers,
flash vs direct attention, MoE dispatch vs dense loop oracle."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_arch
from repro.models import attention, moe, rwkv6, mamba2

jax.config.update("jax_default_matmul_precision", "float32")


class TestRWKV6:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_chunked_equals_sequential(self, seed):
        key = jax.random.PRNGKey(seed)
        B, S, H, N = 2, 128, 3, 16
        ks = jax.random.split(key, 6)
        r, k, v = (jax.random.normal(ks[i], (B, S, H, N)) for i in range(3))
        log_w = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)) - 1.0)
        u = jax.random.normal(ks[4], (H, N))
        state = jax.random.normal(ks[5], (B, H, N, N))
        out_c, st_c = rwkv6.wkv_chunked(r, k, v, log_w, u, state, chunk=32)
        out_s, st_s = rwkv6.wkv_sequential(r, k, v, log_w, u, state)
        np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_s),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(st_c), np.asarray(st_s),
                                   atol=1e-4, rtol=1e-4)

    def test_state_streaming_equivalence(self):
        # processing [0:64] then [64:128] == processing [0:128]
        key = jax.random.PRNGKey(0)
        B, S, H, N = 1, 128, 2, 8
        ks = jax.random.split(key, 5)
        r, k, v = (jax.random.normal(ks[i], (B, S, H, N)) for i in range(3))
        log_w = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)))
        u = jax.random.normal(ks[4], (H, N))
        s0 = jnp.zeros((B, H, N, N))
        out_full, _ = rwkv6.wkv_chunked(r, k, v, log_w, u, s0, chunk=32)
        o1, s1 = rwkv6.wkv_chunked(r[:, :64], k[:, :64], v[:, :64],
                                   log_w[:, :64], u, s0, chunk=32)
        o2, _ = rwkv6.wkv_chunked(r[:, 64:], k[:, 64:], v[:, 64:],
                                  log_w[:, 64:], u, s1, chunk=32)
        np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                                   np.asarray(out_full), atol=1e-4, rtol=1e-4)


class TestMamba2:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_ssd_chunked_equals_sequential(self, seed):
        key = jax.random.PRNGKey(seed)
        B, S, H, P, N = 2, 128, 3, 8, 16
        ks = jax.random.split(key, 6)
        xh = jax.random.normal(ks[0], (B, S, H, P))
        Bs = jax.random.normal(ks[1], (B, S, N))
        Cs = jax.random.normal(ks[2], (B, S, N))
        dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
        A = jnp.exp(jax.random.normal(ks[4], (H,)) * 0.3)
        state = jax.random.normal(ks[5], (B, H, P, N)) * 0.1
        y_c, s_c = mamba2.ssd_chunked(xh, Bs, Cs, dt, A, state, chunk=32)
        y_s, s_s = mamba2.ssd_sequential(xh, Bs, Cs, dt, A, state)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                                   atol=1e-4, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s),
                                   atol=1e-4, rtol=1e-4)

    def test_conv_streaming(self):
        key = jax.random.PRNGKey(1)
        w = jax.random.normal(key, (4, 6))
        b = jnp.zeros((6,))
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 20, 6))
        y_full, _ = mamba2._causal_conv(x, w, b)
        st = None
        outs = []
        for t in range(20):
            y, st = mamba2._causal_conv(x[:, t:t + 1], w, b, st)
            outs.append(y)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                                   np.asarray(y_full), atol=1e-5, rtol=1e-5)


class TestAttention:
    @given(st.integers(0, 2**31 - 1), st.sampled_from([1, 2, 4]))
    @settings(max_examples=8, deadline=None)
    def test_flash_equals_direct(self, seed, kv_heads):
        key = jax.random.PRNGKey(seed)
        B, S, H, D = 2, 64, 4, 16
        q = jax.random.normal(key, (B, S, H, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, kv_heads, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, kv_heads, D))
        out = attention.flash_attention(q, k, v, causal=True, chunk=16)
        # direct reference
        G = H // kv_heads
        qr = q.reshape(B, S, kv_heads, G, D) * D ** -0.5
        s = jnp.einsum("bskgd,btkd->bskgt", qr, k)
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        want = jnp.einsum("bskgt,btkd->bskgd", p, v).reshape(B, S, H, D)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_decode_matches_flash_last_position(self):
        key = jax.random.PRNGKey(3)
        B, T, H, D = 2, 32, 4, 16
        q = jax.random.normal(key, (B, 1, H, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, T, 2, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, T, 2, D))
        got = attention.decode_attention(q, k, v, jnp.int32(T - 1))
        want = attention.flash_attention(q, k, v, causal=True,
                                         q_offset=T - 1, chunk=8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5, rtol=2e-5)

    def test_kv_len_masking(self):
        key = jax.random.PRNGKey(4)
        B, S, H, D = 1, 8, 2, 8
        q = jax.random.normal(key, (B, S, H, D))
        k = jax.random.normal(jax.random.fold_in(key, 1), (B, 16, 2, D))
        v = jax.random.normal(jax.random.fold_in(key, 2), (B, 16, 2, D))
        # padding beyond kv_len must not affect the result
        out1 = attention.flash_attention(q, k, v, causal=False, chunk=8,
                                         kv_len=jnp.int32(10))
        k2 = k.at[:, 10:].set(99.0)
        v2 = v.at[:, 10:].set(-99.0)
        out2 = attention.flash_attention(q, k2, v2, causal=False, chunk=8,
                                         kv_len=jnp.int32(10))
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   atol=1e-6)


class TestMoE:
    def test_matches_dense_loop_oracle(self):
        cfg = get_arch("granite-moe-1b-a400m").reduced().replace(
            capacity_factor=8.0)  # no drops
        key = jax.random.PRNGKey(0)
        params = moe.init_moe(key, cfg)
        x = jax.random.normal(jax.random.fold_in(key, 1), (2, 8, cfg.d_model),
                              jnp.float32)
        out, aux = moe.moe_ffn(params, x, cfg, "bf16")
        # oracle: explicit per-token loop
        from repro.models import layers as L
        xt = x.reshape(-1, cfg.d_model)
        logits = xt @ params["router"]["w"].astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)
        top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
        top_p = top_p / top_p.sum(-1, keepdims=True)
        want = np.zeros_like(np.asarray(xt))
        for t in range(xt.shape[0]):
            for j in range(cfg.top_k):
                e = int(top_e[t, j])
                h_g = np.asarray(xt[t] @ params["experts_gate"][e].astype(jnp.float32))
                h_u = np.asarray(xt[t] @ params["experts_up"][e].astype(jnp.float32))
                h = (h_g / (1 + np.exp(-h_g))) * h_u
                o = h @ np.asarray(params["experts_down"][e], np.float32)
                want[t] += float(top_p[t, j]) * o
        np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model),
                                              np.float32),
                                   want, atol=0.08, rtol=0.08)
        assert float(aux) > 0

    def test_capacity_drops_tokens_gracefully(self):
        cfg = get_arch("granite-moe-1b-a400m").reduced().replace(
            capacity_factor=0.25)
        params = moe.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
        out, _ = moe.moe_ffn(params, x, cfg, "bf16")
        assert np.isfinite(np.asarray(out, np.float32)).all()
