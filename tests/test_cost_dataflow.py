"""Cost-model + dataflow tests (Table III derivations, Section IV-A)."""

import numpy as np
import pytest

from repro.core import cost_model as cm
from repro.core.dataflow import (COLS, ROWS, LayerShape, analyze_traffic,
                                 choose_mapping, enumerate_mappings,
                                 network_mapping_report)


class TestTable3:
    def test_normalized_efficiency_reproduces_paper_headlines(self):
        t = cm.table3(cycles_source="paper")
        # paper: BP-exact area efficiency 1.28 @50%, 1.23 @60%, 1.14 @70%
        np.testing.assert_allclose(t["bp_exact"]["area_eff"][:3],
                                   [1.28, 1.23, 1.14], atol=0.015)
        # paper: BP-exact energy efficiency 1.30 / 1.31 / 1.25
        np.testing.assert_allclose(t["bp_exact"]["energy_eff"][:3],
                                   [1.30, 1.31, 1.25], atol=0.015)
        # AdaS is the normalization base
        assert all(abs(v - 1.0) < 1e-9 for v in t["adas"]["area_eff"])

    def test_modeled_bp_cycles_close_to_paper(self):
        # first-principles emulation vs the paper's measured Table III row
        for bs, want in zip(cm.SPARSITY_LEVELS, cm.PAPER_AVG_CYCLES["bp_exact"]):
            got = cm.modeled_avg_cycles("bp_exact", bs, n=60_000)
            assert abs(got - want) / want < 0.08, (bs, got, want)

    def test_modeled_cycles_monotone_in_sparsity(self):
        for m in ("bp_exact", "bp_approx", "bit_serial", "bitwave"):
            cyc = [cm.modeled_avg_cycles(m, bs, n=30_000)
                   for bs in cm.SPARSITY_LEVELS]
            assert all(a >= b - 1e-6 for a, b in zip(cyc, cyc[1:])), (m, cyc)

    def test_approx_never_slower_than_exact(self):
        for bs in cm.SPARSITY_LEVELS:
            assert (cm.modeled_avg_cycles("bp_approx", bs, n=30_000)
                    <= cm.modeled_avg_cycles("bp_exact", bs, n=30_000) + 1e-6)

    def test_mac_energy_interpolation(self):
        e50 = cm.mac_energy_pj("bp_exact", 0.5)
        e90 = cm.mac_energy_pj("bp_exact", 0.9)
        assert e90 < e50  # sparser -> cheaper
        # @50%: 509.38 uW / 500 MHz * 2.14 cycles ~= 2.18 pJ
        assert abs(e50 - 509.38e-6 / 500e6 * 2.14 * 1e12) < 1e-6


class TestDataflow:
    def test_early_layer_prefers_dataflow_a(self):
        conv1 = LayerShape("conv1", B=1, K=64, C=3, OY=32, OX=32, FY=3, FX=3)
        assert choose_mapping(conv1).dataflow == "a"

    def test_fc_layer_prefers_dataflow_b_under_batch(self):
        fc = LayerShape("fc", B=32, K=4096, C=4096, OY=1, OX=1)
        assert choose_mapping(fc).dataflow == "b"

    def test_small_ox_uses_oy_unrolling(self):
        late = LayerShape("late", B=1, K=512, C=512, OY=8, OX=8, FY=3, FX=3)
        m = choose_mapping(late)
        assert m.dataflow == "a" and (m.oxu, m.oyu) == (8, 4)

    def test_steps_account_for_all_macs(self):
        shape = LayerShape("x", B=2, K=64, C=16, OY=32, OX=32, FY=3, FX=3)
        for m in enumerate_mappings(shape):
            assert m.steps * ROWS * COLS >= shape.total_macs
            assert 0 < m.spatial_utilization <= 1.0

    def test_perfectly_shaped_layer_has_full_utilization(self):
        shape = LayerShape("p", B=1, K=16, C=8, OY=1, OX=32, FY=1, FX=1)
        m = choose_mapping(shape)
        assert m.spatial_utilization == 1.0

    def test_traffic_conservation(self):
        shape = LayerShape("x", B=1, K=64, C=64, OY=16, OX=16, FY=3, FX=3)
        m = choose_mapping(shape)
        t = analyze_traffic(shape, m)
        # each step feeds 16 weights + 32 acts
        assert t.w_cache_reads == m.steps * ROWS
        assert t.a_cache_reads == m.steps * COLS
        assert t.r_cache_writes == shape.output_count
        # DRAM never less than one pass over the data
        assert t.dram_weight_bytes >= shape.weight_count
        assert t.dram_act_bytes >= shape.input_count
        assert t.dram_energy_pj() > 0 and t.cache_energy_pj() > 0

    def test_network_report(self):
        layers = [LayerShape("a", 1, 64, 3, 32, 32, 3, 3),
                  LayerShape("b", 1, 10, 512, 1, 1)]
        rows, util = network_mapping_report(layers)
        assert len(rows) == 2 and 0 < util <= 1.0
