"""Cross-cutting invariants: positions (RoPE/M-RoPE), partition rules,
dataflow enumeration, serving engine semantics."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.configs.base import ARCH_IDS, SHAPES, get_arch, runnable_cells
from repro.core.dataflow import LayerShape, enumerate_mappings
from repro.distributed.sharding import param_spec
from repro.models import layers


class TestRope:
    def test_mrope_with_equal_rows_equals_rope(self):
        """Text-only M-RoPE (t==h==w positions) must reduce to plain RoPE."""
        B, S, D = 2, 16, 32
        pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        cos1, sin1 = layers.rope_angles(pos, D, 1e4)
        pos3 = jnp.broadcast_to(pos[None], (3, B, S))
        cos2, sin2 = layers.mrope_angles(pos3, D, 1e4, (4, 6, 6))
        np.testing.assert_allclose(np.asarray(cos1), np.asarray(cos2),
                                   atol=1e-6)
        np.testing.assert_allclose(np.asarray(sin1), np.asarray(sin2),
                                   atol=1e-6)

    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
        pos = jnp.arange(8)[None]
        cos, sin = layers.rope_angles(pos, 16, 1e4)
        y = layers.apply_rope(x, cos, sin)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(y, np.float32), axis=-1),
            np.linalg.norm(np.asarray(x, np.float32), axis=-1), rtol=1e-4)

    def test_rope_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i - j."""
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
        def dot_at(i, j):
            ci, si = layers.rope_angles(jnp.asarray([[i]]), 16, 1e4)
            cj, sj = layers.rope_angles(jnp.asarray([[j]]), 16, 1e4)
            return float(jnp.sum(layers.apply_rope(q, ci, si)
                                 * layers.apply_rope(k, cj, sj)))
        assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4


class TestPartitionRules:
    MESH = {"data": 16, "model": 16}

    def _leaf(self, shape):
        return jax.ShapeDtypeStruct(shape, jnp.bfloat16)

    def test_2d_train_sharding(self):
        s = param_spec("layers/attn/wq/w", self._leaf((28, 3584, 3584)),
                       "train", self.MESH)
        assert tuple(s) == (None, "data", "model")

    def test_serve_is_tp_only(self):
        s = param_spec("layers/ffn/w_up/w", self._leaf((28, 3584, 18944)),
                       "serve", self.MESH)
        assert tuple(s) == (None, None, "model")

    def test_expert_axis_goes_to_model(self):
        s = param_spec("layers/moe/experts_up", self._leaf((48, 64, 2048, 1408)),
                       "train", self.MESH)
        assert tuple(s) == (None, "model", "data", None)

    def test_indivisible_dims_replicate(self):
        s = param_spec("x/w", self._leaf((30, 50)), "train", self.MESH)
        assert tuple(s) == (None, None)

    def test_1d_replicated(self):
        s = param_spec("norm/scale", self._leaf((4096,)), "train", self.MESH)
        assert tuple(s) == (None,)

    @given(st.integers(1, 4), st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_specs_always_match_rank(self, ndim, seed):
        rng = np.random.default_rng(seed)
        shape = tuple(int(rng.integers(1, 64)) * int(rng.choice([1, 16]))
                      for _ in range(ndim))
        s = param_spec("some/w", self._leaf(shape), "train", self.MESH)
        assert len(tuple(s)) == ndim


class TestDataflowProperties:
    @given(st.integers(1, 64), st.integers(1, 512), st.integers(1, 512),
           st.integers(1, 64), st.integers(1, 64),
           st.sampled_from([1, 3, 5]))
    @settings(max_examples=40, deadline=None)
    def test_every_mapping_covers_all_macs(self, b, k, c, oy, ox, f):
        shape = LayerShape("x", B=b, K=k, C=c, OY=oy, OX=ox, FY=f, FX=f)
        for m in enumerate_mappings(shape):
            assert m.steps * 512 >= shape.total_macs
            assert 0 < m.spatial_utilization <= 1.0 + 1e-9


class TestCellRegistry:
    def test_runnable_cell_count_matches_design(self):
        cells = list(runnable_cells())
        # 10 archs x 3 shapes + long_500k for rwkv6 + zamba2 = 32
        assert len(cells) == 32
        longs = [a for a, s in cells if s == "long_500k"]
        assert sorted(longs) == ["rwkv6-7b", "zamba2-2.7b"]

    def test_all_archs_have_distinct_param_counts(self):
        counts = {a: get_arch(a).param_count() for a in ARCH_IDS}
        # sanity: param counts land near their nameplate sizes
        assert 12e9 < counts["phi3-medium-14b"] < 16e9
        assert 30e9 < counts["granite-34b"] < 38e9
        assert 1.3e9 < counts["qwen2-1.5b"] < 2.1e9
        assert 6.5e9 < counts["qwen2-7b"] < 8.5e9
        # note: the ASSIGNED dims (48L x 64e x d_ff=1408) imply ~28B total;
        # the "a3b" active count is what matches the nameplate (next test)
        assert 20e9 < counts["moonshot-v1-16b-a3b"] < 30e9
        assert 0.9e9 < counts["granite-moe-1b-a400m"] < 1.7e9
        assert 6.4e9 < counts["rwkv6-7b"] < 8.5e9
        assert 2.2e9 < counts["zamba2-2.7b"] < 3.4e9

    def test_moe_active_counts(self):
        moon = get_arch("moonshot-v1-16b-a3b")
        assert 2.2e9 < moon.param_count(active_only=True) < 4e9


class TestServingEngine:
    def test_eos_early_exit(self):
        from repro.serving.engine import ServeConfig, ServingEngine
        from repro.models import api
        cfg = get_arch("qwen2-1.5b").reduced().replace(
            num_layers=2, d_model=64, d_ff=128, vocab_size=64, head_dim=16)
        params = api.init(jax.random.PRNGKey(0), cfg)
        engine = ServingEngine(cfg, params,
                               ServeConfig(max_new_tokens=16, eos_id=0,
                                           temperature=0.0))
        res = engine.generate({"tokens": jnp.ones((2, 4), jnp.int32)})
        assert res.steps <= 16
        # after a sequence hits EOS, it stays EOS
        toks = res.tokens
        for b in range(toks.shape[0]):
            hit = np.where(toks[b] == 0)[0]
            if len(hit) and hit[0] + 1 < toks.shape[1]:
                assert (toks[b, hit[0]:] == 0).all()
