"""Continuous-batching serving subsystem: token-exactness vs the static
engine under greedy decoding, eviction/admission edge cases, recurrent-state
architectures, and the scheduler/queue/cache-manager state machines."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.models import api
from repro.serving import (CacheManager, Request, RequestQueue, RequestState,
                           SchedulerConfig, ServeConfig, ServingEngine)

jax.config.update("jax_default_matmul_precision", "float32")


def _dense_cfg():
    return get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16)


def _engine(cfg, max_new=8, eos=None, seed=0):
    params = api.init(jax.random.PRNGKey(seed), cfg)
    return ServingEngine(cfg, params,
                         ServeConfig(max_new_tokens=max_new, temperature=0.0,
                                     eos_id=eos))


def _prompts(cfg, B, S, seed=1):
    return np.asarray(
        jax.random.randint(jax.random.PRNGKey(seed), (B, S), 2,
                           cfg.vocab_size), np.int32)


def _assert_matches_static(engine, prompts, max_news, report):
    static = engine.generate({"tokens": jnp.asarray(prompts)},
                             max_new_tokens=int(max(max_news)))
    results = sorted(report.results, key=lambda r: r.request_id)
    for i, r in enumerate(results):
        want = np.asarray(static.tokens[i][:max_news[i]])
        assert len(r.tokens) == len(want), (i, r.tokens, want)
        np.testing.assert_array_equal(r.tokens, want, err_msg=f"request {i}")


# ---------------------------------------------------------------------------
# Token exactness
# ---------------------------------------------------------------------------

class TestTokenExactness:
    def test_simultaneous_arrivals_match_static(self):
        cfg = _dense_cfg()
        engine = _engine(cfg)
        prompts = _prompts(cfg, 4, 6)
        reqs = [Request(prompt=prompts[i], max_new_tokens=8) for i in range(4)]
        report = engine.serve(reqs, n_slots=4)
        _assert_matches_static(engine, prompts, [8] * 4, report)

    def test_staggered_arrivals_and_hetero_lengths_match_static(self):
        cfg = _dense_cfg()
        engine = _engine(cfg)
        prompts = _prompts(cfg, 5, 6)
        max_news = [8, 3, 8, 5, 1]
        reqs = [Request(prompt=prompts[i], max_new_tokens=max_news[i],
                        arrival_time=float(i)) for i in range(5)]
        report = engine.serve(reqs, n_slots=2,
                              sched_cfg=SchedulerConfig(lead_window=2))
        _assert_matches_static(engine, prompts, max_news, report)

    def test_arrival_order_does_not_change_outputs(self):
        cfg = _dense_cfg()
        engine = _engine(cfg)
        prompts = _prompts(cfg, 4, 5)
        base = None
        for order_seed in (0, 1):
            rng = np.random.default_rng(order_seed)
            arrivals = rng.permutation(4).astype(float)
            reqs = [Request(prompt=prompts[i], max_new_tokens=6,
                            arrival_time=float(arrivals[i]))
                    for i in range(4)]
            report = engine.serve(reqs, n_slots=2)
            toks = [r.tokens for r in
                    sorted(report.results, key=lambda r: r.request_id)]
            if base is None:
                base = toks
            else:
                for a, b in zip(base, toks):
                    np.testing.assert_array_equal(a, b)

    def test_lead_window_does_not_change_outputs(self):
        cfg = _dense_cfg()
        engine = _engine(cfg)
        prompts = _prompts(cfg, 4, 6)
        reqs_of = lambda: [Request(prompt=prompts[i], max_new_tokens=6,
                                   arrival_time=float(2 * i))
                           for i in range(4)]
        reports = [engine.serve(reqs_of(), n_slots=2,
                                sched_cfg=SchedulerConfig(lead_window=E))
                   for E in (0, 3)]
        for r0, r3 in zip(*(sorted(r.results, key=lambda x: x.request_id)
                            for r in reports)):
            np.testing.assert_array_equal(r0.tokens, r3.tokens)
        _assert_matches_static(engine, prompts, [6] * 4, reports[0])

    def test_heterogeneous_prompt_lengths(self):
        # static lock-step cannot even express this; compare per-request
        cfg = _dense_cfg()
        engine = _engine(cfg)
        lens = [3, 7, 5]
        prompts = [_prompts(cfg, 1, L, seed=L)[0] for L in lens]
        reqs = [Request(prompt=p, max_new_tokens=5, arrival_time=float(i))
                for i, p in enumerate(prompts)]
        report = engine.serve(reqs, n_slots=2)
        for i, r in enumerate(sorted(report.results,
                                     key=lambda r: r.request_id)):
            solo = engine.generate({"tokens": jnp.asarray(prompts[i][None])},
                                   max_new_tokens=5)
            np.testing.assert_array_equal(r.tokens, np.asarray(solo.tokens[0]))


# ---------------------------------------------------------------------------
# Eviction / admission edge cases
# ---------------------------------------------------------------------------

class TestEdgeCases:
    def test_all_eos_batch(self):
        # every request's first greedy token is forced to be EOS: the batch
        # finishes at prefill, no decode step runs, no slot leaks
        cfg = _dense_cfg()
        engine = _engine(cfg)
        prompts = _prompts(cfg, 3, 4)
        first = np.asarray(engine.generate(
            {"tokens": jnp.asarray(prompts)}, max_new_tokens=1).tokens[:, 0])
        # pick one first-token value as EOS and serve the requests that hit it
        eos = int(first[0])
        subset = [i for i in range(3) if first[i] == eos] or [0]
        engine.serve_cfg.eos_id = eos
        reqs = [Request(prompt=prompts[i], max_new_tokens=8) for i in subset]
        report = engine.serve(reqs, n_slots=2)
        for r in report.results:
            assert r.finish_reason == "eos"
            assert r.tokens.tolist() == [eos]
        assert report.steps == 0  # finished at prefill, nothing decoded
        # tokens WERE generated (one per request at prefill): the throughput
        # report must not be blind to them just because no decode step ran
        assert report.total_new_tokens == len(reqs)
        assert report.decode_tokens_per_s > 0.0

    def test_arrival_burst_larger_than_slot_count(self):
        cfg = _dense_cfg()
        engine = _engine(cfg)
        B, n_slots = 7, 2
        prompts = _prompts(cfg, B, 5)
        reqs = [Request(prompt=prompts[i], max_new_tokens=4,
                        arrival_time=0.0) for i in range(B)]
        report = engine.serve(reqs, n_slots=n_slots)
        assert all(r.finish_reason == "length" for r in report.results)
        _assert_matches_static(engine, prompts, [4] * B, report)
        # the pool never held more than n_slots at once
        assert report.slot_utilization <= 1.0
        assert report.n_syncs >= (B + n_slots - 1) // n_slots

    def test_admission_control_rejects_beyond_queue_bound(self):
        cfg = _dense_cfg()
        engine = _engine(cfg)
        prompts = _prompts(cfg, 6, 5)
        reqs = [Request(prompt=prompts[i], max_new_tokens=4,
                        arrival_time=0.0) for i in range(6)]
        report = engine.serve(
            reqs, n_slots=1,
            sched_cfg=SchedulerConfig(lead_window=0, max_waiting=2))
        rejected = [r for r in report.results if r.finish_reason == "rejected"]
        served = [r for r in report.results if r.finish_reason == "length"]
        assert report.n_rejected == len(rejected) > 0
        assert len(served) + len(rejected) == 6
        for r in rejected:
            assert len(r.tokens) == 0

    def test_oversized_request_rejected_not_wedged(self):
        cfg = _dense_cfg()
        engine = _engine(cfg)
        prompts = _prompts(cfg, 2, 5)
        ok = Request(prompt=prompts[0], max_new_tokens=4)
        big = Request(prompt=prompts[1], max_new_tokens=4)
        report = engine.serve([ok, big], n_slots=2, cache_T=5 + 4)
        by_id = {r.request_id: r for r in report.results}
        assert by_id[ok.request_id].finish_reason == "length"
        assert by_id[big.request_id].finish_reason == "length"
        # now an explicit cache too small for request 1's prompt+new
        ok2 = Request(prompt=prompts[0], max_new_tokens=2)
        big2 = Request(prompt=prompts[1], max_new_tokens=8)
        report = engine.serve([ok2, big2], n_slots=2, cache_T=5 + 2)
        by_id = {r.request_id: r for r in report.results}
        assert by_id[ok2.request_id].finish_reason == "length"
        assert by_id[big2.request_id].finish_reason == "rejected"

    def test_idle_gap_between_arrivals(self):
        # queue fully drains, then a late request arrives: clock must jump
        cfg = _dense_cfg()
        engine = _engine(cfg)
        prompts = _prompts(cfg, 2, 5)
        reqs = [Request(prompt=prompts[0], max_new_tokens=3, arrival_time=0.0),
                Request(prompt=prompts[1], max_new_tokens=3,
                        arrival_time=50.0)]
        report = engine.serve(reqs, n_slots=2)
        _assert_matches_static(engine, prompts, [3, 3], report)
        late = sorted(report.results, key=lambda r: r.request_id)[1]
        assert late.ttft_steps is not None and late.ttft_steps <= 1.0


# ---------------------------------------------------------------------------
# Recurrent-state architectures
# ---------------------------------------------------------------------------

class TestRecurrentFamilies:
    def test_rwkv_continuous_matches_static(self):
        cfg = get_arch("rwkv6-7b").reduced().replace(
            num_layers=2, d_model=64, d_ff=128, vocab_size=128)
        engine = _engine(cfg, max_new=5)
        prompts = _prompts(cfg, 3, 6)
        max_news = [5, 2, 4]
        reqs = [Request(prompt=prompts[i], max_new_tokens=max_news[i],
                        arrival_time=float(i)) for i in range(3)]
        report = engine.serve(reqs, n_slots=2)
        _assert_matches_static(engine, prompts, max_news, report)

    def test_zamba_hybrid_continuous_matches_static(self):
        cfg = get_arch("zamba2-2.7b").reduced()
        cfg = cfg.replace(num_layers=2, attn_every=2, d_model=64, d_ff=128,
                          vocab_size=128, head_dim=16)
        engine = _engine(cfg, max_new=4)
        prompts = _prompts(cfg, 3, 6)
        max_news = [4, 2, 4]
        reqs = [Request(prompt=prompts[i], max_new_tokens=max_news[i],
                        arrival_time=float(i)) for i in range(3)]
        report = engine.serve(reqs, n_slots=2)
        _assert_matches_static(engine, prompts, max_news, report)


# ---------------------------------------------------------------------------
# Component state machines
# ---------------------------------------------------------------------------

class TestComponents:
    def test_request_state_machine_rejects_illegal_transitions(self):
        r = Request(prompt=np.arange(4))
        with pytest.raises(ValueError):
            r.transition(RequestState.DECODE)  # WAITING -> DECODE illegal
        r.transition(RequestState.PREFILL)
        r.transition(RequestState.DECODE)
        r.finish(1.0, "length")
        with pytest.raises(ValueError):
            r.transition(RequestState.DECODE)  # DONE is terminal

    def test_queue_fifo_and_bound(self):
        q = RequestQueue(max_waiting=2)
        rs = [Request(prompt=np.arange(3)) for _ in range(3)]
        assert q.submit(rs[0], 0.0) and q.submit(rs[1], 0.0)
        assert not q.submit(rs[2], 0.0)
        assert rs[2].finish_reason == "rejected"
        assert [r.request_id for r in q.pop(5)] == [rs[0].request_id,
                                                    rs[1].request_id]
        assert len(q) == 0

    def test_cache_manager_slot_lifecycle(self):
        cfg = _dense_cfg()
        cm = CacheManager(cfg, n_slots=2, cache_T=8)
        a = cm.alloc()
        b = cm.alloc()
        assert {a, b} == {0, 1} and cm.n_free == 0
        with pytest.raises(RuntimeError):
            cm.alloc()
        cm.advance([a])
        assert cm.divergence() == 1
        cm.free(a)
        assert cm.n_free == 1 and cm.lengths[a] == 0
        with pytest.raises(ValueError):
            cm.free(a)

    def test_cache_manager_insert_roundtrip(self):
        cfg = _dense_cfg()
        params = api.init(jax.random.PRNGKey(0), cfg)
        toks = _prompts(cfg, 1, 4)
        _, src = api.prefill(params, cfg, {"tokens": jnp.asarray(toks)}, 8)
        cm = CacheManager(cfg, n_slots=3, cache_T=8)
        slot = cm.alloc()
        cm.insert(slot, src, length=4)
        got = api.slot_extract(cfg, cm.cache, slot)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(src)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_deployment_estimate_present_when_quantized(self):
        from repro.models.layers import quantize_dense_params
        cfg = _dense_cfg()
        params = api.init(jax.random.PRNGKey(0), cfg)
        qparams = quantize_dense_params(params)
        qcfg = cfg.replace(matmul_mode="bp_exact", kv_cache_int8=True)
        engine = ServingEngine(qcfg, qparams, ServeConfig(max_new_tokens=3))
        est = engine.deployment_estimate(n_mc=2_000)
        assert est is not None and est["mode"] == "bp_exact"
        assert len(est["per_layer"]) >= cfg.num_layers
        assert 0.0 < est["mean_bit_sparsity"] < 1.0
        assert est["mean_cycles_per_mac"] >= 1.0
        # bf16 engine reports no estimate
        bf = ServingEngine(cfg, params, ServeConfig(max_new_tokens=3))
        assert bf.deployment_estimate() is None
