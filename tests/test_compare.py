"""benchmarks/compare.py — the CI regression gate — and the artifact
provenance stamping in benchmarks/common.py."""

import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import common
from benchmarks.compare import (collect_metrics, compare_payloads,
                                fingerprint, main, metric_direction)

META = {"schema": 1, "git_sha": "abc", "hostname": "ci-box",
        "jax_version": "0.4.0", "device_kind": "cpu", "device_count": 1,
        "timestamp_utc": "2026-01-01T00:00:00Z"}


def _artifact(per_step_ms=2.0, tokens_per_s=500.0, meta=META):
    return {
        "continuous_per_step_ms": per_step_ms,
        "continuous_tokens_per_s": tokens_per_s,
        "cells": {"model_slab": {"per_step_ms": per_step_ms,
                                 "tokens_per_s": tokens_per_s}},
        "n_requests": 8,                 # not a gated metric
        "_meta": dict(meta),
    }


def _write(path, payload):
    os.makedirs(os.path.dirname(str(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f)
    return str(path)


class TestCollectMetrics:
    def test_flattens_suffix_matched_leaves_at_any_depth(self):
        m = collect_metrics(_artifact())
        assert m == {
            "continuous_per_step_ms": 2.0,
            "continuous_tokens_per_s": 500.0,
            "cells.model_slab.per_step_ms": 2.0,
            "cells.model_slab.tokens_per_s": 500.0,
        }

    def test_meta_and_non_metrics_excluded(self):
        m = collect_metrics({"_meta": {"x_per_step_ms": 9},
                             "flag_tokens_per_s": True,
                             "n_requests": 8})
        assert m == {}                   # bool and _meta never gate


def _pct_artifact(p50=1.0, p90=2.0, p99=5.0, meta=META):
    """production_mix-shaped artifact: a nested percentile block."""
    return {
        "per_step_ms": {"p50": p50, "p90": p90, "p99": p99},
        "decode": {"per_step_ms": {"p99": p99}},
        "n_requests": 8,
        "_meta": dict(meta),
    }


class TestPercentileGating:
    def test_direction_matches_full_dotted_key(self):
        assert metric_direction("per_step_ms.p99") == "lower"
        assert metric_direction("decode.per_step_ms.p50") == "lower"
        assert metric_direction("continuous_per_step_ms") == "lower"
        assert metric_direction("x_tokens_per_s") == "higher"
        assert metric_direction("n_requests") is None
        # a percentile leaf must not also match the bare suffix
        assert metric_direction("per_step_ms.p75") is None

    def test_percentile_leaves_collected_once_each(self):
        m = collect_metrics(_pct_artifact())
        assert m == {
            "per_step_ms.p50": 1.0,
            "per_step_ms.p90": 2.0,
            "per_step_ms.p99": 5.0,
            "decode.per_step_ms.p99": 5.0,
        }

    def test_p99_regression_fails_gate(self):
        regs, _ = compare_payloads(_pct_artifact(p99=5.0),
                                   _pct_artifact(p99=6.5), 0.15)
        assert len(regs) == 2            # top-level + nested decode block
        assert all("p99" in r for r in regs)

    def test_p99_improvement_passes(self):
        regs, _ = compare_payloads(_pct_artifact(p99=5.0),
                                   _pct_artifact(p99=4.0), 0.15)
        assert regs == []

    def test_main_gates_percentile_artifact(self, tmp_path):
        prev = _write(tmp_path / "prev" / "BENCH_production_mix.json",
                      _pct_artifact(p99=5.0))
        cur = _write(tmp_path / "cur" / "BENCH_production_mix.json",
                     _pct_artifact(p99=9.0))
        assert main([prev, cur]) == 1


class TestComparePayloads:
    def test_twenty_percent_latency_regression_fails(self):
        regs, _ = compare_payloads(_artifact(per_step_ms=2.0),
                                   _artifact(per_step_ms=2.4), 0.15)
        assert len(regs) == 2            # top-level + nested cell
        assert all("REGRESSION" in r for r in regs)

    def test_throughput_drop_fails_improvement_passes(self):
        regs, _ = compare_payloads(_artifact(tokens_per_s=500.0),
                                   _artifact(tokens_per_s=390.0), 0.15)
        assert regs
        regs, _ = compare_payloads(_artifact(per_step_ms=2.0),
                                   _artifact(per_step_ms=1.0), 0.15)
        assert regs == []                # faster is never a regression

    def test_identical_passes(self):
        regs, notes = compare_payloads(_artifact(), _artifact(), 0.15)
        assert regs == [] and notes

    def test_fingerprint_mismatch_skips(self):
        other = dict(META, device_kind="TPU v4")
        regs, notes = compare_payloads(_artifact(per_step_ms=2.0),
                                       _artifact(per_step_ms=99.0,
                                                 meta=other), 0.15)
        assert regs == []
        assert any("SKIP" in n for n in notes)

    def test_hostname_change_still_compares(self):
        # ephemeral CI runners: new hostname per run, same machine class
        other = dict(META, hostname="fv-az123", git_sha="def")
        regs, _ = compare_payloads(_artifact(per_step_ms=2.0),
                                   _artifact(per_step_ms=2.4, meta=other),
                                   0.15)
        assert regs

    def test_missing_meta_skips(self):
        prev = _artifact()
        cur = _artifact(per_step_ms=99.0)
        del cur["_meta"]
        regs, notes = compare_payloads(prev, cur, 0.15)
        assert regs == [] and any("SKIP" in n for n in notes)
        assert fingerprint(cur) is None

    def test_nan_current_is_a_regression_not_ok(self):
        # NaN compares False against any threshold: without the explicit
        # guard a NaN'd metric would print "ok" and pass the gate
        regs, _ = compare_payloads(_artifact(per_step_ms=2.0),
                                   _artifact(per_step_ms=float("nan")),
                                   0.15)
        assert regs and all("not finite" in r for r in regs)
        regs, _ = compare_payloads(_artifact(tokens_per_s=500.0),
                                   _artifact(tokens_per_s=float("inf")),
                                   0.15)
        assert regs                      # inf current is flagged too

    def test_zero_or_nan_baseline_skips_with_a_note(self):
        regs, notes = compare_payloads(_artifact(per_step_ms=0.0),
                                       _artifact(per_step_ms=5.0), 0.15)
        assert regs == []
        assert any("SKIP" in n and "not a positive finite" in n
                   for n in notes)
        regs, notes = compare_payloads(_artifact(per_step_ms=float("nan")),
                                       _artifact(per_step_ms=5.0), 0.15)
        assert regs == []
        assert any("SKIP" in n for n in notes)

    def test_negative_baseline_skips(self):
        regs, notes = compare_payloads(_artifact(tokens_per_s=-1.0),
                                       _artifact(tokens_per_s=1.0), 0.15)
        assert regs == []
        assert any("SKIP" in n for n in notes)


class TestMainExitCodes:
    def test_regression_exits_1(self, tmp_path):
        prev = _write(tmp_path / "prev" / "BENCH_x.json", _artifact(2.0))
        cur = _write(tmp_path / "cur" / "BENCH_x.json", _artifact(2.4))
        assert main([prev, cur]) == 1

    def test_identical_exits_0(self, tmp_path):
        prev = _write(tmp_path / "prev" / "BENCH_x.json", _artifact())
        cur = _write(tmp_path / "cur" / "BENCH_x.json", _artifact())
        assert main([prev, cur]) == 0

    def test_missing_previous_skips_exit_0(self, tmp_path):
        cur = _write(tmp_path / "cur" / "BENCH_x.json", _artifact())
        assert main([str(tmp_path / "nope"), cur]) == 0

    def test_missing_current_is_usage_error(self, tmp_path):
        assert main([str(tmp_path / "a"), str(tmp_path / "b")]) == 2

    def test_dir_mode_matches_by_filename(self, tmp_path):
        _write(tmp_path / "prev" / "BENCH_a.json", _artifact(2.0))
        _write(tmp_path / "cur" / "BENCH_a.json", _artifact(2.4))
        _write(tmp_path / "cur" / "BENCH_new.json", _artifact())  # no prev
        _write(tmp_path / "cur" / "notes.json", _artifact(9.0))   # unmatched
        assert main([str(tmp_path / "prev"), str(tmp_path / "cur")]) == 1

    def test_threshold_flag_loosens_gate(self, tmp_path):
        prev = _write(tmp_path / "p" / "BENCH_x.json", _artifact(2.0))
        cur = _write(tmp_path / "c" / "BENCH_x.json", _artifact(2.4))
        assert main([prev, cur, "--threshold", "0.25"]) == 0


class TestArtifactMeta:
    def test_save_artifact_stamps_meta(self, tmp_path, monkeypatch):
        monkeypatch.setattr(common, "ARTIFACT_DIR", str(tmp_path))
        path = common.save_artifact("BENCH_t", {"x_per_step_ms": 1.0})
        with open(path) as f:
            payload = json.load(f)
        meta = payload["_meta"]
        assert meta["schema"] == common.ARTIFACT_SCHEMA_VERSION
        for key in ("git_sha", "hostname", "timestamp_utc", "jax_version",
                    "device_kind", "device_count"):
            assert key in meta
        assert fingerprint(payload) is not None

    def test_existing_meta_not_overwritten(self, tmp_path, monkeypatch):
        monkeypatch.setattr(common, "ARTIFACT_DIR", str(tmp_path))
        payload = copy.deepcopy(_artifact())
        path = common.save_artifact("BENCH_t2", payload)
        with open(path) as f:
            assert json.load(f)["_meta"]["hostname"] == "ci-box"

    def test_two_stamped_artifacts_share_a_fingerprint(self, tmp_path,
                                                       monkeypatch):
        monkeypatch.setattr(common, "ARTIFACT_DIR", str(tmp_path))
        a = common.save_artifact("BENCH_a", {"v_tokens_per_s": 1.0})
        b = common.save_artifact("BENCH_b", {"v_tokens_per_s": 2.0})
        with open(a) as f:
            fa = fingerprint(json.load(f))
        with open(b) as f:
            fb = fingerprint(json.load(f))
        assert fa == fb                  # same machine -> comparable
