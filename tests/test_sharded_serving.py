"""Mesh-sharded serving vs single-device: greedy ``serve()`` outputs must be
TOKEN-IDENTICAL across executors for both cache backends, all virtual mesh
shapes, and both quantized matmul modes.  Run in subprocesses with 8 virtual
CPU devices (XLA_FLAGS must be set before jax init — the same pattern as
``tests/test_multidevice.py``)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


_HEADER = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    jax.config.update("jax_default_matmul_precision", "float32")
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import (MeshExecutor, Request, SchedulerConfig,
                               ServeConfig, ServingEngine)

    cfg = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16,
        matmul_mode=%(mode)r, kv_cache_int8=%(int8kv)r)
    params = api.init(jax.random.PRNGKey(0), cfg)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (4, 6), 2, cfg.vocab_size), np.int32)

    def serve_tokens(mesh_shape, backend):
        engine = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=8, temperature=0.0, cache_backend=backend,
            block_size=4, mesh_shape=mesh_shape))
        if mesh_shape is not None:
            assert isinstance(engine.executor, MeshExecutor)
        reqs = [Request(prompt=prompts[i], max_new_tokens=[8, 3, 6, 8][i],
                        arrival_time=float(i)) for i in range(4)]
        rep = engine.serve(reqs, n_slots=2,
                           sched_cfg=SchedulerConfig(lead_window=2))
        assert rep.mesh_shape == mesh_shape
        return [list(r.tokens) for r in
                sorted(rep.results, key=lambda r: r.request_id)], engine
"""


def _script(mode, int8kv, shapes, backends, tail=""):
    # ``tail`` must use the same 4-space base indent as _HEADER — the whole
    # script is dedented once by _run
    return _HEADER % {"mode": mode, "int8kv": int8kv} + f"""
    shapes = {shapes!r}
    for backend in {backends!r}:
        ref, _ = serve_tokens(None, backend)
        for shape in shapes:
            got, engine = serve_tokens(tuple(shape), backend)
            assert got == ref, (backend, shape, ref, got)
            print("OK", backend, shape)
""" + tail


@pytest.mark.slow
def test_sharded_serve_2x4_token_identity_both_backends():
    """The acceptance bar: on a 2x4 ("data", "model") virtual CPU mesh,
    sharded serve() greedy outputs are token-identical to single-device for
    BOTH the slab and paged cache backends (bp_exact weights)."""
    out = _run(_script("bp_exact", False, [(2, 4)], ["slab", "paged"]))
    assert "OK slab (2, 4)" in out and "OK paged (2, 4)" in out


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["bp_exact", "bp_approx"])
def test_sharded_serve_mesh_shapes_1x8_8x1(mode):
    """Degenerate shapes: pure TP (1x8) and pure slot/data parallelism
    (8x1) are token-identical too, both backends, both quant modes."""
    out = _run(_script(mode, False, [(1, 8), (8, 1)], ["slab", "paged"]))
    assert out.count("OK") == 4


@pytest.mark.slow
def test_sharded_serve_bp_approx_int8_kv():
    """The approximate MAC formulation + int8 KV cache survive the mesh:
    the extra correction matmuls and scale pages shard/replicate without
    changing a token."""
    out = _run(_script("bp_approx", True, [(2, 4)], ["slab", "paged"]))
    assert out.count("OK") == 2


@pytest.mark.slow
def test_sharded_speculative_serve_token_identity():
    """Speculative decoding composes with the mesh executor: the drafter's
    traces ride the target's mesh, and 2x4 sharded speculative serve() is
    token-identical to single-device NON-speculative greedy on both cache
    backends.  Acceptance is asserted positive, not ~1: on a mesh the
    draft chain (an S=1 decode program) and the verify (an S=K+1 program)
    have different cross-shard reduction orders, so near-tie argmaxes can
    flip between them — drafts are proposals, the verify is authoritative,
    and token identity is the invariant that must survive."""
    _run(_script("bp_exact", False, [], [], tail="""
    for backend in ("slab", "paged"):
        ref, base_eng = serve_tokens(None, backend)
        spec = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=8, temperature=0.0, cache_backend=backend,
            block_size=4, mesh_shape=(2, 4), draft="model",
            num_draft_tokens=3), draft_cfg=cfg, draft_params=params)
        assert spec.draft_executor.mesh is spec.executor.mesh
        reqs = [Request(prompt=prompts[i], max_new_tokens=[8, 3, 6, 8][i],
                        arrival_time=float(i)) for i in range(4)]
        rep = spec.serve(reqs, n_slots=2,
                         sched_cfg=SchedulerConfig(lead_window=2))
        got = [list(r.tokens) for r in
               sorted(rep.results, key=lambda r: r.request_id)]
        assert got == ref, (backend, ref, got)
        assert rep.acceptance_rate > 0.0
        print("OK spec", backend, rep.steps)
"""))


@pytest.mark.slow
def test_sharded_static_generate_and_report_fields():
    """The static generate() path is mesh-identical as well, and the mesh
    engine keeps the deployment estimate + donation running."""
    _run(_script("bp_exact", False, [], [], tail="""
    single = ServingEngine(cfg, params, ServeConfig(max_new_tokens=8))
    mesh = ServingEngine(cfg, params, ServeConfig(max_new_tokens=8,
                                                  mesh_shape=(2, 4)))
    a = single.generate({"tokens": jnp.asarray(prompts)})
    b = mesh.generate({"tokens": jnp.asarray(prompts)})
    np.testing.assert_array_equal(np.asarray(a.tokens),
                                  np.asarray(b.tokens))
    est = mesh.deployment_estimate(n_mc=500)
    assert est is not None and est["mode"] == "bp_exact"
    print("OK static")
"""))
