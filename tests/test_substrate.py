"""Substrate tests: data pipeline, optimizer, checkpointing, compression,
quasi-sync distributed training, fault tolerance, trainer resume."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import get_arch
from repro.data.pipeline import DataConfig, PrefetchingLoader, make_batch
from repro.distributed import compression
from repro.distributed.quasi_sync import (BoundedStalenessTrainer,
                                          ClusterConfig, cluster_utilization)
from repro.train import optimizer as opt_lib
from repro.train.train_loop import TrainConfig, Trainer, make_train_step


class TestDataPipeline:
    def test_deterministic_addressing(self):
        cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4)
        a = make_batch(cfg, 7)
        b = make_batch(cfg, 7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        c = make_batch(cfg, 8)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_host_sharding_partitions_global_batch(self):
        kw = dict(vocab_size=128, seq_len=16, global_batch=8, num_hosts=2)
        h0 = make_batch(DataConfig(**kw, host_id=0), 3)
        h1 = make_batch(DataConfig(**kw, host_id=1), 3)
        assert h0["tokens"].shape == (4, 16)
        assert not np.array_equal(h0["tokens"], h1["tokens"])

    def test_prefetcher_resumes_from_step(self):
        cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
        loader = PrefetchingLoader(cfg, start_step=5)
        got = next(loader)
        loader.close()
        np.testing.assert_array_equal(got["tokens"], make_batch(cfg, 5)["tokens"])

    def test_tokens_in_range_and_mask(self):
        cfg = DataConfig(vocab_size=100, seq_len=64, global_batch=2,
                         pad_fraction=0.2)
        b = make_batch(cfg, 0)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 100
        assert 0.05 < (~b["loss_mask"]).mean() < 0.4


class TestOptimizer:
    def test_quadratic_convergence(self):
        cfg = opt_lib.OptimizerConfig(peak_lr=0.1, warmup_steps=5,
                                      total_steps=200, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = opt_lib.init_state(params)
        loss = lambda p: jnp.sum(p["w"] ** 2)
        for _ in range(200):
            g = jax.grad(loss)(params)
            params, state, m = opt_lib.apply_updates(cfg, params, state, g)
        assert float(loss(params)) < 1e-3

    def test_schedule_shape(self):
        cfg = opt_lib.OptimizerConfig(peak_lr=1.0, warmup_steps=10,
                                      total_steps=100, min_lr_ratio=0.1)
        lrs = [float(opt_lib.lr_schedule(cfg, jnp.int32(s)))
               for s in [0, 5, 10, 55, 100]]
        assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-6
        assert abs(lrs[2] - 1.0) < 1e-6
        assert lrs[2] > lrs[3] > lrs[4] >= 0.1 - 1e-6

    def test_grad_clipping_bounds_update(self):
        cfg = opt_lib.OptimizerConfig(peak_lr=1e-3, warmup_steps=0,
                                      total_steps=10, clip_norm=1.0)
        params = {"w": jnp.zeros((4,))}
        state = opt_lib.init_state(params)
        huge = {"w": jnp.full((4,), 1e9)}
        _, _, m = opt_lib.apply_updates(cfg, params, state, huge)
        assert float(m["grad_norm"]) > 1e8  # reported pre-clip


class TestCheckpoint:
    def test_roundtrip_and_gc(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        tree = {"a": jnp.arange(6.0).reshape(2, 3),
                "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
        for s in (1, 2, 3):
            mgr.save(s, jax.tree.map(lambda x: x + s, tree))
        assert mgr.all_steps() == [2, 3]  # gc keeps newest 2
        got = mgr.restore(3, tree)
        np.testing.assert_allclose(np.asarray(got["a"]),
                                   np.asarray(tree["a"]) + 3)

    def test_corruption_detected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        tree = {"a": jnp.ones((4,))}
        mgr.save(1, tree)
        # corrupt the array file
        d = os.path.join(str(tmp_path), "step_000000001")
        fname = [f for f in os.listdir(d) if f.endswith(".npy")][0]
        arr = np.load(os.path.join(d, fname))
        arr[0] = 999.0
        np.save(os.path.join(d, fname), arr)
        with pytest.raises(IOError):
            mgr.restore(1, tree)

    def test_partial_tmp_dirs_ignored(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        os.makedirs(os.path.join(str(tmp_path), "step_000000009.tmp"))
        assert mgr.latest_step() is None


class TestCompression:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=15, deadline=None)
    def test_roundtrip_error_bound(self, seed):
        g = jax.random.normal(jax.random.PRNGKey(seed), (300,)) * 5
        q, s, meta = compression.compress(g)
        back = compression.decompress(q, s, meta)
        blockmax = np.abs(np.asarray(g)).max()
        assert float(jnp.abs(back - g).max()) <= blockmax / 127.0 + 1e-6

    def test_error_feedback_contraction(self):
        # over many steps, sum(sent) ~= sum(true grads): bias vanishes
        key = jax.random.PRNGKey(0)
        grads = [{"w": jax.random.normal(jax.random.fold_in(key, i), (64,))}
                 for i in range(50)]
        err = compression.init_error_state(grads[0])
        total_sent = jnp.zeros((64,))
        total_true = jnp.zeros((64,))
        for g in grads:
            sent, err = compression.compress_tree_with_feedback(g, err)
            total_sent += sent["w"]
            total_true += g["w"]
        resid = float(jnp.abs(total_sent - total_true).max())
        # residual equals the final carried error, bounded by one quant step
        assert resid <= float(jnp.abs(err["w"]).max()) + 1e-5

    def test_wire_bytes_halved_vs_bf16(self):
        tree = {"w": jnp.zeros((1024, 1024), jnp.bfloat16)}
        wire = compression.compressed_bytes(tree)
        assert wire < 0.55 * 1024 * 1024 * 2   # int8 + per-128 scales
        tree32 = {"w": jnp.zeros((1024, 1024), jnp.float32)}
        assert wire < 0.3 * 1024 * 1024 * 4    # 4x vs fp32 grads


class TestQuasiSyncCluster:
    def test_elasticity_improves_fleet_utilization(self):
        base = ClusterConfig(workers_per_group=4, n_groups=8, E=0, Q=0,
                             straggler_sigma=0.4, mean_round_ms=20)
        eq = ClusterConfig(workers_per_group=4, n_groups=8, E=3, Q=2,
                           straggler_sigma=0.4, mean_round_ms=20)
        u0 = cluster_utilization(base, n_rounds=60).pe_utilization
        u1 = cluster_utilization(eq, n_rounds=60).pe_utilization
        assert u1 > u0 + 0.03

    def test_zero_skip_reduces_time(self):
        a = ClusterConfig(workers_per_group=2, n_groups=4, E=3, Q=2,
                          zero_skip_fraction=0.0, mean_round_ms=10)
        b = ClusterConfig(workers_per_group=2, n_groups=4, E=3, Q=2,
                          zero_skip_fraction=0.5, mean_round_ms=10)
        ca = cluster_utilization(a, n_rounds=50).cycles
        cb = cluster_utilization(b, n_rounds=50).cycles
        assert cb < ca

    def test_bounded_staleness_converges_like_sync(self):
        target = jnp.asarray([1.0, -2.0, 3.0])
        def grad_fn(p, batch):
            return {"w": 2 * (p["w"] - target)}
        def update_fn(p, g):
            return {"w": p["w"] - 0.05 * g["w"]}
        # sync baseline
        p_sync = {"w": jnp.zeros(3)}
        for _ in range(120):
            p_sync = update_fn(p_sync, grad_fn(p_sync, None))
        # quasi-sync with staleness up to 3
        tr = BoundedStalenessTrainer(grad_fn, update_fn, {"w": jnp.zeros(3)},
                                     E=3, n_groups=4, seed=0)
        for _ in range(120):
            tr.step([None] * 4)
        err_sync = float(jnp.abs(p_sync["w"] - target).max())
        err_qs = float(jnp.abs(tr.params["w"] - target).max())
        assert err_qs < max(5 * err_sync, 1e-2)

    def test_version_buffer_depth_bound(self):
        tr = BoundedStalenessTrainer(lambda p, b: p, lambda p, g: p,
                                     {"w": jnp.zeros(1)}, E=2, n_groups=2)
        for _ in range(10):
            tr.step([None, None])
        assert len(tr.history) == 3  # E + 1


class TestTrainerEndToEnd:
    def _mini(self, tmp_path, total_steps=6, **kw):
        arch = get_arch("qwen2-1.5b").reduced().replace(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256, head_dim=16)
        tc = TrainConfig(total_steps=total_steps, ckpt_every=3,
                         ckpt_dir=str(tmp_path), log_every=100,
                         optimizer=opt_lib.OptimizerConfig(
                             peak_lr=1e-3, warmup_steps=2, total_steps=total_steps),
                         **kw)
        dc = DataConfig(vocab_size=256, seq_len=32, global_batch=4)
        return arch, tc, dc

    def test_loss_decreases_and_resumes(self, tmp_path):
        arch, tc, dc = self._mini(tmp_path, total_steps=6)
        tr = Trainer(arch, tc, dc)
        end_step, hist = tr.run()
        assert end_step == 6
        assert tr.ckpt.latest_step() == 6
        # resume continues from saved step
        tr2 = Trainer(arch, tc._replace_total(12) if hasattr(tc, "_replace_total")
                      else TrainConfig(**{**tc.__dict__, "total_steps": 12}), dc)
        assert tr2.start_step == 6
        end2, _ = tr2.run()
        assert end2 == 12

    def test_spike_rejection_keeps_params(self):
        arch, tc, dc = self._mini("/tmp/unused_ckpt_dir_spike")
        step_fn = make_train_step(arch, tc)
        import jax
        from repro.models import api as mapi
        params = mapi.init(jax.random.PRNGKey(0), arch)
        opt_state = opt_lib.init_state(params)
        batch = {"tokens": jnp.ones((4, 32), jnp.int32)}
        # snapshot to host first: the step donates its input buffers
        l0 = np.asarray(jax.tree.leaves(params)[0], np.float32)
        # absurdly low median forces rejection
        p2, o2, _, m = step_fn(params, opt_state, jnp.zeros((1,)), batch,
                               jnp.float32(1e-9))
        assert float(m["committed"]) == 0.0
        l2 = np.asarray(jax.tree.leaves(p2)[0], np.float32)
        np.testing.assert_array_equal(l0, l2)

    def test_grad_accum_matches_full_batch(self):
        arch, tc, dc = self._mini("/tmp/unused2", total_steps=1)
        from repro.models import api as mapi
        params = mapi.init(jax.random.PRNGKey(0), arch)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                              0, 256)}
        tc1 = TrainConfig(**{**tc.__dict__, "grad_accum": 1})
        tc2 = TrainConfig(**{**tc.__dict__, "grad_accum": 2})
        s1 = make_train_step(arch, tc1)
        s2 = make_train_step(arch, tc2)
        o = opt_lib.init_state(params)
        p1, *_ = s1(params, o, jnp.zeros((1,)), batch, jnp.float32(0))
        # params/opt were donated — re-init deterministically for the 2nd run
        params = mapi.init(jax.random.PRNGKey(0), arch)
        o = opt_lib.init_state(params)
        p2, *_ = s2(params, o, jnp.zeros((1,)), batch, jnp.float32(0))
        a = np.asarray(jax.tree.leaves(p1)[0], np.float32)
        b = np.asarray(jax.tree.leaves(p2)[0], np.float32)
        np.testing.assert_allclose(a, b, atol=2e-2, rtol=2e-2)
