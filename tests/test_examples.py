"""Every shipped example must run end-to-end (subprocess smoke tests)."""

import os
import subprocess
import sys

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable] + args, capture_output=True,
                         text=True, timeout=timeout, env=env, cwd=ROOT)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_quickstart():
    out = _run(["examples/quickstart.py"])
    assert "product=-5301 (check: -5301)" in out
    assert "kernel == jnp reference: True" in out


@pytest.mark.slow
def test_serve_lm():
    out = _run(["examples/serve_lm.py", "--tokens", "6", "--requests", "2",
                "--prompt-len", "8", "--slots", "2"])
    assert "tokens/s" in out and "deployment estimate" in out
    assert "slot utilization" in out


@pytest.mark.slow
def test_train_lm_runs_and_resumes(tmp_path):
    d = str(tmp_path / "ckpt")
    out1 = _run(["examples/train_lm.py", "--steps", "8", "--ckpt-dir", d,
                 "--fresh"])
    assert "done at step 8" in out1
    out2 = _run(["examples/train_lm.py", "--steps", "12", "--ckpt-dir", d])
    assert "resumed from checkpoint at step 8" in out2
    assert "done at step 12" in out2


@pytest.mark.slow
def test_estimate_deployment():
    out = _run(["examples/estimate_deployment.py", "--arch", "qwen2-1.5b"])
    assert "mean weight bit sparsity" in out
    assert "bp_approx" in out
