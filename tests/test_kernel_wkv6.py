"""WKV6 Pallas kernel vs the step-recurrence and chunk-parallel oracles
(interpret mode), across shape/chunk/dtype sweeps per the kernel contract."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.kernels.wkv6 import ref, wkv6
from repro.kernels.wkv6.kernel import wkv6_kernel


def _inputs(key, R, T, N, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    r = jax.random.normal(ks[0], (R, T, N), dtype)
    k = jax.random.normal(ks[1], (R, T, N), dtype)
    v = jax.random.normal(ks[2], (R, T, N), dtype)
    log_w = -jnp.exp(jax.random.normal(ks[3], (R, T, N)) - 1.0)
    u = jax.random.normal(ks[4], (R, N))
    s = jax.random.normal(ks[5], (R, N, N)) * 0.2
    return r, k, v, log_w.astype(jnp.float32), u, s


@pytest.mark.parametrize("R,T,N,chunk", [
    (2, 64, 16, 16),     # multi-chunk
    (1, 32, 32, 32),     # single chunk
    (4, 128, 64, 64),    # production head-dim tile
    (3, 96, 8, 32),      # ragged-ish dims
])
def test_kernel_matches_sequential_oracle(R, T, N, chunk):
    args = _inputs(jax.random.PRNGKey(hash((R, T, N)) % 2**31), R, T, N)
    out, s = wkv6_kernel(*args, chunk=chunk, interpret=True)
    want_out, want_s = ref.wkv6_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_out),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s),
                               atol=2e-4, rtol=2e-4)


def test_kernel_matches_chunked_oracle_cross_validation():
    args = _inputs(jax.random.PRNGKey(7), 2, 64, 16)
    out, s = wkv6_kernel(*args, chunk=32, interpret=True)
    want_out, want_s = ref.wkv6_chunked_ref(*args, chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_out),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s),
                               atol=2e-4, rtol=2e-4)


@given(st.integers(0, 2**31 - 1), st.sampled_from([16, 32]),
       st.sampled_from([jnp.float32, jnp.bfloat16]))
@settings(max_examples=8, deadline=None)
def test_property_random(seed, chunk, dtype):
    args = _inputs(jax.random.PRNGKey(seed), 2, 64, 16, dtype)
    out, s = wkv6_kernel(*args, chunk=chunk, interpret=True)
    want_out, want_s = ref.wkv6_ref(*args)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_out),
                               atol=tol, rtol=tol)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s),
                               atol=tol, rtol=tol)


def test_model_layout_wrapper_with_padding():
    """(B,S,H,N) entry point, S not a chunk multiple (padding path), must
    equal the model stack's own chunked form."""
    from repro.models import rwkv6 as m
    key = jax.random.PRNGKey(3)
    B, S, H, N = 2, 50, 3, 16
    ks = jax.random.split(key, 6)
    r, k, v = (jax.random.normal(ks[i], (B, S, H, N)) for i in range(3))
    log_w = -jnp.exp(jax.random.normal(ks[3], (B, S, H, N)))
    u = jax.random.normal(ks[4], (H, N))
    s0 = jax.random.normal(ks[5], (B, H, N, N)) * 0.1
    out, s = wkv6(r, k, v, log_w, u, s0, chunk=32, interpret=True)
    want_out, want_s = m.wkv_sequential(r, k, v, log_w, u, s0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want_out),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want_s),
                               atol=2e-4, rtol=2e-4)
