"""GPipe pipeline primitive: exact equivalence with the sequential stack,
on 4 virtual devices (subprocess, per the XLA_FLAGS rule)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"


@pytest.mark.slow
def test_pipeline_matches_sequential_and_is_differentiable():
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_apply

        S, B, D = 4, 8, 16
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (S, D, D)) / jnp.sqrt(D)
        x = jax.random.normal(jax.random.fold_in(key, 1), (B, D))

        def stage(params, h):
            return jnp.tanh(h @ params)

        # version-portable mesh construction (no AxisType on jax<0.5)
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()), ("model",))

        def pipe(w, x):
            return pipeline_apply(stage, w, x, mesh=mesh,
                                  axis_name="model", n_microbatches=4)

        got = jax.jit(pipe)(w, x)
        want = x
        for s in range(S):
            want = stage(w[s], want)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)

        # differentiable end to end (ppermute transposes correctly)
        g = jax.grad(lambda w: jnp.sum(pipe(w, x) ** 2))(w)
        g_ref = jax.grad(lambda w: jnp.sum(
            jnp.tanh(jnp.tanh(jnp.tanh(jnp.tanh(x @ w[0]) @ w[1]) @ w[2])
                     @ w[3]) ** 2))(w)
        np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                                   atol=1e-5, rtol=1e-4)
        print("OK pipeline")
    """)
