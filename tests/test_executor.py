"""Execution layer: the engine/executor split, the cache-donation contract
(per-step KV updates and admissions must alias the pooled cache buffer, not
copy it), and the logical-axis -> PartitionSpec helpers the mesh executor
places params/caches with."""

import inspect

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_arch
from repro.distributed import sharding as shd
from repro.models import api
from repro.serving import (Request, ServeConfig, ServingEngine,
                           SingleDeviceExecutor, make_cache_manager,
                           make_executor, make_serving_mesh)

jax.config.update("jax_default_matmul_precision", "float32")


def _dense_cfg(**kw):
    return get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16, **kw)


def _engine(cfg, **serve_kw):
    params = api.init(jax.random.PRNGKey(0), cfg)
    return ServingEngine(cfg, params, ServeConfig(**serve_kw))


def _cache_bytes(cache):
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))


# ---------------------------------------------------------------------------
# The refactor boundary: the engine holds NO device-shaped code
# ---------------------------------------------------------------------------

def test_engine_module_contains_no_jit_or_placement_calls():
    """The acceptance bar of the executor split: every jit trace, backend
    scope, and device placement is routed through the executor interface."""
    import repro.serving.engine as engine_mod
    src = inspect.getsource(engine_mod)
    for forbidden in ("jax.jit", "device_put", "use_matmul_backend",
                      "donate_argnums", "NamedSharding"):
        assert forbidden not in src, (
            f"serving/engine.py must not call {forbidden} directly — "
            f"that belongs to serving/executor.py")


def test_engine_exposes_executor_and_params():
    cfg = _dense_cfg(matmul_mode="bp_exact")
    engine = _engine(cfg, max_new_tokens=4)
    assert isinstance(engine.executor, SingleDeviceExecutor)
    assert engine.executor.mesh is None
    # params are the executor's placed (pre-quantized) params
    assert engine.params is engine.executor.params


def test_executor_without_params_rejects_model_entry_points():
    cfg = _dense_cfg()
    ex = make_executor(cfg)   # cache-only (what cache managers build)
    with pytest.raises(ValueError, match="without params"):
        ex.prefill({"tokens": jnp.zeros((1, 4), jnp.int32)}, 8)
    # cache ops still work
    cache = ex.zeros_cache(2, 8)
    assert jax.tree.leaves(cache)[0].shape[1] == 2


def test_make_serving_mesh_rejects_oversized_shape():
    need = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="devices"):
        make_serving_mesh((1, need))


# ---------------------------------------------------------------------------
# Donation: the decode step must not allocate a second cache-sized buffer
# ---------------------------------------------------------------------------

class TestCacheDonation:
    @staticmethod
    def _step_args(engine, n_slots=2, cache_T=8):
        cache = engine.executor.zeros_cache(n_slots, cache_T)
        step = {"tokens": jnp.zeros((n_slots, 1), jnp.int32),
                "cache_len": jnp.zeros((n_slots,), jnp.int32)}
        keys = jnp.zeros((n_slots, 2), jnp.uint32)
        counts = jnp.zeros((n_slots,), jnp.uint32)
        return cache, step, keys, counts

    def test_decode_step_aliases_cache_in_hlo(self):
        """Regression: the jitted decode step's HLO must alias every cache
        leaf input to an output (tf.aliasing_output), i.e. the per-step KV
        update runs in the donated buffer instead of materializing a second
        cache-sized array."""
        engine = _engine(_dense_cfg(), max_new_tokens=4)
        # cache big enough that activations/temps cannot mask a stray copy
        cache, step, keys, counts = self._step_args(engine, n_slots=4,
                                                    cache_T=64)
        fn = engine.executor.decode_sample_fn(0.0)
        lowered = fn.lower(cache, step, keys, counts)
        n_aliased = lowered.as_text().count("tf.aliasing_output")
        assert n_aliased >= len(jax.tree.leaves(cache)), (
            f"only {n_aliased} aliased args for "
            f"{len(jax.tree.leaves(cache))} cache leaves")
        # the whole cache rides in aliased (donated) output bytes; what the
        # step actually allocates for outputs beyond the aliased buffer is
        # just the sampled tokens — far below one cache copy.  (temp_size is
        # NOT asserted: decode attention upcasts the bf16 cache to f32 in
        # scratch, which legitimately exceeds cache bytes.)
        ma = lowered.compile().memory_analysis()
        if ma is not None and hasattr(ma, "alias_size_in_bytes"):
            assert ma.alias_size_in_bytes >= _cache_bytes(cache)
            fresh_out = ma.output_size_in_bytes - ma.alias_size_in_bytes
            assert fresh_out < _cache_bytes(cache)

    def test_decode_step_consumes_cache_buffer(self):
        engine = _engine(_dense_cfg(), max_new_tokens=4)
        cache, step, keys, counts = self._step_args(engine)
        fn = engine.executor.decode_sample_fn(0.0)
        leaves = jax.tree.leaves(cache)
        _, new_cache = fn(cache, step, keys, counts)
        assert all(l.is_deleted() for l in leaves), (
            "decode step did not donate the cache buffer")
        assert not any(l.is_deleted() for l in jax.tree.leaves(new_cache))

    def test_decode_scan_consumes_cache_buffer(self):
        engine = _engine(_dense_cfg(), max_new_tokens=4)
        B, cache_T = 2, 8
        logits, cache = engine.executor.prefill(
            {"tokens": jnp.zeros((B, 3), jnp.int32)}, cache_T)
        scan = engine.executor.decode_scan_fn(2, 0.0, None)
        leaves = jax.tree.leaves(cache)
        tok = jnp.zeros((B,), jnp.int32)
        done = jnp.zeros((B,), bool)
        out = scan(tok, cache, done, jax.random.PRNGKey(0),
                   jnp.int32(3), jnp.int32(0))
        assert all(l.is_deleted() for l in leaves)
        assert out[4].shape == (2, B)   # (chunk, B) sampled tokens

    def test_slot_insert_consumes_pool_buffer(self):
        cfg = _dense_cfg()
        engine = _engine(cfg, max_new_tokens=4)
        cm = make_cache_manager(cfg, 2, 8, executor=engine.executor)
        _, src = engine.executor.prefill(
            {"tokens": jnp.zeros((1, 4), jnp.int32)}, 8)
        slot = cm.alloc()
        pool_leaves = jax.tree.leaves(cm.cache)
        cm.insert(slot, src, length=4)
        assert all(l.is_deleted() for l in pool_leaves), (
            "slot_insert did not donate the pool buffer")
        # the prefill source survives: a group inserts it into several slots
        assert not any(l.is_deleted() for l in jax.tree.leaves(src))

    def test_paged_insert_and_copy_block_consume_pages(self):
        cfg = _dense_cfg()
        engine = _engine(cfg, max_new_tokens=4)
        cm = make_cache_manager(cfg, 2, 16, backend="paged", block_size=4,
                                executor=engine.executor)
        _, src = engine.executor.prefill(
            {"tokens": jnp.asarray([[3, 4, 5, 6]], jnp.int32)}, cm.prefill_T)
        slot = cm.alloc()
        pages_before = jax.tree.leaves(cm.pages)
        cm.insert(slot, src, length=4, tokens=[3, 4, 5, 6])
        assert all(l.is_deleted() for l in pages_before)
        pages_before = jax.tree.leaves(cm.pages)
        cm.pages = cm.executor.copy_block(cm.pages, 2, 1)
        assert all(l.is_deleted() for l in pages_before)

    def test_serve_runs_with_donation_end_to_end(self):
        # the whole continuous path over the donating executor ops stays
        # token-identical to the static path (donation is semantics-free)
        cfg = _dense_cfg()
        engine = _engine(cfg, max_new_tokens=6)
        prompts = np.asarray(jax.random.randint(
            jax.random.PRNGKey(1), (3, 5), 2, cfg.vocab_size), np.int32)
        reqs = [Request(prompt=prompts[i], max_new_tokens=6,
                        arrival_time=float(i)) for i in range(3)]
        report = engine.serve(reqs, n_slots=2)
        static = engine.generate({"tokens": jnp.asarray(prompts)},
                                 max_new_tokens=6)
        for i, r in enumerate(sorted(report.results,
                                     key=lambda r: r.request_id)):
            np.testing.assert_array_equal(r.tokens, np.asarray(static.tokens[i]))


# ---------------------------------------------------------------------------
# Logical-axis -> PartitionSpec helpers (mesh placement without a mesh)
# ---------------------------------------------------------------------------

class TestPartitionSpecHelpers:
    MESH = {"data": 2, "model": 4}

    def test_cache_pspecs_dense_decode_recipe(self):
        cfg = _dense_cfg()
        specs = api.cache_pspecs(cfg, 8, 24, self.MESH)
        # KV (L, slots, T, KH, hd): slots over "data", cache seq over "model"
        assert specs["k"] == P(None, "data", "model", None, None)
        assert specs["v"] == P(None, "data", "model", None, None)

    def test_cache_pspecs_drop_non_divisible_axes(self):
        cfg = _dense_cfg()
        specs = api.cache_pspecs(cfg, 3, 25, self.MESH)   # 3 % 2, 25 % 4
        assert specs["k"] == P(None, None, None, None, None)

    def test_cache_pspecs_int8_kv_scales(self):
        cfg = _dense_cfg(kv_cache_int8=True)
        specs = api.cache_pspecs(cfg, 8, 24, self.MESH)
        assert specs["k_scale"] == P(None, "data", "model", None)
        assert specs["v_scale"] == P(None, "data", "model", None)

    def test_cache_pspecs_recurrent_families(self):
        cfg = get_arch("rwkv6-7b").reduced().replace(
            num_layers=2, d_model=64, d_ff=128, vocab_size=128)
        specs = api.cache_pspecs(cfg, 8, 16, self.MESH)
        assert specs["x_tm"] == P(None, "data", None)
        # wkv heads axis maps to "heads" -> unsharded under decode
        assert specs["wkv"][1] == "data"

    def test_paged_cache_pspecs_fully_replicated(self):
        cfg = _dense_cfg()
        specs = api.paged_cache_pspecs(cfg, 8, 4, self.MESH)
        for s in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
            assert s == P(*([None] * len(s)))

    def test_param_pspecs_tp_over_model(self):
        cfg = _dense_cfg()
        params = api.init(jax.random.PRNGKey(0), cfg)
        specs = api.param_pspecs(params, self.MESH)
        leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        # serve recipe: at least the big 2D+ kernels TP-shard their last dim
        assert any(s and s[-1] == "model" for s in leaves if len(s))
        # and nothing uses "data" on a second-to-last dim (train-only FSDP)
        assert not any(len(s) >= 2 and s[-2] == "data" for s in leaves)

    def test_logical_pspec_matches_shard_resolution_rules(self):
        # kv_seq under decode -> "model"; under train -> gathered (None)
        assert shd.logical_pspec((4, 24, 2, 16), ("batch", "kv_seq", None,
                                                  None), "decode",
                                 self.MESH) == P("data", "model", None, None)
        assert shd.logical_pspec((4, 24, 2, 16), ("batch", "kv_seq", None,
                                                  None), "train",
                                 self.MESH)[1] is None
