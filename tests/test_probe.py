"""Hardware-cost observability: the fused bit-sparsity probe.

Pins the four acceptance bars of docs/observability.md's hw_estimate
section: (1) the fused on-device stat reductions equal the reference
``core.sparsity`` math to 1e-6 on ragged batches, (2) the disabled probe
(``NULL_PROBE``) is a strict no-op — token-identical serve output across
slab/paged x plain/speculative, (3) ``hw_estimate`` records match the
golden schema and ``ServeReport.hw_measured`` is a pure fold over them,
(4) ``probe_supported`` gates unsupported configs with a loud error."""

import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core import probe as core_probe
from repro.core import quant
from repro.core.sparsity import (N_STATS, bit_sparsity_sign_magnitude,
                                 bit_sparsity_twos_complement,
                                 per_layer_stats, sm_bit_stats,
                                 stats_to_rates, value_sparsity)
from repro.models import api
from repro.models.layers import quantize_dense_params
from repro.serving import (NULL_PROBE, PROBE_METHODS, Request,
                           SchedulerConfig, ServeConfig, ServingEngine,
                           SparsityProbe, Telemetry, probe_supported,
                           read_jsonl, reduce_stream)
from repro.serving.telemetry import SCHEMA_VERSION, STEP_SCHEMA

jax.config.update("jax_default_matmul_precision", "float32")


def _dense_cfg(**kw):
    return get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16, **kw)


def _quantized(cfg, seed=0):
    params = api.init(jax.random.PRNGKey(seed), cfg)
    return (cfg.replace(matmul_mode="bp_exact", kv_cache_int8=True),
            quantize_dense_params(params))


def _engine(q_cfg, q_params, backend="slab", draft="none", probe=None,
            telemetry=None, max_new=6):
    return ServingEngine(q_cfg, q_params, ServeConfig(
        max_new_tokens=max_new, temperature=0.0, cache_backend=backend,
        block_size=4, draft=draft, num_draft_tokens=3,
        probe=probe, telemetry=telemetry))


def _prompts(cfg, n, seed=1):
    """Repeated-phrase prompts (the prompt-lookup drafter needs material)."""
    key = jax.random.PRNGKey(seed)
    phrase = np.asarray(jax.random.randint(key, (4,), 2, cfg.vocab_size),
                        np.int32)
    out = []
    for i in range(n):
        uniq = np.asarray(
            jax.random.randint(jax.random.PRNGKey(seed + 10 + i), (2 + i,),
                               2, cfg.vocab_size), np.int32)
        out.append(np.concatenate([phrase, phrase, uniq, phrase]))
    return out


def _serve(eng, prompts, max_new=6):
    reqs = [Request(prompt=p, max_new_tokens=max_new, arrival_time=0.0)
            for p in prompts]
    return eng.serve(reqs, n_slots=len(prompts), cache_T=32, num_blocks=40,
                     sched_cfg=SchedulerConfig(lead_window=2))


def _tokens_in_order(report):
    return [np.asarray(r.tokens)
            for r in sorted(report.results, key=lambda r: r.request_id)]


# ---------------------------------------------------------------------------
# Fused stat reductions vs the reference sparsity math
# ---------------------------------------------------------------------------

class TestFusedStats:
    def test_sm_bit_stats_equals_reference(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 7, 33))
        x_q = quant.quantize(x, quant.compute_scale(x, axis=(-1,)))
        stats = np.asarray(sm_bit_stats(x_q), np.float64)
        assert stats.shape == (N_STATS,)
        assert stats[1] == x_q.size
        ref_bs = float(bit_sparsity_sign_magnitude(x_q))
        ref_vs = float(value_sparsity(x_q))
        assert abs(stats[0] / (7.0 * stats[1]) - ref_bs) < 1e-6
        assert abs(stats[2] / stats[1] - ref_vs) < 1e-6

    def test_per_layer_stats_equals_per_layer_loop(self):
        q = jax.random.randint(jax.random.PRNGKey(1), (4, 5, 9), -127, 128,
                               dtype=jnp.int32).astype(jnp.int8)
        rows = np.asarray(per_layer_stats(q), np.float64)
        assert rows.shape == (4, N_STATS)
        for i in range(4):
            np.testing.assert_allclose(
                rows[i], np.asarray(sm_bit_stats(q[i]), np.float64),
                atol=1e-6)

    def test_stats_to_rates_handles_empty_rows(self):
        bs, vs = stats_to_rates(jnp.zeros((2, N_STATS)))
        assert float(bs[0]) == 0.0 and float(vs[1]) == 0.0

    def test_jitted_tap_matches_eager_tap_on_ragged_batch(self):
        """The probed prefill's fused in-scan reductions must equal the
        same hooks run eagerly — element-weighted, across a ragged batch
        whose rows carry different real lengths."""
        cfg, params = _quantized(_dense_cfg())
        tokens = np.array(
            jax.random.randint(jax.random.PRNGKey(3), (2, 12), 2,
                               cfg.vocab_size), np.int32)
        # a ragged batch: row 1 is padding beyond length 5
        tokens[1, 5:] = 0
        batch = {"tokens": jnp.asarray(tokens)}

        def tapped(fn):
            with core_probe.probe_tap():
                fn()
                return np.asarray(core_probe.collect(), np.float64)

        lens = jnp.asarray([12, 5], jnp.int32)
        eager = tapped(lambda: api.prefill(params, cfg, batch, 16,
                                           prompt_lens=lens))
        jitted_fn = jax.jit(
            lambda b: (api.prefill(params, cfg, b, 16, prompt_lens=lens),
                       core_probe.collect())[1])
        with core_probe.probe_tap():
            jitted = np.asarray(jitted_fn(batch), np.float64)
        assert eager.shape[0] >= cfg.num_layers
        np.testing.assert_allclose(jitted, eager, rtol=1e-6, atol=1e-6)
        bs = eager[:, 0].sum() / (7.0 * eager[:, 1].sum())
        assert 0.0 < bs < 1.0

    def test_untapped_hooks_are_noops(self):
        assert not core_probe.tap_active()
        core_probe.record_activation(jnp.ones((2, 2)))   # must not raise
        assert core_probe.collect() is None
        assert np.all(np.asarray(core_probe.drain_layer()) == 0.0)


class TestVectorizedTwosComplement:
    def test_matches_scalar_popcount_reference(self):
        rng = np.random.default_rng(0)
        q = rng.integers(-128, 128, size=257).astype(np.int8)
        ref = np.mean([(8 - bin(int(v) & 0xFF).count("1")) / 8.0
                       for v in q])
        got = float(bit_sparsity_twos_complement(jnp.asarray(q)))
        assert abs(got - ref) < 1e-6

    def test_extremes(self):
        assert float(bit_sparsity_twos_complement(
            jnp.zeros((5,), jnp.int8))) == 1.0
        assert float(bit_sparsity_twos_complement(
            jnp.full((5,), -1, jnp.int8))) == 0.0


# ---------------------------------------------------------------------------
# Disabled probe is a strict no-op; enabled probe never changes tokens
# ---------------------------------------------------------------------------

class TestTokenIdentity:
    def test_null_probe_is_the_default(self):
        cfg, params = _quantized(_dense_cfg())
        eng = _engine(cfg, params)
        loop = eng.make_loop([Request(prompt=_prompts(cfg, 1)[0],
                                      max_new_tokens=2)], n_slots=1,
                             cache_T=32)
        assert loop.probe is NULL_PROBE
        assert not NULL_PROBE.enabled
        assert not NULL_PROBE.should_sample(0)

    @pytest.mark.parametrize("backend", ["slab", "paged"])
    @pytest.mark.parametrize("draft", ["none", "prompt_lookup"])
    def test_probe_on_vs_off_token_identity(self, backend, draft):
        cfg, params = _quantized(_dense_cfg())
        prompts = _prompts(cfg, 3)
        base = _tokens_in_order(
            _serve(_engine(cfg, params, backend=backend, draft=draft),
                   prompts))
        probed = _tokens_in_order(
            _serve(_engine(cfg, params, backend=backend, draft=draft,
                           probe=SparsityProbe(probe_every=2, n_mc=2000)),
                   prompts))
        assert len(base) == len(probed) == 3
        for a, b in zip(base, probed):
            assert a.shape == b.shape and (a == b).all()


# ---------------------------------------------------------------------------
# hw_estimate records: golden schema + report == stream reduction
# ---------------------------------------------------------------------------

class TestHwEstimateRecords:
    def _probed_serve(self, tmp_path, probe_every=1):
        cfg, params = _quantized(_dense_cfg())
        tel = Telemetry(metrics_path=str(tmp_path / "m.jsonl"))
        eng = _engine(cfg, params, probe=SparsityProbe(
            probe_every=probe_every, n_mc=2000), telemetry=tel)
        report = _serve(eng, _prompts(cfg, 2))
        tel.close()
        return cfg, report, read_jsonl(str(tmp_path / "m.jsonl"))

    def test_golden_schema_and_value_ranges(self, tmp_path):
        cfg, report, records = self._probed_serve(tmp_path)
        hw = [r for r in records if r["kind"] == "hw_estimate"]
        assert hw, "probe_every=1 must emit hw_estimate records"
        assert {r["phase"] for r in hw} >= {"prefill", "decode"}
        for r in hw:
            assert STEP_SCHEMA["hw_estimate"] <= set(r)
            assert r["schema"] == SCHEMA_VERSION
            assert r["n_layers"] == cfg.num_layers
            assert 0.0 < r["act_bit_sparsity"] < 1.0
            assert 0.0 <= r["act_value_sparsity"] < 1.0
            assert 0.0 < r["weight_bit_sparsity"] < 1.0
            assert len(r["per_layer_act_bit_sparsity"]) >= cfg.num_layers
            assert set(r["cycles"]) == set(PROBE_METHODS)
            assert all(c > 0 for c in r["cycles"].values())
            assert all(e > 0 for e in r["mac_energy_pj"].values())
            assert 0.0 < r["array_utilization"] <= 1.0

    def test_probe_every_subsamples_decode_steps(self, tmp_path):
        _, _, records = self._probed_serve(tmp_path, probe_every=2)
        decode_steps = [r for r in records if r["kind"] == "decode"]
        hw_decode = [r for r in records
                     if r["kind"] == "hw_estimate"
                     and r["phase"] == "decode"]
        assert 0 < len(hw_decode) <= len(decode_steps) // 2 + 1

    def test_report_equals_stream_reduction(self, tmp_path):
        _, report, records = self._probed_serve(tmp_path)
        s = reduce_stream(records)
        hw = report.hw_measured
        assert hw is not None and s.n_hw_samples == hw["n_samples"] > 0
        assert hw["act_bit_sparsity"] == pytest.approx(
            s.hw_act_bit_sparsity / s.n_hw_samples)
        assert hw["act_value_sparsity"] == pytest.approx(
            s.hw_act_value_sparsity / s.n_hw_samples)
        assert hw["weight_bit_sparsity"] == pytest.approx(
            s.hw_weight_bit_sparsity / s.n_hw_samples)
        assert hw["array_utilization"] == pytest.approx(
            s.hw_array_utilization / s.n_hw_samples)
        for m in PROBE_METHODS:
            assert hw["cycles"][m] == pytest.approx(
                s.hw_cycles[m] / s.n_hw_samples)
            assert hw["mac_energy_pj"][m] == pytest.approx(
                s.hw_mac_energy_pj[m] / s.n_hw_samples)

    def test_weight_profile_is_element_weighted_reference(self):
        cfg, params = _quantized(_dense_cfg())
        eng = _engine(cfg, params, probe=SparsityProbe(probe_every=1,
                                                       n_mc=2000))
        prof = eng.weight_sparsity_profile()
        assert len(prof["per_layer_bit_sparsity"]) == cfg.num_layers
        zero_bits = total = zero_vals = 0.0
        for leaf in jax.tree.leaves(eng.params):
            if getattr(leaf, "dtype", None) != jnp.int8:
                continue
            s = np.asarray(sm_bit_stats(leaf), np.float64)
            zero_bits, total, zero_vals = (zero_bits + s[0], total + s[1],
                                           zero_vals + s[2])
        assert total > 0
        assert prof["bit_sparsity"] == pytest.approx(
            zero_bits / (7.0 * total), abs=1e-9)
        assert prof["value_sparsity"] == pytest.approx(
            zero_vals / total, abs=1e-9)


# ---------------------------------------------------------------------------
# Unsupported configs fail loudly, never silently un-probed
# ---------------------------------------------------------------------------

class TestProbeSupport:
    def test_bf16_mode_is_unsupported(self):
        cfg = _dense_cfg()                   # matmul_mode stays bf16
        assert not probe_supported(cfg)
        params = api.init(jax.random.PRNGKey(0), cfg)
        eng = _engine(cfg, params, probe=SparsityProbe(probe_every=1,
                                                       n_mc=2000))
        with pytest.raises(ValueError, match="probe"):
            eng.serve([Request(prompt=np.arange(2, 8, dtype=np.int32),
                               max_new_tokens=2)], n_slots=1, cache_T=16)

    def test_bp_modes_supported(self):
        assert probe_supported(_dense_cfg(matmul_mode="bp_exact"))
        assert probe_supported(_dense_cfg(matmul_mode="bp_approx"))
