"""Pallas BitParticle matmul kernel vs pure-jnp oracle (interpret mode).

Per the kernel contract: sweep shapes (aligned and ragged), modes and dtypes;
integer outputs must match the oracle EXACTLY; fused-dequant outputs must be
allclose to the f32 reference.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.kernels.bitparticle_matmul import bp_matmul, ref

I = lambda *s: s  # noqa: E731


def _rand_q(key, shape):
    return jax.random.randint(key, shape, -127, 128, dtype=jnp.int32).astype(jnp.int8)


SHAPES = [
    (8, 128, 128),      # single block
    (16, 256, 384),     # multi-block in N/K
    (256, 256, 256),    # exact default blocks
    (5, 33, 17),        # ragged everything (padding path)
    (1, 128, 1),        # degenerate edges
    (300, 520, 260),    # multi-block with padding
]


@pytest.mark.parametrize("approx", [False, True], ids=["exact", "approx"])
@pytest.mark.parametrize("m,k,n", SHAPES)
def test_kernel_int_matches_ref(m, k, n, approx):
    key = jax.random.PRNGKey(hash((m, k, n, approx)) % 2**31)
    a = _rand_q(key, (m, k))
    w = _rand_q(jax.random.fold_in(key, 1), (k, n))
    got = bp_matmul(a, w, approx=approx, interpret=True,
                    block_m=128, block_n=128, block_k=128)
    want = ref.bp_matmul_ref(a, w, "bp_approx" if approx else "bp_exact")
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("approx", [False, True], ids=["exact", "approx"])
def test_kernel_vs_elementwise_hardware_oracle(approx):
    # cross-validates kernel AND algebraic ref against the literal 4x4-IR
    # hardware reconstruction.
    key = jax.random.PRNGKey(7)
    a = _rand_q(key, (6, 40))
    w = _rand_q(jax.random.fold_in(key, 3), (40, 9))
    got = bp_matmul(a, w, approx=approx, interpret=True,
                    block_m=8, block_n=128, block_k=128)
    want = ref.bp_matmul_elementwise_oracle(
        a.astype(jnp.int32), w.astype(jnp.int32),
        "bp_approx" if approx else "bp_exact")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("approx", [False, True], ids=["exact", "approx"])
def test_fused_dequant_epilogue(approx):
    key = jax.random.PRNGKey(11)
    m, k, n = 24, 96, 48
    a = _rand_q(key, (m, k))
    w = _rand_q(jax.random.fold_in(key, 1), (k, n))
    sa = jax.random.uniform(jax.random.fold_in(key, 2), (m,), minval=0.01, maxval=0.1)
    sw = jax.random.uniform(jax.random.fold_in(key, 3), (n,), minval=0.001, maxval=0.01)
    got = bp_matmul(a, w, sa, sw, approx=approx, interpret=True,
                    block_m=8, block_n=128, block_k=128)
    want = ref.bp_matmul_dequant_ref(a, w, sa.reshape(-1, 1), sw.reshape(1, -1),
                                     "bp_approx" if approx else "bp_exact")
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_leading_batch_dims():
    key = jax.random.PRNGKey(5)
    a = _rand_q(key, (2, 3, 64))
    w = _rand_q(jax.random.fold_in(key, 1), (64, 32))
    got = bp_matmul(a, w, interpret=True, block_m=8, block_n=128, block_k=128)
    want = ref.bp_matmul_ref(a.reshape(6, 64), w).reshape(2, 3, 32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(0, 2**31 - 1),
       st.sampled_from([1, 7, 64]), st.sampled_from([13, 128, 200]),
       st.sampled_from([3, 128, 140]), st.booleans())
@settings(max_examples=12, deadline=None)
def test_property_random_shapes(seed, m, k, n, approx):
    key = jax.random.PRNGKey(seed)
    a = _rand_q(key, (m, k))
    w = _rand_q(jax.random.fold_in(key, 1), (k, n))
    got = bp_matmul(a, w, approx=approx, interpret=True,
                    block_m=64, block_n=128, block_k=128)
    want = ref.bp_matmul_ref(a, w, "bp_approx" if approx else "bp_exact")
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pick_block_minimizes_padded_work():
    from repro.kernels.bitparticle_matmul.ops import _pick_block, _round_up
    # a dim just past the preferred block must NOT pad to 2x the work:
    # 257 under pref=256 picks 128 (padded 384), not 256 (padded 512)
    assert _pick_block(257, 256, 128) == 128
    # exact multiples keep the largest block (fewest grid steps)
    assert _pick_block(256, 256, 128) == 256
    assert _pick_block(512, 256, 128) == 256
    # small dims: one minimal aligned block
    assert _pick_block(5, 256, 8) == 8
    assert _pick_block(33, 256, 8) == 40
    # the chosen block is always optimal among aligned candidates
    for dim in (1, 7, 129, 200, 257, 300, 511, 520):
        for align, pref in ((8, 256), (128, 256), (128, 128)):
            b = _pick_block(dim, pref, align)
            assert b % align == 0 and b <= max(pref, align)
            best = min(_round_up(dim, c) for c in range(align, pref + 1, align))
            assert _round_up(dim, b) == best, (dim, align, pref, b)


def test_approx_differs_but_is_close():
    # sanity: approx is not a no-op, and its magnitude error per MAC <= 81*K
    key = jax.random.PRNGKey(13)
    a = _rand_q(key, (16, 64))
    w = _rand_q(jax.random.fold_in(key, 1), (64, 16))
    exact = bp_matmul(a, w, approx=False, interpret=True, block_m=8)
    approx = bp_matmul(a, w, approx=True, interpret=True, block_m=8)
    diff = np.abs(np.asarray(exact) - np.asarray(approx))
    assert diff.max() > 0
    assert diff.max() <= 81 * 64
