"""Quasi-sync MAC-array simulator: invariants + paper-claim trend tests."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core.array_sim import ArrayConfig, SimResult, build_op_costs, run_experiment, simulate

SMALL = dict(rows=4, cols=8)


def _cfg(E, Q, **kw):
    return ArrayConfig(E=E, Q=Q, **{**SMALL, **kw})


def _rand_costs(rng, cfg, steps, p_zero=0.0):
    c = rng.integers(1, 5, size=(cfg.rows, cfg.cols, steps)).astype(np.int32)
    if p_zero:
        c[rng.random(c.shape) < p_zero] = 0
    return c


class TestInvariants:
    def test_strict_sync_equals_analytic(self):
        # E0Q0: the whole array advances in lock-step; cycles = sum of
        # per-step global maxima.
        rng = np.random.default_rng(0)
        cfg = _cfg(0, 0)
        costs = _rand_costs(rng, cfg, 50)
        res = simulate(costs, cfg)
        want = int(np.maximum(costs.max(axis=(0, 1)), 1).sum())
        assert res.cycles == want

    @given(st.integers(0, 10_000), st.sampled_from([0, 1, 3]),
           st.sampled_from([0, 1, 2]), st.floats(0.0, 0.5))
    @settings(max_examples=20, deadline=None)
    def test_bounds_and_conservation(self, seed, E, Q, p_zero):
        rng = np.random.default_rng(seed)
        cfg = _cfg(E, Q)
        costs = _rand_costs(rng, cfg, 30, p_zero)
        res = simulate(costs, cfg)
        # every op must execute somewhere: cycles >= busiest PE's total work
        assert res.cycles >= costs.sum(axis=-1).max()
        # a column accepts at most one step per cycle
        assert res.cycles >= 30
        assert 0.0 <= res.pe_utilization <= 1.0
        assert res.max_observed_divergence <= max(E, 0)
        # total busy cycles == total work (nothing lost or duplicated)
        busy = res.pe_utilization * res.cycles * cfg.rows * cfg.cols
        assert abs(busy - costs.sum()) < 1e-6

    def test_all_zero_costs_run_one_cycle_per_step(self):
        cfg = _cfg(3, 2)
        costs = np.zeros((cfg.rows, cfg.cols, 20), np.int32)
        res = simulate(costs, cfg)
        assert res.cycles == 20 and res.pe_utilization == 0.0

    def test_divergence_bound_is_tight_when_one_column_stalls(self):
        cfg = _cfg(2, 1)
        costs = np.ones((cfg.rows, cfg.cols, 30), np.int32)
        costs[:, 0, :] = 4   # column 0 is 4x slower
        res = simulate(costs, cfg)
        assert res.max_observed_divergence == 2


class TestPaperTrends:
    """Section IV-B3 conclusions, on the real generator (reduced sizes)."""

    @pytest.fixture(scope="class")
    def grid(self):
        out = {}
        for E, Q in [(0, 0), (0, 2), (3, 0), (3, 2)]:
            out[(E, Q)] = run_experiment(
                0, ArrayConfig(E=E, Q=Q), n_steps=160, bit_sparsity=0.7)
        return out

    def test_elasticity_improves_utilization(self, grid):
        base = grid[(0, 0)].pe_utilization
        assert grid[(0, 2)].pe_utilization > base   # intra-group alone helps
        assert grid[(3, 0)].pe_utilization > base   # inter-group alone helps
        best = grid[(3, 2)].pe_utilization
        assert best > grid[(0, 2)].pe_utilization
        assert best > grid[(3, 0)].pe_utilization   # combining is best

    def test_intra_group_beats_inter_group_at_typical_sparsity(self, grid):
        # paper: for bs in [0.5, 0.8], EuQy(intra) > EuQ0(inter)
        assert grid[(0, 2)].pe_utilization > grid[(3, 0)].pe_utilization

    def test_cycles_per_step_improves(self, grid):
        assert (grid[(3, 2)].avg_cycles_per_step
                < grid[(0, 0)].avg_cycles_per_step)

    def test_zero_filtering_reduces_cycles_per_step(self):
        slow = run_experiment(1, ArrayConfig(E=3, Q=2, zero_filter=False),
                              n_steps=160, bit_sparsity=0.65,
                              a_value_sparsity=0.6)
        fast = run_experiment(1, ArrayConfig(E=3, Q=2, zero_filter=True),
                              n_steps=160, bit_sparsity=0.65,
                              a_value_sparsity=0.6)
        assert fast.avg_cycles_per_step < slow.avg_cycles_per_step

    def test_higher_bit_sparsity_is_faster(self):
        lo = run_experiment(2, ArrayConfig(E=3, Q=2), 120, bit_sparsity=0.5)
        hi = run_experiment(2, ArrayConfig(E=3, Q=2), 120, bit_sparsity=0.9)
        assert hi.avg_cycles_per_step < lo.avg_cycles_per_step


class TestCostBuilder:
    def test_shapes_and_range(self):
        cfg = ArrayConfig(E=3, Q=2)
        import jax
        costs = build_op_costs(jax.random.PRNGKey(0), cfg, 40, 0.6)
        assert costs.shape == (16, 32, 40)
        assert costs.min() >= 1 and costs.max() <= 4

    def test_zero_filter_zeroes_value_sparse_ops(self):
        cfg = ArrayConfig(E=3, Q=2, zero_filter=True)
        import jax
        costs = build_op_costs(jax.random.PRNGKey(0), cfg, 40, 0.6,
                               a_value_sparsity=0.5)
        assert (costs == 0).mean() > 0.3

    def test_weight_shared_across_columns(self):
        # row-r step-s weight identical for all columns => if a weight is
        # zero, with zero_filter every column's op at that (r, s) is free.
        cfg = ArrayConfig(E=0, Q=0, zero_filter=True)
        import jax
        costs = build_op_costs(jax.random.PRNGKey(3), cfg, 60, 0.5,
                               w_value_sparsity=0.9)
        zero_rows = (costs == 0).all(axis=1)   # (R, S) — same across cols
        assert zero_rows.any()
