"""Block-pool invariants (hypothesis property tests).

The properties the paged subsystem stands on:

  * no double-free — over-releasing a block always raises;
  * refcount consistency — every block's refcount equals the number of live
    block-table references across occupied slots (cached prefix blocks sit
    at refcount 0 until re-adopted);
  * free/cached/live partition — every allocatable id is in exactly one of
    the free list, the LRU cached set, or the live set;
  * prefix-hit blocks are never written in place — adopting a shared block
    must not change its page content (copy-on-write covers divergent
    writes);
  * preempted requests replay to identical tokens — a pool too small for
    the workload forces preemption-and-requeue, and the outputs still match
    the slab backend bit-for-bit.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.configs.base import get_arch
from repro.models import api
from repro.serving import (NoFreeBlocks, PagedCacheManager, Request,
                           SchedulerConfig, ServeConfig, ServingEngine)
from repro.serving.block_pool import TRASH_BLOCK, BlockPool

jax.config.update("jax_default_matmul_precision", "float32")

BS = 4            # block size used throughout
CACHE_T = 16      # 4 blocks per sequence


def _cfg():
    return get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=32, d_ff=64, vocab_size=64, head_dim=8,
        num_heads=2, num_kv_heads=1)


def _rand_src_cache(cfg, B, T, seed):
    """Random prefill-shaped cache (no model run needed for pool tests)."""
    specs = api.cache_specs(cfg, B, T)
    leaves, treedef = jax.tree.flatten(specs)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(treedef, [
        jax.random.normal(k, s.shape).astype(s.dtype)
        for k, s in zip(keys, leaves)])


def _check_refcounts(cm: PagedCacheManager):
    """refcount[b] == number of live table references to b, for every b."""
    refs = np.zeros(cm.pool.num_blocks, np.int64)
    for s in range(cm.n_slots):
        if cm._occupied[s]:
            k = int(cm._n_blocks_of[s])
            for bid in cm.tables[s, :k]:
                refs[int(bid)] += 1
    assert refs[TRASH_BLOCK] == 0 or True  # trash never refcounted
    live = np.asarray(cm.pool.refcount)
    np.testing.assert_array_equal(live[1:], refs[1:])
    # free / cached / live partition the allocatable ids
    free = set(cm.pool._free)
    cached = set(cm.pool._cached)
    live_ids = {b for b in range(1, cm.pool.num_blocks) if live[b] > 0}
    assert not (free & cached) and not (free & live_ids) \
        and not (cached & live_ids)
    assert free | cached | live_ids == set(range(1, cm.pool.num_blocks))


# ---------------------------------------------------------------------------
# BlockPool accounting
# ---------------------------------------------------------------------------

class TestBlockPool:
    def test_double_free_raises(self):
        pool = BlockPool(num_blocks=4, block_size=BS)
        b = pool.alloc()
        pool.decref(b)
        with pytest.raises(ValueError):
            pool.decref(b)

    def test_trash_block_never_allocated_or_referenced(self):
        pool = BlockPool(num_blocks=4, block_size=BS)
        got = {pool.alloc() for _ in range(3)}
        assert TRASH_BLOCK not in got
        with pytest.raises(NoFreeBlocks):
            pool.alloc()
        with pytest.raises(ValueError):
            pool.incref(TRASH_BLOCK)

    def test_registered_block_is_cached_then_lru_evicted(self):
        pool = BlockPool(num_blocks=3, block_size=BS)
        a, b = pool.alloc(), pool.alloc()
        pool.register(None, (1, 2, 3, 4), a)
        pool.decref(a)               # cached, not freed
        assert pool.match_prefix([1, 2, 3, 4])[0] == [a]
        pool.decref(b)               # plain free
        assert pool.alloc() == b     # free list first
        assert pool.alloc() == a     # then LRU eviction of the cached block
        assert pool.n_evictions == 1
        assert pool.match_prefix([1, 2, 3, 4])[0] == []   # trie entry gone

    def test_partial_suffix_match(self):
        pool = BlockPool(num_blocks=4, block_size=BS)
        a = pool.alloc()
        pool.register(None, (5, 6, 7, 8), a)
        full, partial = pool.match_prefix([5, 6])
        assert full == [] and partial == (a, 2)
        # a full-block miss disables partial matching deeper in
        full, partial = pool.match_prefix([9, 9, 9, 9, 5, 6])
        assert full == [] and partial is None

    @given(st.lists(st.integers(0, 2), min_size=1, max_size=40),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_alloc_free_never_leaks(self, ops, seed):
        """Random alloc/decref/incref traffic: the pool never loses or
        duplicates a block id."""
        rng = np.random.default_rng(seed)
        pool = BlockPool(num_blocks=6, block_size=BS)
        live = []
        for op in ops:
            if op == 0:                      # alloc
                try:
                    live.append(pool.alloc())
                except NoFreeBlocks:
                    assert pool.n_free == 0
            elif op == 1 and live:           # decref
                i = int(rng.integers(len(live)))
                pool.decref(live.pop(i))
            elif op == 2 and live:           # incref + decref (share cycle)
                b = live[int(rng.integers(len(live)))]
                pool.incref(b)
                pool.decref(b)
            counts = {}
            for b in live:
                counts[b] = counts.get(b, 0) + 1
            for b, c in counts.items():
                assert pool.refcount[b] == c
            assert len(pool._free) + pool.n_live == pool.num_blocks - 1


# ---------------------------------------------------------------------------
# PagedCacheManager invariants under insert/free/append traffic
# ---------------------------------------------------------------------------

class TestPagedManagerInvariants:
    @given(st.lists(st.tuples(st.integers(1, 12),      # prompt length
                              st.booleans()),          # reuse a seen prompt
                    min_size=1, max_size=10),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_refcounts_match_live_references(self, specs, seed):
        cfg = _cfg()
        rng = np.random.default_rng(seed)
        cm = PagedCacheManager(cfg, n_slots=3, cache_T=CACHE_T,
                               block_size=BS, num_blocks=20)
        src = _rand_src_cache(cfg, 1, cm.prefill_T, seed)
        seen = []
        for plen, reuse in specs:
            if cm.n_free == 0:
                s = int(rng.choice(np.flatnonzero(cm._occupied)))
                cm.free(s)
                _check_refcounts(cm)
            if reuse and seen:
                prompt = seen[int(rng.integers(len(seen)))]
                prompt = prompt[:plen] if len(prompt) >= plen else prompt
            else:
                prompt = rng.integers(2, 40, size=plen).tolist()
            seen.append(prompt)
            slot = cm.alloc()
            try:
                cm.insert(slot, src, len(prompt), tokens=prompt)
            except NoFreeBlocks:
                cm.free(slot)
            _check_refcounts(cm)
        for s in np.flatnonzero(cm._occupied):
            cm.free(int(s))
        _check_refcounts(cm)
        assert cm.pool.n_live == 0

    def test_prefix_hit_blocks_never_written_in_place(self):
        cfg = _cfg()
        cm = PagedCacheManager(cfg, n_slots=2, cache_T=CACHE_T,
                               block_size=BS, num_blocks=16)
        src_a = _rand_src_cache(cfg, 1, cm.prefill_T, 1)
        src_b = _rand_src_cache(cfg, 1, cm.prefill_T, 2)   # different values
        prompt = list(range(2, 2 + 8))                     # 2 full blocks
        sa = cm.alloc()
        cm.insert(sa, src_a, len(prompt), tokens=prompt)
        shared = [int(b) for b in cm.tables[sa, :2]]
        before = [np.asarray(cm.pages["k"][:, b]).copy() for b in shared]
        sb = cm.alloc()
        cm.insert(sb, src_b, len(prompt), tokens=prompt)
        assert [int(b) for b in cm.tables[sb, :2]] == shared   # adopted
        assert cm.pool.refcount[shared[0]] == 2
        for b, want in zip(shared, before):
            np.testing.assert_array_equal(
                np.asarray(cm.pages["k"][:, b]), want)

    def test_partial_hit_copy_on_write(self):
        cfg = _cfg()
        cm = PagedCacheManager(cfg, n_slots=2, cache_T=CACHE_T,
                               block_size=BS, num_blocks=16)
        src = _rand_src_cache(cfg, 1, cm.prefill_T, 3)
        long_prompt = list(range(2, 2 + 8))     # 2 full registered blocks
        sa = cm.alloc()
        cm.insert(sa, src, 8, tokens=long_prompt)
        short = long_prompt[:6]                 # 1 full + partial suffix of 2
        sb = cm.alloc()
        cm.insert(sb, src, 6, tokens=short)
        shared_tail = int(cm.tables[sb, 1])
        assert shared_tail == int(cm.tables[sa, 1])     # partial adoption
        before = np.asarray(cm.pages["k"][:, shared_tail]).copy()
        # first divergent append: must CoW, not write the shared block
        failed = cm.prepare_append([sb])
        assert failed is None
        assert int(cm.tables[sb, 1]) != shared_tail
        assert cm.pool.n_cow == 1
        np.testing.assert_array_equal(
            np.asarray(cm.pages["k"][:, shared_tail]), before)

    @given(st.lists(st.tuples(st.integers(0, 3),       # op kind
                              st.integers(1, 12),      # prompt len / span
                              st.booleans()),          # reuse a seen prompt
                    min_size=1, max_size=14),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_spec_rollback_interleaved_invariants(self, ops, seed):
        """Speculative append/rollback interleaved with admission,
        preemption (free) and prefix sharing: never leaks, never
        double-frees, never rewinds into (or mutates) a shared block —
        checked against the full refcount/partition invariant after every
        operation, plus byte-identity of every registered shared block."""
        cfg = _cfg()
        rng = np.random.default_rng(seed)
        cm = PagedCacheManager(cfg, n_slots=3, cache_T=CACHE_T,
                               block_size=BS, num_blocks=14)
        src = _rand_src_cache(cfg, 1, cm.prefill_T, seed)
        seen = []
        # bid -> (trie key, page bytes) at registration time; the key pins
        # identity across LRU-evict-then-re-register of the same block id
        shared_content = {}

        def snapshot_registered():
            for bid, key in list(cm.pool._block_key.items()):
                cur = shared_content.get(bid)
                if cur is None or cur[0] != key:
                    shared_content[bid] = (key, np.asarray(
                        cm.pages["k"][:, bid]).copy())

        def check_shared_untouched():
            for bid, (key, want) in list(shared_content.items()):
                if cm.pool._block_key.get(bid) == key:
                    np.testing.assert_array_equal(
                        np.asarray(cm.pages["k"][:, bid]), want,
                        err_msg=f"registered block {bid} mutated in place")
                else:
                    del shared_content[bid]   # evicted: content reusable

        for kind, n, reuse in ops:
            occupied = np.flatnonzero(cm._occupied)
            if kind == 0:                    # admit (insert, prefix-shared)
                if cm.n_free == 0:
                    continue
                if reuse and seen:
                    prompt = seen[int(rng.integers(len(seen)))][:max(n, 1)]
                else:
                    prompt = rng.integers(2, 30, size=n).tolist()
                seen.append(prompt)
                slot = cm.alloc()
                try:
                    cm.insert(slot, src, len(prompt), tokens=prompt)
                    snapshot_registered()
                except NoFreeBlocks:
                    cm.free(slot)
            elif kind == 1 and len(occupied):  # speculative append + commit
                slot = int(rng.choice(occupied))
                span = min(n, CACHE_T - int(cm.lengths[slot]))
                if span < 1:
                    continue
                if cm.prepare_append([slot], [span]) is not None:
                    continue                 # pool dry: skip (engine would
                                             # preempt; covered by kind 3)
                # verify writes the span, then commits a random prefix
                commit = int(rng.integers(1, span + 1))
                cm.advance([slot], [commit])
                cm.release_tail(slot)
            elif kind == 2 and len(occupied):  # rejection: commit nothing
                slot = int(rng.choice(occupied))
                if int(cm.lengths[slot]) >= CACHE_T:
                    continue
                if cm.prepare_append([slot], [min(n, 4)]) is not None:
                    continue
                cm.release_tail(slot)        # lengths unchanged: full rewind
            elif kind == 3 and len(occupied):  # preemption / finish
                cm.free(int(rng.choice(occupied)))
            _check_refcounts(cm)
            check_shared_untouched()
        for s in np.flatnonzero(cm._occupied):
            cm.free(int(s))
        _check_refcounts(cm)
        assert cm.pool.n_live == 0           # no leaked blocks

    def test_vectorized_advance_matches_loop(self):
        cfg = _cfg()
        cm = PagedCacheManager(cfg, n_slots=4, cache_T=CACHE_T,
                               block_size=BS, num_blocks=24)
        slots = [cm.alloc() for _ in range(3)]
        cm.lengths[slots] = [3, 5, 7]
        cm.advance(slots[:2])
        np.testing.assert_array_equal(cm.lengths[slots], [4, 6, 7])
        cm.advance([])                          # empty step is a no-op
        np.testing.assert_array_equal(cm.lengths[slots], [4, 6, 7])
        assert cm.divergence() == 3             # reads the same state


# ---------------------------------------------------------------------------
# Preemption replays to identical tokens (engine level)
# ---------------------------------------------------------------------------

_ENGINES = {}


def _engine(backend):
    if backend not in _ENGINES:
        cfg = get_arch("qwen2-1.5b").reduced().replace(
            num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16)
        params = api.init(jax.random.PRNGKey(0), cfg)
        _ENGINES[backend] = ServingEngine(
            cfg, params, ServeConfig(max_new_tokens=8, cache_backend=backend,
                                     block_size=BS))
    return _ENGINES[backend]


class TestPreemptionReplay:
    @given(st.lists(st.tuples(st.integers(2, 10),      # prompt length
                              st.integers(1, 6),       # max_new_tokens
                              st.integers(0, 3)),      # arrival gap
                    min_size=2, max_size=5),
           st.integers(0, 2**31 - 1))
    @settings(max_examples=5, deadline=None)
    def test_tiny_pool_replays_token_identical(self, specs, seed):
        prompts = [np.asarray(
            jax.random.randint(jax.random.PRNGKey(seed + i), (plen,), 2, 128),
            np.int32) for i, (plen, _, _) in enumerate(specs)]
        t, arrivals = 0.0, []
        for _, _, gap in specs:
            arrivals.append(t)
            t += gap

        def reqs():
            return [Request(prompt=prompts[i], max_new_tokens=mn,
                            arrival_time=arrivals[i])
                    for i, (_, mn, _) in enumerate(specs)]

        slab = _engine("slab").serve(reqs(), n_slots=2, cache_T=24)
        # 9 usable blocks (36 tokens) across 2 slots of up to 16+8 tokens
        # each: appends outrun the pool and force preemption-and-requeue
        paged = _engine("paged").serve(reqs(), n_slots=2, cache_T=24,
                                       num_blocks=10)
        for a, b in zip(sorted(slab.results, key=lambda r: r.request_id),
                        sorted(paged.results, key=lambda r: r.request_id)):
            assert a.finish_reason == b.finish_reason
            np.testing.assert_array_equal(a.tokens, b.tokens)

    def test_preemption_actually_fires_and_matches(self):
        rng = np.random.default_rng(0)
        prompts = [np.asarray(rng.integers(2, 128, size=8), np.int32)
                   for _ in range(3)]

        def reqs():
            return [Request(prompt=p, max_new_tokens=8, arrival_time=0.0)
                    for p in prompts]

        slab = _engine("slab").serve(reqs(), n_slots=3, cache_T=24)
        paged = _engine("paged").serve(reqs(), n_slots=3, cache_T=24,
                                       num_blocks=9)
        assert paged.n_preemptions > 0
        for a, b in zip(sorted(slab.results, key=lambda r: r.request_id),
                        sorted(paged.results, key=lambda r: r.request_id)):
            np.testing.assert_array_equal(a.tokens, b.tokens)
        done = {r.finish_reason for r in paged.results}
        assert done <= {"eos", "length"}
