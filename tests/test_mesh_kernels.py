"""shard_map kernel parity under the mesh: the Pallas fast path no longer
falls back to the XLA oracle when a mesh is active.

Covers the regression (``resolve_matmul_backend("kernel")`` stays "kernel"
under an active mesh), the one-time fallback ledger, interpret-mode kernel
vs XLA-oracle parity for both kernels on ragged shapes under the 8-virtual-
CPU mesh (all three matmul partition strategies, S=1 decode rows and
S=K+1 verify rows, split-KV and replicated paged attention), and serve-level
token identity of the mesh-kernel path against single-device-kernel and
mesh-XLA.  Multi-device cases run in subprocesses (XLA_FLAGS must be set
before jax initializes — the ``tests/test_sharded_serving.py`` pattern).
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


def test_resolve_backend_keeps_kernel_under_mesh():
    """Regression for the blanket mesh downgrade: kernel backends resolve
    to themselves under an active mesh (the dispatch sites shard_map the
    kernels instead)."""
    import jax
    from jax.sharding import Mesh
    from repro.core import bp_matmul as bpm
    from repro.distributed import sharding as shd

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    with shd.activate_mesh(mesh):
        assert shd.current_mesh() is not None
        assert bpm.resolve_matmul_backend("kernel") == "kernel"
        assert bpm.resolve_matmul_backend("kernel_interpret") == \
            "kernel_interpret"
        assert bpm.resolve_matmul_backend("xla") == "xla"
    assert shd.current_mesh() is None


def test_backend_fallback_ledger_counts_and_paged_scale_demotion():
    """Remaining per-call kernel->xla demotions are never silent: the int8
    KV scale-page path records itself in the fallback ledger (once per
    reason in the log, every occurrence in the count)."""
    import jax
    import jax.numpy as jnp
    from repro.core import bp_matmul as bpm
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_xla

    bpm.clear_backend_fallbacks()
    try:
        rng = np.random.default_rng(0)
        B, H, KH, D, bs, P = 2, 2, 1, 8, 4, 2
        N = 5
        q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(N, bs, KH, D)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(N, bs, KH, D)), jnp.float32)
        ks = jnp.ones((N, bs, KH), jnp.float32)
        bt = jnp.asarray(rng.integers(1, N, size=(B, P)), jnp.int32)
        ln = jnp.asarray(rng.integers(0, P * bs, size=(B,)), jnp.int32)

        out = paged_attention(q, kp, vp, bt, ln, k_scale_pages=ks,
                              v_scale_pages=ks, backend="kernel_interpret")
        ref = paged_attention_xla(q, kp, vp, bt, ln, k_scale_pages=ks,
                                  v_scale_pages=ks)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
        ledger = bpm.backend_fallbacks()
        assert len(ledger) == 1 and list(ledger.values()) == [1]
        paged_attention(q, kp, vp, bt, ln, k_scale_pages=ks,
                        backend="kernel_interpret")
        assert list(bpm.backend_fallbacks().values()) == [2]
        # an explicit xla request is not a fallback
        paged_attention(q, kp, vp, bt, ln, backend="xla")
        assert list(bpm.backend_fallbacks().values()) == [2]
    finally:
        bpm.clear_backend_fallbacks()


_HEADER = """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax, jax.numpy as jnp
    jax.config.update("jax_default_matmul_precision", "float32")
    from jax.sharding import Mesh
    from repro.distributed import sharding as shd
    from repro.core import bp_matmul as bpm

    mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4),
                ("data", "model"))
"""


@pytest.mark.slow
def test_mesh_matmul_kernel_parity_all_strategies():
    """quantized_matmul under the 2x4 mesh, kernel_interpret vs the XLA
    oracle, for every partition strategy (column split / split-K / fully
    replicated), both quant modes, S=1 decode rows and S=4 verify rows.
    The sharded kernel wrapper itself is additionally pinned bit-identical
    to the single-device kernel on fixed int8 operands."""
    _run(_HEADER + """
    from repro.core import quant
    from repro.core.bp_matmul import quantized_matmul
    from repro.kernels.bitparticle_matmul.ops import (
        _matmul_strategy, bp_matmul, bp_matmul_sharded)

    axes = shd.mesh_axes_dict(mesh)
    # (B, S, K, N) -> expected strategy on ("data"=2, "model"=4)
    cases = [
        ((4, 1, 33, 128), "col"),      # N % 4 == 0: column split
        ((4, 4, 33, 128), "col"),      # S=4: speculative verify rows
        ((4, 1, 128, 130), "splitk"),  # K % 4 == 0, N ragged: split-K psum
        ((4, 4, 128, 130), "splitk"),
        ((5, 1, 33, 17), "rep"),       # nothing divides: replicated
    ]
    for (b, s, k, n), want in cases:
        got_strat = _matmul_strategy([b, s], k, n, axes)[1]
        assert got_strat == want, ((b, s, k, n), got_strat, want)
        x = jax.random.normal(jax.random.PRNGKey(b + n), (b, s, k),
                              jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
        for mode in ("bp_exact", "bp_approx"):
            def f(x, w):
                w_q, w_s = quant.quantize_per_channel(w, channel_axis=-1)
                return quantized_matmul(x, w_q, w_s.reshape(-1), mode)
            with shd.activate_mesh(mesh), bpm.use_matmul_backend("xla"):
                ref = jax.jit(f)(x, w)
            with shd.activate_mesh(mesh), \\
                 bpm.use_matmul_backend("kernel_interpret"):
                got = jax.jit(f)(x, w)
            np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
            print("OK", (b, s, k, n), want, mode)

    # the shard_map wrapper is bit-identical to the unsharded kernel when
    # quantized operands and scales are fixed (integer partials + identical
    # dequant epilogue ordering)
    rng = np.random.default_rng(0)
    for (b, s, k, n), want in cases:
        xq = jnp.asarray(rng.integers(-127, 128, size=(b, s, k)), jnp.int8)
        wq = jnp.asarray(rng.integers(-127, 128, size=(k, n)), jnp.int8)
        sa = jnp.asarray(rng.random((b, s, 1)), jnp.float32)
        sw = jnp.asarray(rng.random((n,)), jnp.float32)
        for approx in (False, True):
            single = bp_matmul(xq, wq, sa, sw, approx=approx, interpret=True)
            with shd.activate_mesh(mesh):
                sharded = jax.jit(lambda *a: bp_matmul_sharded(
                    *a, approx=approx, interpret=True, mesh=mesh))(
                    xq, wq, sa, sw)
            np.testing.assert_array_equal(np.asarray(single),
                                          np.asarray(sharded))
    print("BITWISE OK")
""")


@pytest.mark.slow
def test_mesh_paged_attention_kernel_parity():
    """Paged-attention kernel under the 2x4 mesh vs the XLA gather oracle:
    the split-KV path (page dim divisible by "model" -> per-shard online
    softmax + (m, l, acc) cross-shard combine) and the replicated path
    (ragged page count), ragged lengths including length 0."""
    _run(_HEADER + """
    from repro.kernels.paged_attention.ops import paged_attention
    from repro.kernels.paged_attention.ref import paged_attention_xla

    rng = np.random.default_rng(0)
    #          B  H  KH  D  bs  P     (P % 4 == 0 -> KV split over "model")
    cases = [(4, 4, 2, 16, 4, 8),
             (4, 8, 4, 16, 2, 12),
             (4, 4, 2, 16, 4, 5),     # ragged page count: replicated
             (6, 2, 2,  8, 4, 4)]     # B % 2 == 0 but B % 4 != 0
    for (B, H, KH, D, bs, P) in cases:
        N = P * B + 1
        q = jnp.asarray(rng.normal(size=(B, H, D)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(N, bs, KH, D)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(N, bs, KH, D)), jnp.float32)
        bt = jnp.asarray(rng.integers(1, N, size=(B, P)), jnp.int32)
        ln = np.asarray(rng.integers(0, P * bs, size=(B,)), np.int32)
        ln[0] = 0                      # only the just-written token valid
        ln = jnp.asarray(ln)
        ref = paged_attention_xla(q, kp, vp, bt, ln)
        with shd.activate_mesh(mesh), \\
             bpm.use_matmul_backend("kernel_interpret"):
            got = jax.jit(paged_attention)(q, kp, vp, bt, ln)
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
        print("OK", (B, H, KH, D, bs, P))
""")


@pytest.mark.slow
@pytest.mark.parametrize("cache_backend", ["slab", "paged"])
def test_mesh_kernel_serve_token_identity(cache_backend):
    """The acceptance bar: mesh serve under ``matmul_backend=
    "kernel_interpret"`` is token-identical to single-device-kernel AND
    mesh-XLA serve (2x4 mesh, plain and speculative decoding)."""
    _run(_HEADER + """
    from repro.configs.base import get_arch
    from repro.models import api
    from repro.serving import (MeshExecutor, Request, SchedulerConfig,
                               ServeConfig, ServingEngine)

    base = get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16,
        matmul_mode="bp_exact")
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (4, 6), 2, base.vocab_size), np.int32)

    def tokens(mesh_shape, mm, spec=False):
        cfg = base.replace(matmul_backend=mm)
        params = api.init(jax.random.PRNGKey(0), cfg)
        sc = dict(max_new_tokens=8, temperature=0.0,
                  cache_backend=%(backend)r, block_size=4,
                  mesh_shape=mesh_shape)
        kw = {}
        if spec:
            sc.update(draft="model", num_draft_tokens=3)
            kw = dict(draft_cfg=cfg, draft_params=params)
        engine = ServingEngine(cfg, params, ServeConfig(**sc), **kw)
        if mesh_shape is not None:
            assert isinstance(engine.executor, MeshExecutor)
        assert engine.executor.matmul_backend == mm
        reqs = [Request(prompt=prompts[i], max_new_tokens=[8, 3, 6, 8][i],
                        arrival_time=float(i)) for i in range(4)]
        rep = engine.serve(reqs, n_slots=2,
                           sched_cfg=SchedulerConfig(lead_window=2))
        if spec:
            assert rep.acceptance_rate > 0.0
        return [list(r.tokens) for r in
                sorted(rep.results, key=lambda r: r.request_id)]

    single_kernel = tokens(None, "kernel_interpret")
    assert tokens((2, 4), "kernel_interpret") == single_kernel
    assert tokens((2, 4), "xla") == single_kernel
    assert tokens((2, 4), "kernel_interpret", spec=True) == single_kernel
    print("OK serve", %(backend)r)
""" % {"backend": cache_backend})
