"""Front door: chunked prefill, streaming serve loop, HTTP server +
router, SLO scheduling, and the request-record telemetry they ride on."""

import json
import time

import numpy as np
import pytest
import jax

from repro.configs.base import get_arch
from repro.models import api
from repro.models.layers import quantize_dense_params
from repro.serving import (FrontDoor, FrontDoorClient, Replica, Request,
                           Router, SchedulerConfig, ServeConfig, SLOClass,
                           ServingEngine, SparsityProbe, percentiles,
                           read_jsonl, reduce_stream)
from repro.serving.telemetry import STEP_SCHEMA, Telemetry

jax.config.update("jax_default_matmul_precision", "float32")


def _dense_cfg(**kw):
    return get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16, **kw)


@pytest.fixture(scope="module")
def dense():
    cfg = _dense_cfg()
    return cfg, api.init(jax.random.PRNGKey(0), cfg)


def _engine(dense, *, backend="slab", prefill_chunk=None, max_new=6, **kw):
    cfg, params = dense
    return ServingEngine(cfg, params, ServeConfig(
        max_new_tokens=max_new, temperature=0.0, cache_backend=backend,
        block_size=4, prefill_chunk=prefill_chunk, **kw))


def _prompt(n, seed=1, vocab=128):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (n,),
                                         2, vocab), np.int32)


def _tokens(report):
    return [r.tokens.tolist() for r in report.results]


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------


class TestChunkedPrefill:
    @pytest.mark.parametrize("backend", ["slab", "paged"])
    def test_token_identity_vs_oneshot(self, dense, backend):
        prompts = [_prompt(5 + 9 * i % 23, seed=i) for i in range(5)]
        outs = {}
        for chunk in (None, 3, 8):
            eng = _engine(dense, backend=backend, prefill_chunk=chunk)
            reqs = [Request(prompt=p, max_new_tokens=6) for p in prompts]
            outs[chunk] = _tokens(eng.serve(reqs, n_slots=2, cache_T=64))
        assert outs[3] == outs[None]
        assert outs[8] == outs[None]

    def test_chunks_interleave_with_decode(self, dense):
        """A long prompt admitted mid-run must NOT stall the in-flight
        decoder: some verify step carries both decode commits and chunk
        feeds."""
        eng = _engine(dense, backend="paged", prefill_chunk=4, max_new=12)
        reqs = [Request(prompt=_prompt(4, seed=1), max_new_tokens=12,
                        arrival_time=0.0),
                Request(prompt=_prompt(24, seed=2), max_new_tokens=4,
                        arrival_time=2.0)]
        loop = eng.make_loop(reqs, n_slots=2, cache_T=64)
        loop.run()
        mixed = [r for r in loop.stream if r["kind"] == "verify"
                 and r["chunk_tokens"] > 0 and r["committed_tokens"] > 0]
        assert mixed, "no step interleaved chunked prefill with decode"
        # per-step prefill cost is bounded by the chunk across every slot
        assert all(r["chunk_tokens"] <= 2 * 4 for r in loop.stream
                   if r["kind"] == "verify")

    def test_composes_with_speculation(self, dense):
        base = _engine(dense, max_new=10)
        prompts = [_prompt(17, seed=i) for i in range(3)]
        want = _tokens(base.serve(
            [Request(prompt=p, max_new_tokens=10) for p in prompts],
            n_slots=2, cache_T=64))
        eng = _engine(dense, prefill_chunk=5, max_new=10,
                      draft="prompt_lookup", num_draft_tokens=3)
        got = _tokens(eng.serve(
            [Request(prompt=p, max_new_tokens=10) for p in prompts],
            n_slots=2, cache_T=64))
        assert got == want

    def test_rejects_temperature_and_bad_chunk(self, dense):
        cfg, params = dense
        eng = ServingEngine(cfg, params, ServeConfig(
            temperature=0.5, prefill_chunk=4))
        with pytest.raises(ValueError, match="greedy-only"):
            eng.make_loop([], cache_T=32)
        eng = _engine(dense, prefill_chunk=0)
        with pytest.raises(ValueError, match="prefill_chunk"):
            eng.make_loop([], cache_T=32)


# ---------------------------------------------------------------------------
# streaming serve loop
# ---------------------------------------------------------------------------


class TestStreamingLoop:
    def test_submit_close_matches_batch_run(self, dense):
        prompts = [_prompt(7, seed=i) for i in range(4)]
        want = _tokens(_engine(dense).serve(
            [Request(prompt=p, max_new_tokens=6) for p in prompts],
            n_slots=2, cache_T=64))
        loop = _engine(dense).make_loop([], n_slots=2, cache_T=64)
        for p in prompts:
            loop.submit(Request(prompt=p, max_new_tokens=6))
        loop.close()
        report = loop.run_forever(poll_s=0.0)
        assert _tokens(report) == want

    def test_on_token_streams_each_position_once(self, dense):
        loop = _engine(dense).make_loop([], n_slots=2, cache_T=64)
        seen = {}
        loop.on_token = lambda req, tok, i: (
            seen.setdefault(req.request_id, []).append((i, tok)))
        reqs = [Request(prompt=_prompt(7, seed=i), max_new_tokens=6)
                for i in range(3)]
        for r in reqs:
            loop.submit(r)
        loop.close()
        loop.run_forever(poll_s=0.0)
        for r in reqs:
            assert [t for _, t in seen[r.request_id]] == r.tokens
            assert [i for i, _ in seen[r.request_id]] == list(
                range(len(r.tokens)))

    def test_submit_after_close_raises(self, dense):
        loop = _engine(dense).make_loop([], n_slots=2, cache_T=64)
        loop.close()
        with pytest.raises(RuntimeError, match="closed"):
            loop.submit(Request(prompt=_prompt(4)))

    @pytest.mark.filterwarnings(
        "ignore::pytest.PytestUnhandledThreadExceptionWarning")
    def test_replica_worker_crash_surfaces_error(self, dense):
        """A dead worker must not strand its clients: orphaned in-flight
        handles get on_finish (with a non-terminal request, which is the
        tell), and submit/close re-raise instead of hanging."""
        rep = Replica(_engine(dense), name="boom", n_slots=2, cache_T=64)

        def _explode():
            raise ZeroDivisionError("boom")

        rep.loop._step = _explode
        finished = []
        rep.start()
        rep.submit(Request(prompt=_prompt(6), max_new_tokens=4),
                   on_finish=finished.append)
        rep._thread.join(timeout=30)
        assert isinstance(rep.error, ZeroDivisionError)
        assert len(finished) == 1 and not finished[0].is_terminal
        with pytest.raises(RuntimeError, match="worker died"):
            rep.submit(Request(prompt=_prompt(6), max_new_tokens=4))
        with pytest.raises(RuntimeError, match="worker died"):
            rep.close()


# ---------------------------------------------------------------------------
# router (pure policy, fake replicas)
# ---------------------------------------------------------------------------


class _FakeReplica:
    def __init__(self, name, depth=0, cost=0.0, block_size=4):
        self.name = name
        self.depth = depth
        self.cost = cost
        self.block_size = block_size

    def stats(self):
        return {"name": self.name, "queue_depth": self.depth,
                "cost_hint_cycles_per_token": self.cost}


class TestRouter:
    def test_affinity_same_prefix_same_replica(self):
        reps = [_FakeReplica("a"), _FakeReplica("b"), _FakeReplica("c")]
        router = Router(reps, policy="affinity", affinity_blocks=2)
        sys_prompt = _prompt(8, seed=7)
        picks = {router.pick(np.concatenate([sys_prompt, _prompt(5, seed=i)]))
                 for i in range(10)}
        assert len(picks) == 1

    def test_affinity_spills_on_imbalance_without_rehoming(self):
        reps = [_FakeReplica("a"), _FakeReplica("b")]
        router = Router(reps, policy="affinity", max_imbalance=2)
        p = _prompt(12, seed=3)
        home = router.pick(p)
        home.depth = 10                      # home gets swamped
        other = router.pick(p)
        assert other is not home and router.n_spills == 1
        home.depth = 0                       # pressure gone: back home
        assert router.pick(p) is home

    def test_least_loaded_breaks_ties_on_cost_hint(self):
        reps = [_FakeReplica("a", depth=1, cost=9.0),
                _FakeReplica("b", depth=1, cost=2.0),
                _FakeReplica("c", depth=3, cost=0.0)]
        router = Router(reps, policy="least_loaded")
        assert router.pick(_prompt(4)) is reps[1]

    def test_round_robin_cycles(self):
        reps = [_FakeReplica("a"), _FakeReplica("b")]
        router = Router(reps, policy="round_robin")
        assert [router.pick(_prompt(4)).name for _ in range(4)] == [
            "a", "b", "a", "b"]

    def test_unknown_policy_rejected(self):
        with pytest.raises(ValueError, match="policy"):
            Router([_FakeReplica("a")], policy="hash")


# ---------------------------------------------------------------------------
# HTTP front door (real TCP)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def door(dense):
    replicas = [Replica(_engine(dense, backend="paged", prefill_chunk=6,
                                max_new=24),
                        name=f"r{i}", n_slots=2, cache_T=96)
                for i in range(2)]
    fd = FrontDoor(replicas, policy="affinity", affinity_blocks=1).start()
    yield fd, FrontDoorClient("127.0.0.1", fd.port)
    fd.stop()


class TestFrontDoorHTTP:
    def test_healthz_and_stats(self, door):
        _, client = door
        assert client.healthz() == {"ok": True}
        stats = client.stats()
        assert stats["policy"] == "affinity"
        assert {r["name"] for r in stats["replicas"]} == {"r0", "r1"}

    def test_token_identity_vs_direct_serve(self, dense, door):
        _, client = door
        prompts = [_prompt(15, seed=i) for i in range(4)]
        want = _tokens(_engine(dense, max_new=5).serve(
            [Request(prompt=p, max_new_tokens=5) for p in prompts],
            n_slots=2, cache_T=96))
        got = [client.generate(p, max_new_tokens=5)["tokens"]
               for p in prompts]
        assert got == want
        streamed = [client.generate(p, max_new_tokens=5,
                                    stream=True)["tokens"]
                    for p in prompts]
        assert streamed == want

    def test_bad_requests_get_4xx(self, door):
        _, client = door
        with pytest.raises(RuntimeError, match="404"):
            client._request_json("GET", "/nope")
        with pytest.raises(RuntimeError, match="400"):
            client._request_json("POST", "/v1/generate", {"prompt": "hi"})

    def test_disconnect_cancels_and_releases_everything(self, dense):
        replica = Replica(_engine(dense, backend="paged", prefill_chunk=6,
                                  max_new=24),
                          name="solo", n_slots=2, cache_T=96)
        fd = FrontDoor([replica]).start()
        client = FrontDoorClient("127.0.0.1", fd.port)
        try:
            p = _prompt(15, seed=40)
            full = client.generate(p, max_new_tokens=24)
            part = client.generate(p, max_new_tokens=24, disconnect_after=2)
            assert part["disconnected"]
            # the partial stream is a PREFIX of the fault-free stream
            assert part["tokens"] == full["tokens"][:len(part["tokens"])]
            assert len(part["tokens"]) < len(full["tokens"])
            deadline = time.monotonic() + 30
            while time.monotonic() < deadline:
                s = replica.stats()
                if s["queue_depth"] == 0 and s["blocks_in_use"] == 0:
                    break
                time.sleep(0.02)
            s = replica.stats()
            assert s["queue_depth"] == 0
            assert s["blocks_in_use"] == 0, "disconnect leaked KV blocks"
        finally:
            reports = fd.stop()
        assert reports["solo"].n_cancelled == 1
        cancelled = [r for r in reports["solo"].results
                     if r.finish_reason == "cancelled"]
        assert len(cancelled) == 1

    def test_two_replicas_share_one_engine_rejected(self, dense):
        eng = _engine(dense)
        with pytest.raises(ValueError, match="engine"):
            FrontDoor([Replica(eng, name="a", cache_T=32),
                       Replica(eng, name="b", cache_T=32)])


# ---------------------------------------------------------------------------
# SLO scheduling
# ---------------------------------------------------------------------------


def _slo_sched_cfg(**kw):
    return SchedulerConfig(policy="slo", slo_classes={
        "interactive": SLOClass(name="interactive", priority=10,
                                ttft_target_s=kw.pop("ttft_target_s", None),
                                itl_target_s=kw.pop("itl_target_s", None)),
        "batch": SLOClass(name="batch", priority=0)}, **kw)


class TestSLOScheduling:
    def _trace(self, n_low=6, n_high=2):
        reqs = [Request(prompt=_prompt(6, seed=i), max_new_tokens=6,
                        arrival_time=0.0, slo_class="batch")
                for i in range(n_low)]
        reqs += [Request(prompt=_prompt(6, seed=50 + i), max_new_tokens=6,
                         arrival_time=0.0, slo_class="interactive")
                 for i in range(n_high)]
        return reqs

    def _per_class_ttft(self, reqs):
        out = {}
        for r in reqs:
            out.setdefault(r.slo_class, []).append(r.ttft)
        return {k: percentiles(v)["p90"] for k, v in out.items()}

    def test_priority_class_beats_fifo_on_ttft(self, dense):
        ttfts, toks = {}, {}
        for policy in ("fifo", "slo"):
            sched_cfg = (_slo_sched_cfg() if policy == "slo"
                         else SchedulerConfig())
            reqs = self._trace()
            _engine(dense).serve(reqs, n_slots=2, cache_T=64,
                                 sched_cfg=sched_cfg)
            ttfts[policy] = self._per_class_ttft(reqs)
            toks[policy] = [r.tokens for r in reqs]
        # scheduling order must never change tokens (batch-composition
        # independence is the repo's correctness anchor)
        assert toks["slo"] == toks["fifo"]
        # the high-priority class jumps the queue: strictly better p90
        # TTFT on the same trace, measured on the deterministic step clock
        assert (ttfts["slo"]["interactive"]
                < ttfts["fifo"]["interactive"])

    def test_ttft_breach_collapses_lead_window(self, dense):
        loop = _engine(dense).make_loop(
            [], n_slots=2, cache_T=64,
            sched_cfg=_slo_sched_cfg(ttft_target_s=0.5, lead_window=4))
        sched = loop.sched
        assert sched._effective_lead_window() == 4
        sched.observe_ttft("interactive", 2.0)
        assert sched._effective_lead_window() == 0
        # recovery: enough in-target samples push p90 back under target
        for _ in range(40):
            sched.observe_ttft("interactive", 0.01)
        assert sched._effective_lead_window() == 4

    def test_itl_breach_throttles_admission_burst(self, dense):
        loop = _engine(dense).make_loop(
            [], n_slots=4, cache_T=64,
            sched_cfg=_slo_sched_cfg(itl_target_s=0.01, lead_window=0))
        for i in range(4):
            loop.submit(Request(prompt=_prompt(6, seed=i),
                                max_new_tokens=6, slo_class="batch"))
        loop._drain_inbox()
        loop.submit_arrivals()
        # an active batch + breached ITL: admissions throttle to 1
        loop.sched.cache_mgr.alloc()
        for _ in range(8):
            loop.sched.observe_itl("interactive", 1.0)
        groups = loop.sched.plan_admissions()
        assert sum(len(g) for g in groups) == 1

    def test_unknown_policy_rejected(self, dense):
        with pytest.raises(ValueError, match="policy"):
            _engine(dense).make_loop(
                [], cache_T=32, sched_cfg=SchedulerConfig(policy="edf"))


# ---------------------------------------------------------------------------
# request records + report parity
# ---------------------------------------------------------------------------


class TestRequestRecords:
    def test_stream_has_one_record_per_request(self, dense, tmp_path):
        path = str(tmp_path / "metrics.jsonl")
        tel = Telemetry(metrics_path=path)
        cfg, params = dense
        eng = ServingEngine(cfg, params, ServeConfig(
            max_new_tokens=6, temperature=0.0, prefill_chunk=4,
            telemetry=tel))
        reqs = [Request(prompt=_prompt(9, seed=i), max_new_tokens=6,
                        slo_class="interactive" if i % 2 else "batch")
                for i in range(4)]
        report = eng.serve(reqs, n_slots=2, cache_T=64,
                           sched_cfg=_slo_sched_cfg())
        tel.close()
        recs = [r for r in read_jsonl(path)
                if r["kind"] == "request"]
        assert len(recs) == 4
        for r in recs:
            assert STEP_SCHEMA["request"] <= set(r)
            assert r["queue_wait_s"] >= 0.0
            assert r["ttft_wall_s"] > 0.0
            assert len(r["itl_wall_s"]) == r["n_tokens"] - 1
        # file/live parity: the report's SLO numbers are a pure reduction
        # of the stream, so re-reducing the FILE reproduces them exactly
        s = reduce_stream(read_jsonl(path))
        assert report.queue_wait == percentiles(s.queue_wait_samples)
        assert set(report.slo_classes) == {"interactive", "batch"}
        for name, stats in report.slo_classes.items():
            assert stats["ttft_wall"] == percentiles(
                s.slo_ttft_samples[name])
        assert report.chunk_tokens == s.chunk_tokens > 0

    def test_cost_hint_accumulates_from_probe(self, dense):
        cfg, params = dense
        q_cfg = cfg.replace(matmul_mode="bp_exact", kv_cache_int8=True)
        eng = ServingEngine(q_cfg, quantize_dense_params(params),
                            ServeConfig(max_new_tokens=6, temperature=0.0,
                                        probe=SparsityProbe(probe_every=2)))
        loop = eng.make_loop(
            [Request(prompt=_prompt(6, seed=i), max_new_tokens=6)
             for i in range(2)], n_slots=2, cache_T=64)
        assert loop.cost_hint_cycles_per_token == 0.0
        loop.run()
        assert loop.cost_hint_cycles_per_token > 0.0
