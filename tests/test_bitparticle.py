"""Exhaustive + property tests for the BitParticle MAC emulation.

The magnitude space is only 7 bits, so core claims are verified EXHAUSTIVELY
over all 128x128 magnitude pairs (and all 255x255 signed pairs where cheap).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # optional dev dep: skip, don't error
from hypothesis import given, settings, strategies as st

from repro.core import bitparticle as bp
from repro.core import bp_matmul, quant, sparsity


def _all_magnitude_pairs():
    a = np.arange(128).repeat(128)
    w = np.tile(np.arange(128), 128)
    return jnp.asarray(a), jnp.asarray(w)


# ---------------------------------------------------------------------------
# Structure
# ---------------------------------------------------------------------------

class TestStructure:
    def test_groups_partition_all_16_positions(self):
        ids = sorted(i for g in bp.GROUP_IDS for i in g)
        assert ids == list(range(16))

    def test_group_sets_partition_groups(self):
        assert sorted(bp.GROUP_SET0 + bp.GROUP_SET1) == list(range(7))

    def test_paper_named_groups(self):
        # Section III-A: group 3-6-9-12, group 7-10-13, group 2-5-8, etc.
        assert bp.GROUP_IDS[3] == (3, 6, 9, 12)
        assert bp.GROUP_IDS[4] == (7, 10, 13)
        assert bp.GROUP_IDS[2] == (2, 5, 8)
        assert bp.GROUP_IDS[1] == (1, 4)
        assert bp.GROUP_IDS[0] == (0,)
        assert bp.GROUP_IDS[6] == (15,)

    def test_particlize_roundtrip_exhaustive(self):
        mags = jnp.arange(128)
        assert (bp.unparticlize(bp.particlize(mags)) == mags).all()

    def test_particle_widths(self):
        p = np.asarray(bp.particlize(jnp.arange(128)))
        assert p[:, :3].max() == 3 and p[:, 3].max() == 1


# ---------------------------------------------------------------------------
# Exact product reconstruction (the central "faithfulness" proof)
# ---------------------------------------------------------------------------

class TestExactProduct:
    def test_magnitude_product_exhaustive(self):
        ma, mw = _all_magnitude_pairs()
        got = bp.magnitude_product_from_irs(ma, mw)
        assert (got == ma * mw).all()

    def test_signed_product_exhaustive(self):
        vals = jnp.arange(-127, 128)
        a = vals[:, None]
        w = vals[None, :]
        assert (bp.multiply_exact(a, w) == a * w).all()

    def test_ir_value_set(self):
        ma, mw = _all_magnitude_pairs()
        irs = np.asarray(bp.ir_matrix(ma, mw))
        assert set(np.unique(irs)) <= set(bp.IR_VALUE_SET)

    def test_ir_encode3_roundtrip(self):
        vals = jnp.asarray(bp.IR_VALUE_SET)
        codes = bp.ir_encode3(vals)
        assert codes.max() <= 7  # fits in 3 bits
        assert (bp.ir_decode3(codes) == vals).all()


# ---------------------------------------------------------------------------
# Cycle model
# ---------------------------------------------------------------------------

class TestCycles:
    def test_cycles_bounds_exhaustive(self):
        ma, mw = _all_magnitude_pairs()
        c = np.asarray(bp.mac_cycles(ma, mw))
        assert c.min() >= 1 and c.max() <= bp.MAX_CYCLES

    def test_zero_operand_single_cycle(self):
        assert int(bp.mac_cycles(0, 127)) == 1
        assert int(bp.mac_cycles(127, 0)) == 1

    def test_worst_case_is_four(self):
        # all magnitude bits set on both operands -> group 3-6-9-12 full.
        assert int(bp.mac_cycles(127, 127)) == 4

    def test_approx_cycles_never_exceed_exact(self):
        ma, mw = _all_magnitude_pairs()
        ce = np.asarray(bp.mac_cycles(ma, mw, approx=False))
        ca = np.asarray(bp.mac_cycles(ma, mw, approx=True))
        assert (ca <= ce).all()


# ---------------------------------------------------------------------------
# Cycle-by-cycle datapath (selection + concatenation + 13-bit adder)
# ---------------------------------------------------------------------------

class TestDatapath:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_assembly_matches_product_random(self, seed):
        rng = np.random.default_rng(seed)
        for _ in range(500):
            a = int(rng.integers(-127, 128))
            w = int(rng.integers(-127, 128))
            prod, pps, cycles = bp.assemble_partial_products(a, w)
            assert prod == a * w
            assert len(pps) == cycles <= bp.MAX_CYCLES
            n_pps = sum(1 for s0, s1 in pps for v in (s0, s1) if v)
            assert n_pps <= bp.MAX_PARTIAL_PRODUCTS
            for s0, s1 in pps:
                assert 0 <= s0 < (1 << 13) and 0 <= s1 < (1 << 13)  # 13-bit PPs

    def test_assembly_cycles_match_model(self):
        rng = np.random.default_rng(3)
        a = rng.integers(-127, 128, size=200)
        w = rng.integers(-127, 128, size=200)
        model = np.asarray(bp.mac_cycles(jnp.asarray(a), jnp.asarray(w)))
        for i in range(200):
            _, _, cyc = bp.assemble_partial_products(int(a[i]), int(w[i]))
            assert cyc == model[i]

    def test_worst_case_pp_count_is_seven(self):
        _, pps, cycles = bp.assemble_partial_products(127, 127)
        assert cycles == 4
        n_pps = sum(1 for s0, s1 in pps for v in (s0, s1) if v)
        assert n_pps == 7  # matches a conventional 7-bit multiplier


# ---------------------------------------------------------------------------
# Approximate variant
# ---------------------------------------------------------------------------

class TestApprox:
    def test_approx_identity_exhaustive(self):
        vals = jnp.arange(-127, 128)
        a, w = vals[:, None], vals[None, :]
        approx = bp.multiply_approx(a, w)
        corr = bp.approx_correction(a, w)
        assert (approx == a * w - corr).all()

    def test_approx_error_bound_exhaustive(self):
        # dropped: a0*w0 + 4*(a0*w1 + a1*w0) <= 9 + 4*(9+9) = 81
        vals = jnp.arange(-127, 128)
        a, w = vals[:, None], vals[None, :]
        err = np.abs(np.asarray(bp.multiply_approx(a, w) - a * w))
        assert err.max() == 81
        assert abs(np.asarray(bp.approx_correction(a, w))).max() == 81

    def test_approx_drops_low_groups_only(self):
        ma, mw = _all_magnitude_pairs()
        got = bp.magnitude_product_from_irs(ma, mw, bp.APPROX_DROPPED_GROUPS)
        irs = np.asarray(bp.ir_matrix(ma, mw))
        diag = np.add.outer(np.arange(4), np.arange(4))
        want = (irs * np.where(diag >= 2, 1 << (2 * diag), 0)).sum((-2, -1))
        assert (np.asarray(got) == want).all()


# ---------------------------------------------------------------------------
# Skipped-calculations metric (Fig. 11 foundations)
# ---------------------------------------------------------------------------

class TestSkipped:
    def test_ordering_at_high_sparsity(self):
        key = jax.random.PRNGKey(0)
        a = sparsity.sample_with_bit_sparsity(key, (20000,), 0.7)
        w = sparsity.sample_with_bit_sparsity(jax.random.PRNGKey(1), (20000,), 0.7)
        ideal = float(jnp.mean(bp.skipped_calculations(a, w, "ideal")))
        serial = float(jnp.mean(bp.skipped_calculations(a, w, "bit_serial")))
        exact = float(jnp.mean(bp.skipped_calculations(a, w, "bp_exact")))
        approx = float(jnp.mean(bp.skipped_calculations(a, w, "bp_approx")))
        # paper Fig. 11: ideal >= bp_approx >= bp_exact >= bit_serial at bs >= 0.52
        assert ideal >= approx >= exact >= serial

    def test_ideal_zero_operand(self):
        assert float(bp.skipped_calculations(0, 127, "ideal")) == 1.0

    def test_dense_operands_skip_nothing(self):
        assert float(bp.skipped_calculations(127, 127, "ideal")) == 0.0
        assert float(bp.skipped_calculations(127, 127, "bp_exact")) == 0.0


# ---------------------------------------------------------------------------
# Quantization
# ---------------------------------------------------------------------------

class TestQuant:
    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=30, deadline=None)
    def test_quant_range_and_roundtrip(self, seed):
        key = jax.random.PRNGKey(seed)
        x = jax.random.normal(key, (64,)) * jax.random.uniform(key, ()) * 10
        q, s = quant.quantize_per_tensor(x)
        assert np.abs(np.asarray(q)).max() <= 127
        err = np.abs(np.asarray(quant.dequantize(q, s) - x))
        assert err.max() <= float(s) / 2 + 1e-6

    def test_per_channel_shapes(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
        q, s = quant.quantize_per_channel(x, channel_axis=-1)
        assert q.shape == (32, 16) and s.shape == (1, 16)

    def test_fake_quant_ste(self):
        x = jnp.linspace(-2.0, 2.0, 64)
        s = jnp.asarray(1.0 / 127)
        g = jax.grad(lambda v: jnp.sum(quant.fake_quant(v, s)))(x)
        assert np.allclose(np.asarray(g), np.where(np.abs(x) <= 1.0, 1.0, 0.0))


# ---------------------------------------------------------------------------
# Integer matmul backends (the jnp reference the Pallas kernel must match)
# ---------------------------------------------------------------------------

class TestBpMatmul:
    @given(st.integers(0, 2**32 - 1), st.sampled_from([1, 3, 8]),
           st.sampled_from([4, 17, 64]), st.sampled_from([2, 5, 16]))
    @settings(max_examples=25, deadline=None)
    def test_exact_equals_int_matmul(self, seed, m, k, n):
        key = jax.random.PRNGKey(seed)
        a = jax.random.randint(key, (m, k), -127, 128)
        w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -127, 128)
        got = bp_matmul.bp_matmul_int(a, w, "bp_exact")
        assert (np.asarray(got) == np.asarray(a) @ np.asarray(w)).all()

    @given(st.integers(0, 2**32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_approx_matches_elementwise_oracle(self, seed):
        key = jax.random.PRNGKey(seed)
        m, k, n = 5, 19, 7
        a = jax.random.randint(key, (m, k), -127, 128)
        w = jax.random.randint(jax.random.fold_in(key, 1), (k, n), -127, 128)
        got = bp_matmul.bp_matmul_int(a, w, "bp_approx")
        # oracle: elementwise IR-reconstruction products, summed over K
        prod = bp.multiply_approx(a[:, :, None], w[None, :, :])
        want = jnp.sum(prod, axis=1)
        assert (np.asarray(got) == np.asarray(want)).all()

    def test_dense_apply_modes_close(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (4, 32), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (32, 8), jnp.float32) / 6
        y = bp_matmul.dense_apply(x, w, "bf16")
        y_e = bp_matmul.dense_apply(x, w, "bp_exact")
        y_a = bp_matmul.dense_apply(x, w, "bp_approx")
        y_q = bp_matmul.dense_apply(x, w, "qat")
        assert np.allclose(y, y_e, atol=0.15)
        assert np.allclose(y_e, y_a, atol=0.05)   # approx error is tiny
        assert np.allclose(y, y_q, atol=0.15)

    def test_quantized_matmul_grad_flows_to_x(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 8)) / 4
        def loss(xx):
            return jnp.sum(bp_matmul.dense_apply(xx, w, "bp_exact") ** 2)
        g = jax.grad(loss)(x)
        assert np.isfinite(np.asarray(g)).all() and float(jnp.abs(g).max()) > 0


# ---------------------------------------------------------------------------
# Sparsity statistics
# ---------------------------------------------------------------------------

class TestSparsity:
    def test_generator_hits_target(self):
        key = jax.random.PRNGKey(0)
        for bs in (0.5, 0.7, 0.9):
            x = sparsity.sample_with_bit_sparsity(key, (50000,), bs)
            got = float(sparsity.bit_sparsity_sign_magnitude(x))
            assert abs(got - bs) < 0.01

    def test_sign_magnitude_sparser_than_twos_complement(self):
        # paper Fig. 1's motivation: gaussian-ish small negatives have dense
        # 2's-complement patterns but sparse magnitudes.
        x = jax.random.normal(jax.random.PRNGKey(2), (20000,))
        q, _ = quant.quantize_per_tensor(x)
        sm = float(sparsity.bit_sparsity_sign_magnitude(q))
        tc = float(sparsity.bit_sparsity_twos_complement(q))
        assert sm > tc

    def test_value_sparsity(self):
        x = jnp.asarray([0, 0, 1, -3])
        assert float(sparsity.value_sparsity(x)) == 0.5
