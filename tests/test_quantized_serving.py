"""Quantized serving paths (§Perf iterations B/C): int8 weights + int8 KV
cache must stay numerically close to the bf16 path, and the q8gather STE
must be gradient-transparent."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import get_arch
from repro.core import bp_matmul
from repro.models import api, attention
from repro.models.layers import quantize_dense_params

jax.config.update("jax_default_matmul_precision", "float32")


def test_quantize_kv_roundtrip():
    k = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16))
    v = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 4, 16))
    kq, ks, vq, vs = attention.quantize_kv(k, v)
    assert kq.dtype == jnp.int8 and ks.shape == (2, 8, 4)
    err = np.abs(np.asarray(kq, np.float32) * np.asarray(ks)[..., None]
                 - np.asarray(k))
    assert err.max() <= float(np.abs(np.asarray(k)).max()) / 127 + 1e-6


def test_decode_attention_int8_close_to_fp():
    key = jax.random.PRNGKey(2)
    q = jax.random.normal(key, (2, 1, 8, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 24, 4, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 24, 4, 16))
    ref = attention.decode_attention(q, k, v, jnp.int32(23))
    kq, ks, vq, vs = attention.quantize_kv(k, v)
    got = attention.decode_attention(q, kq, vq, jnp.int32(23),
                                     k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=0.05, rtol=0.05)


def test_int8_weight_serving_end_to_end():
    cfg = get_arch("qwen2-7b").reduced()
    params = api.init(jax.random.PRNGKey(3), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(4), (2, 12), 0,
                                cfg.vocab_size)
    ref_logits, _ = api.prefill(params, cfg, {"tokens": tokens}, 16)

    q_params = quantize_dense_params(params)
    q_cfg = cfg.replace(matmul_mode="bp_exact", kv_cache_int8=True)
    got_logits, cache = api.prefill(q_params, q_cfg, {"tokens": tokens}, 16)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    # quantization noise bounded: top-1 agreement + absolute closeness
    np.testing.assert_allclose(np.asarray(got_logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               atol=0.35, rtol=0.2)

    # one decode step runs and returns updated int8 cache
    logits, cache2 = api.decode_step(q_params, q_cfg, {
        "tokens": tokens[:, :1], "cache": cache, "cache_len": jnp.int32(12)})
    assert cache2["k"].dtype == jnp.int8
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_q8gather_is_gradient_transparent():
    w = jax.random.normal(jax.random.PRNGKey(5), (16, 8))
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 16))

    def loss(w):
        y = bp_matmul.dense_apply(x, w, "bf16+q8gather")
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(w)
    # STE: gradient equals the plain-path gradient through the dequantized
    # weight, evaluated at the quantized point — finite, nonzero, same shape
    assert g.shape == w.shape
    assert np.isfinite(np.asarray(g)).all() and float(jnp.abs(g).max()) > 0
    # forward value is the per-channel fake-quantized matmul
    y = bp_matmul.dense_apply(x, w, "bf16+q8gather")
    y_ref = bp_matmul.dense_apply(x, w, "bf16")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               atol=0.25, rtol=0.1)
