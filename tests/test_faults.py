"""Chaos suite: seeded fault injection against the serve loop.

The correctness anchor is the same as everywhere else in the serving
stack — TOKEN IDENTITY.  A run under a seeded fault schedule must produce,
for every request that still finishes normally, exactly the tokens of the
fault-free run: retries re-dispatch untouched steps, recoveries rebuild
the executor and replay token-exact, the NaN guard fails only the
poisoned slot, and cancellations/timeouts release every block they held.
"""

import numpy as np
import pytest
import jax

from repro.configs.base import get_arch
from repro.models import api
from repro.serving import (NULL_INJECTOR, FaultInjector, Request,
                           RequestState, SchedulerConfig, ServeConfig,
                           ServingEngine)

jax.config.update("jax_default_matmul_precision", "float32")

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _dense_cfg():
    return get_arch("qwen2-1.5b").reduced().replace(
        num_layers=2, d_model=64, d_ff=128, vocab_size=128, head_dim=16)


CFG = _dense_cfg()
_PARAMS = None


def _params():
    global _PARAMS
    if _PARAMS is None:
        _PARAMS = api.init(jax.random.PRNGKey(0), CFG)
    return _PARAMS


def _prompt(S, seed):
    return np.asarray(jax.random.randint(jax.random.PRNGKey(seed), (S,), 2,
                                         CFG.vocab_size), np.int32)


def _requests():
    """A small heterogeneous stream; index i is comparable across runs."""
    spec = [(6, 1, 8, 0.0), (6, 1, 5, 0.0),   # shared prompt: prefix hits
            (4, 2, 7, 1.0), (5, 3, 6, 2.0), (7, 4, 8, 4.0)]
    return [Request(prompt=_prompt(S, seed), max_new_tokens=m,
                    arrival_time=t) for S, seed, m, t in spec]


def _serve(backend="slab", draft="none", faults=None, num_blocks=None,
           lead_window=2, **cfg_over):
    cfg_kw = dict(max_new_tokens=8, temperature=0.0, cache_backend=backend,
                  block_size=4, draft=draft, num_draft_tokens=3,
                  faults=faults)
    cfg_kw.update(cfg_over)
    engine = ServingEngine(CFG, _params(), ServeConfig(**cfg_kw))
    reqs = _requests()
    loop = engine.make_loop(reqs, n_slots=2, num_blocks=num_blocks,
                            sched_cfg=SchedulerConfig(
                                lead_window=lead_window))
    report = loop.run()
    return report, loop, reqs


_BASELINES = {}


def _baseline(backend, draft):
    """Fault-free reference tokens, one serve per (backend, draft)."""
    key = (backend, draft)
    if key not in _BASELINES:
        report, _, _ = _serve(backend, draft)
        _BASELINES[key] = [list(r.tokens) for r in report.results]
    return _BASELINES[key]


def _tokens(report):
    return [list(r.tokens) for r in report.results]


def _assert_pool_drained(loop):
    """After the queue drains, the paged pool must be leak-free: no live
    blocks, free+cached partition covering everything but the trash
    block, zero refcounts."""
    if not loop.paged:
        return
    pool = loop.cm.pool
    assert pool.n_live == 0
    assert pool.n_free == pool.num_blocks - 1
    assert int(pool.refcount.sum()) == 0


def _injected_fault_records(loop):
    return [r for r in loop.stream
            if r["kind"] == "fault" and r.get("injected")]


# ---------------------------------------------------------------------------
# NULL_INJECTOR is a strict no-op
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend,draft",
                         [("slab", "none"), ("paged", "prompt_lookup")])
def test_null_injector_strict_noop(backend, draft):
    report, loop, _ = _serve(backend, draft, faults=NULL_INJECTOR)
    assert _tokens(report) == _baseline(backend, draft)
    assert not [r for r in loop.stream if r["kind"] == "fault"]
    assert report.n_injected_faults == 0 and report.n_recoveries == 0


# ---------------------------------------------------------------------------
# the chaos property: survivors are token-identical, resources leak-free,
# every injection visible in the stream
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("backend,draft",
                         [("slab", "none"), ("slab", "prompt_lookup"),
                          ("paged", "none"), ("paged", "prompt_lookup")])
def test_chaos_survivors_token_identical(backend, draft, seed):
    rates = {"step": 0.05, "prefill": 0.05, "oom": 0.03, "nan": 0.01,
             "cancel": 0.01}
    if backend == "paged":
        rates["pool"] = 0.05
    if draft != "none":
        rates["drafter"] = 0.10
    inj = FaultInjector(seed=seed, rates=rates, max_faults=8)
    report, loop, reqs = _serve(backend, draft, faults=inj,
                                max_step_retries=1, max_recoveries=20)
    base = _baseline(backend, draft)
    assert all(r.is_terminal for r in reqs)
    for i, res in enumerate(report.results):
        if res.finish_reason in ("eos", "length"):
            assert list(res.tokens) == base[i], (i, res.finish_reason)
        else:
            assert res.finish_reason in ("cancelled", "failed", "timeout")
            # partial streams never diverge before dying
            assert list(res.tokens) == base[i][:len(res.tokens)]
    _assert_pool_drained(loop)
    # the stream accounts for every single injection, 1:1
    assert len(_injected_fault_records(loop)) == len(inj.injected)
    assert report.n_injected_faults == len(inj.injected)


def test_chaos_same_seed_replays_identically():
    def once():
        inj = FaultInjector(seed=7, rates={"step": 0.1, "nan": 0.02,
                                           "pool": 0.05}, max_faults=6)
        report, _, _ = _serve("paged", "none", faults=inj,
                              max_step_retries=1, max_recoveries=20)
        return [(site, n) for site, n, _ in inj.injected], _tokens(report)
    assert once() == once()


# ---------------------------------------------------------------------------
# retry / recovery / watchdog
# ---------------------------------------------------------------------------

def test_retry_absorbs_transient_step_faults():
    inj = FaultInjector(schedule=[("step", 0), ("step", 1)])
    report, _, _ = _serve(faults=inj, max_step_retries=2)
    assert _tokens(report) == _baseline("slab", "none")
    assert report.n_retries == 2
    assert report.n_recoveries == 0


def test_recovery_rebuilds_and_replays():
    inj = FaultInjector(schedule=[("step", 1)])
    report, loop, _ = _serve(faults=inj, max_step_retries=0)
    assert _tokens(report) == _baseline("slab", "none")
    assert report.n_recoveries == 1
    kinds = [r["kind"] for r in loop.stream]
    assert "recover" in kinds
    # recovery preempted the actives: replay shows up as preempt records
    assert report.n_preemptions >= 1


def test_recovery_budget_exhausted_fails_inflight_and_returns():
    inj = FaultInjector(rates={"step": 1.0, "prefill": 1.0})
    report, loop, reqs = _serve(faults=inj, max_step_retries=0,
                                max_recoveries=2)
    # serve() RETURNED (no hang, no raise) with everything failed
    assert all(r.state is RequestState.FAILED for r in reqs)
    assert report.n_failed == len(reqs)
    assert any(r["kind"] == "degrade" and r["action"] == "abort"
               for r in loop.stream)
    _assert_pool_drained(loop)


def test_watchdog_aborts_stuck_step():
    # the budget must cover a post-recovery re-trace/re-compile of the
    # step fn, so it is generous; the injected spike is far beyond it
    inj = FaultInjector(rates={"slow": 1.0}, max_faults=1, slow_s=8.0)
    report, _, _ = _serve(faults=inj, step_timeout_s=2.5,
                          max_step_retries=0, max_recoveries=20)
    assert report.n_recoveries >= 1
    assert _tokens(report) == _baseline("slab", "none")


def test_real_executor_failure_recovers_as_step_fault(monkeypatch):
    # a genuine (non-injected) executor exception must be wrapped and
    # survive via the same rebuild-and-replay path
    report_ref, loop, reqs = (None, None, None)
    engine = ServingEngine(CFG, _params(), ServeConfig(max_new_tokens=8))
    loop = engine.make_loop(_requests(), n_slots=2,
                            sched_cfg=SchedulerConfig(lead_window=2))
    real_fn = loop._decode_fn
    state = {"fired": False}

    def boom(*a, **k):
        if not state["fired"]:
            state["fired"] = True
            raise ValueError("simulated XLA crash")
        return real_fn(*a, **k)

    loop._decode_fn = boom
    report = loop.run()
    assert state["fired"]
    assert report.n_recoveries == 1
    assert _tokens(report) == _baseline("slab", "none")
    # the real failure shows up as a non-injected fault record
    assert any(r["kind"] == "fault" and not r.get("injected")
               and "ValueError" in r.get("error", "")
               for r in loop.stream)


# ---------------------------------------------------------------------------
# NaN guard
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("draft", ["none", "prompt_lookup"])
def test_nan_guard_fails_only_the_poisoned_slot(draft):
    inj = FaultInjector(rates={"nan": 1.0}, max_faults=1)
    report, loop, _ = _serve(draft=draft, faults=inj)
    base = _baseline("slab", draft)
    failed = [r for r in report.results if r.finish_reason == "failed"]
    assert len(failed) == 1
    for i, res in enumerate(report.results):
        assert -1 not in list(res.tokens)
        if res.finish_reason == "failed":
            assert list(res.tokens) == base[i][:len(res.tokens)]
        else:
            assert list(res.tokens) == base[i]
    assert any(r["kind"] == "fault" and r.get("site") == "nan_guard"
               for r in loop.stream)
    assert loop.cm.n_active == 0


# ---------------------------------------------------------------------------
# degradation ladder
# ---------------------------------------------------------------------------

def test_repeated_drafter_faults_disable_speculation():
    inj = FaultInjector(rates={"drafter": 1.0})
    report, loop, _ = _serve(draft="prompt_lookup", faults=inj,
                             drafter_fault_limit=2)
    # draft-less verify steps commit the single greedy token: identity
    # against the PLAIN baseline (speculation is an optimization only)
    assert _tokens(report) == _baseline("slab", "none")
    assert loop.drafter is None
    assert any(r["kind"] == "degrade"
               and r["action"] == "disable_speculation"
               for r in loop.stream)
    assert report.n_degrades >= 1
    assert report.draft == "prompt_lookup"    # names what the run started with


def test_repeated_kernel_faults_fall_back_to_xla():
    inj = FaultInjector(schedule=[("step", 0), ("step", 2)])
    engine = ServingEngine(CFG, _params(), ServeConfig(
        max_new_tokens=8, faults=inj, max_step_retries=0,
        kernel_fault_limit=2))
    engine.executor.matmul_backend = "kernel_interpret"
    loop = engine.make_loop(_requests(), n_slots=2,
                            sched_cfg=SchedulerConfig(lead_window=2))
    report = loop.run()
    assert engine.executor.matmul_backend == "xla"
    assert any(r["kind"] == "degrade" and r["action"] == "xla_fallback"
               for r in loop.stream)
    assert _tokens(report) == _baseline("slab", "none")


def test_pool_pressure_shrinks_lead_window():
    inj = FaultInjector(rates={"pool": 0.6}, seed=3, max_faults=12)
    report, loop, _ = _serve("paged", faults=inj, max_step_retries=1,
                             max_recoveries=20, lead_window=4,
                             pool_pressure_limit=2)
    assert report.n_preemptions >= 2
    assert loop.sched.cfg.lead_window < 4
    assert any(r["kind"] == "degrade"
               and r["action"] == "shrink_lead_window"
               for r in loop.stream)
    assert _tokens(report) == _baseline("paged", "none")
    _assert_pool_drained(loop)


# ---------------------------------------------------------------------------
# cancellation + deadlines
# ---------------------------------------------------------------------------

def test_cancel_before_run_never_admits_the_request():
    engine = ServingEngine(CFG, _params(), ServeConfig(max_new_tokens=8))
    reqs = _requests()
    engine.cancel(reqs[3].request_id)
    loop = engine.make_loop(reqs, n_slots=2,
                            sched_cfg=SchedulerConfig(lead_window=2))
    report = loop.run()
    base = _baseline("slab", "none")
    assert reqs[3].state is RequestState.CANCELLED
    assert reqs[3].finish_reason == "cancelled"
    assert list(report.results[3].tokens) == []
    for i in (0, 1, 2, 4):
        assert list(report.results[i].tokens) == base[i]
    assert report.n_cancelled == 1
    recs = [r for r in loop.stream if r["kind"] == "cancel"]
    assert len(recs) == 1
    assert recs[0]["request_id"] == reqs[3].request_id


@pytest.mark.parametrize("backend", ["slab", "paged"])
def test_cancel_mid_decode_releases_all_blocks(backend):
    engine = ServingEngine(CFG, _params(), ServeConfig(
        max_new_tokens=8, cache_backend=backend, block_size=4))
    reqs = _requests()
    target = reqs[0]

    def hook(loop):
        if any(r is target for r in loop.active.values()):
            engine.cancel(target.request_id)

    loop = engine.make_loop(reqs, n_slots=2,
                            sched_cfg=SchedulerConfig(lead_window=2))
    loop.on_step_end = hook
    report = loop.run()
    base = _baseline(backend, "none")
    assert target.state is RequestState.CANCELLED
    assert 0 < len(report.results[0].tokens) < len(base[0])
    assert list(report.results[0].tokens) == base[0][:len(
        report.results[0].tokens)]
    for i in (1, 2, 3, 4):
        assert list(report.results[i].tokens) == base[i]
    assert loop.cm.n_active == 0
    _assert_pool_drained(loop)
    recs = [r for r in loop.stream if r["kind"] == "cancel"]
    assert [r["request_id"] for r in recs] == [target.request_id]
    assert recs[0]["where"] == "active"


def test_ttft_deadline_expires_waiting_request():
    engine = ServingEngine(CFG, _params(), ServeConfig(max_new_tokens=8))
    reqs = _requests()
    reqs[4].ttft_deadline_s = 0.0   # expires the moment it is submitted
    loop = engine.make_loop(reqs, n_slots=2,
                            sched_cfg=SchedulerConfig(lead_window=2))
    report = loop.run()
    assert reqs[4].state is RequestState.TIMED_OUT
    assert reqs[4].finish_reason == "timeout"
    assert report.n_timed_out == 1
    recs = [r for r in loop.stream if r["kind"] == "timeout"]
    assert len(recs) == 1 and recs[0]["deadline"] == "ttft"
    base = _baseline("slab", "none")
    for i in range(4):
        assert list(report.results[i].tokens) == base[i]


def test_total_deadline_expires_active_request():
    engine = ServingEngine(CFG, _params(), ServeConfig(
        max_new_tokens=8, cache_backend="paged", block_size=4))
    reqs = _requests()
    target = reqs[0]

    def hook(loop):
        if any(r is target for r in loop.active.values()):
            target.deadline_s = 0.0
            loop._any_deadlines = True

    loop = engine.make_loop(reqs, n_slots=2,
                            sched_cfg=SchedulerConfig(lead_window=2))
    loop.on_step_end = hook
    report = loop.run()
    assert target.state is RequestState.TIMED_OUT
    recs = [r for r in loop.stream if r["kind"] == "timeout"]
    assert recs and recs[0]["where"] == "active"
    assert recs[0]["deadline"] == "total"
    _assert_pool_drained(loop)


# ---------------------------------------------------------------------------
# rejection path (satellite): both rejection flavors emit exactly one
# reject record through the one central RequestQueue.reject funnel
# ---------------------------------------------------------------------------

def test_on_reject_emits_exactly_one_record_per_path():
    engine = ServingEngine(CFG, _params(), ServeConfig(max_new_tokens=8))
    ok = Request(prompt=_prompt(4, 1), max_new_tokens=2)
    over_capacity = Request(prompt=_prompt(4, 2), max_new_tokens=2)
    too_big = Request(prompt=_prompt(4, 3), max_new_tokens=64)
    loop = engine.make_loop([ok, over_capacity, too_big], n_slots=2,
                            cache_T=8,
                            sched_cfg=SchedulerConfig(max_waiting=1))
    report = loop.run()
    assert over_capacity.finish_reason == "rejected"
    assert too_big.finish_reason == "rejected"
    assert ok.finish_reason in ("eos", "length")
    recs = [r for r in loop.stream if r["kind"] == "reject"]
    assert sorted(r["request_id"] for r in recs) == sorted(
        [over_capacity.request_id, too_big.request_id])
    assert report.n_rejected == 2


# ---------------------------------------------------------------------------
# injector unit behavior (no jax)
# ---------------------------------------------------------------------------

def test_injector_schedule_and_ledger():
    inj = FaultInjector(schedule=[("step", 1)], rates={})
    assert not inj.fire("step")
    assert inj.fire("step")
    assert not inj.fire("step")
    assert [(s, n) for s, n, _ in inj.injected] == [("step", 1)]


def test_injector_max_faults_cap():
    inj = FaultInjector(rates={"step": 1.0}, max_faults=2)
    fires = [inj.fire("step") for _ in range(5)]
    assert fires == [True, True, False, False, False]


def test_injector_cancel_requests_dedups():
    inj = FaultInjector(rates={"cancel": 1.0})
    assert inj.cancel_requests([1, 2]) == [1, 2]
    assert inj.cancel_requests([1, 2, 3]) == [3]


def test_null_injector_has_no_side_effects():
    ledger0 = list(NULL_INJECTOR.injected)
    assert not NULL_INJECTOR.fire("step")
    NULL_INJECTOR.check("oom")
    NULL_INJECTOR.delay()
    assert NULL_INJECTOR.nan_slots([0, 1]) == []
    assert NULL_INJECTOR.cancel_requests([1]) == []
    assert list(NULL_INJECTOR.injected) == ledger0 == []


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(seed=st.integers(0, 2**16),
           rates=st.dictionaries(
               st.sampled_from(["step", "pool", "nan", "oom"]),
               st.floats(0.0, 1.0), max_size=4),
           n_checks=st.integers(0, 64))
    def test_injector_deterministic_replay(seed, rates, n_checks):
        """Property: a given (seed, rates, call sequence) replays the
        exact same fault schedule."""
        def trace():
            inj = FaultInjector(seed=seed, rates=rates)
            return [inj.fire(site) for site in
                    ["step", "pool", "nan", "oom"] * n_checks]
        assert trace() == trace()
