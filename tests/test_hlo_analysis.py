"""HLO analyzer: synthetic-text unit tests + a real compile integration test
that validates trip-count-aware FLOP counting against a closed form."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch import hlo_analysis as H

SYNTHETIC = """
HloModule test

%cond (p: (s32[], f32[4,8])) -> pred[] {
  %p = (s32[], f32[4,8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (p2: (s32[], f32[4,8])) -> (s32[], f32[4,8]) {
  %p2 = (s32[], f32[4,8]) parameter(0)
  %x = f32[4,8] get-tuple-element(%p2), index=1
  %w = f32[8,8] constant({...})
  %d = f32[4,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[4,32] all-gather(%d), dimensions={1}
  %i2 = s32[] get-tuple-element(%p2), index=0
  ROOT %t = (s32[], f32[4,8]) tuple(%i2, %d)
}

ENTRY %main (a: f32[4,8]) -> f32[4,8] {
  %a = f32[4,8] parameter(0)
  %w2 = f32[8,16] constant({...})
  %d0 = f32[4,16] dot(%a, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[4,16] all-reduce(%d0), to_apply=%cond
  %init = (s32[], f32[4,8]) tuple-thing()
  %wl = (s32[], f32[4,8]) while(%init), condition=%cond, body=%body
  ROOT %out = f32[4,8] get-tuple-element(%wl), index=1
}
"""


class TestSyntheticParse:
    def test_trip_count_multiplies_body(self):
        res = H.analyze(SYNTHETIC)
        # entry dot: 2*4*16*8 = 1024; body dot: 2*4*8*8 = 512, x7 trips
        assert res["dot_flops_per_device"] == 1024 + 7 * 512

    def test_collectives_weighted(self):
        res = H.analyze(SYNTHETIC)
        # all-gather in body: result 4*32*4B = 512B x 7
        assert res["collective_bytes"]["all-gather"] == 7 * 512
        # all-reduce at entry: operand 4*16*4 = 256B x 1
        assert res["collective_bytes"]["all-reduce"] == 256

    def test_loop_discovery(self):
        res = H.analyze(SYNTHETIC)
        assert any(l["trips"] == 7 for l in res["while_loops"])


@pytest.mark.slow
class TestRealCompile:
    def test_scan_flops_match_closed_form(self):
        L, d = 5, 32
        w = jnp.ones((L, d, d), jnp.float32)

        def f(x, w):
            def body(c, wl):
                return c @ wl, None
            y, _ = jax.lax.scan(body, x, w)
            return y

        compiled = jax.jit(f).lower(jnp.ones((8, d)), w).compile()
        res = H.analyze(compiled.as_text())
        want = L * 2 * 8 * d * d
        assert abs(res["dot_flops_per_device"] - want) / want < 0.01

    def test_nested_scan_multiplies(self):
        Lo, Li, d = 3, 4, 16
        w = jnp.ones((Lo, Li, d, d), jnp.float32)

        def f(x, w):
            def outer(c, wo):
                def inner(ci, wi):
                    return ci @ wi, None
                c2, _ = jax.lax.scan(inner, c, wo)
                return c2, None
            y, _ = jax.lax.scan(outer, x, w)
            return y

        compiled = jax.jit(f).lower(jnp.ones((4, d)), w).compile()
        res = H.analyze(compiled.as_text())
        want = Lo * Li * 2 * 4 * d * d
        assert abs(res["dot_flops_per_device"] - want) / want < 0.01
