"""Multi-device integration tests, run in subprocesses with 8 virtual CPU
devices (XLA_FLAGS must be set before jax init, so these cannot run in the
main pytest process — per the dry-run's own rule)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=600, env=env)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The sharded (2 data x 4 model) train step computes the same loss as
    single-device execution — the distribution layer is semantics-free."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs.base import get_arch
        from repro.distributed import sharding as shd
        from repro.models import api

        cfg = get_arch("qwen2-1.5b").reduced().replace(
            num_layers=2, d_model=64, d_ff=128, vocab_size=512, head_dim=16)
        params = api.init(jax.random.PRNGKey(0), cfg)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (4, 64), 0, 512)}
        ref, _ = api.loss_fn(params, cfg, batch)   # single device

        # version-portable mesh activation (jax<0.5 and >=0.5 alike)
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        with shd.activate_mesh(mesh):
            p_sh = shd.named_shardings(params, "train", mesh)
            params_s = jax.tree.map(jax.device_put, params, p_sh)
            b_sh = {"tokens": NamedSharding(mesh, P("data", None))}
            batch_s = jax.tree.map(jax.device_put, batch, b_sh)

            def step(p, b):
                with shd.recipe("train"):
                    return api.loss_fn(p, cfg, b)[0]
            got = jax.jit(step, in_shardings=(p_sh, b_sh))(params_s, batch_s)
        np.testing.assert_allclose(float(got), float(ref), rtol=2e-2)
        print("OK", float(got), float(ref))
    """)


@pytest.mark.slow
def test_elastic_checkpoint_reshard_across_meshes():
    """Save on a (4, 2) mesh, restore onto (2, 4) and single-device — the
    elastic path of the checkpoint manager."""
    _run("""
        import os, tempfile
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.checkpoint.checkpoint import CheckpointManager
        from repro.runtime.elastic import restore_for_mesh
        from repro.distributed.sharding import named_shardings

        tree = {"layers": {"w": jnp.arange(64.0).reshape(8, 8),
                           "b": jnp.ones((8,))}}
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d, async_save=False)

        devs = np.asarray(jax.devices())
        mesh_a = Mesh(devs.reshape(4, 2), ("data", "model"))
        sh_a = named_shardings(tree, "train", mesh_a)
        tree_a = jax.tree.map(jax.device_put, tree, sh_a)
        mgr.save(5, tree_a)

        mesh_b = Mesh(devs.reshape(2, 4), ("data", "model"))
        restored = restore_for_mesh(mgr, 5, tree, mesh_b, "train")
        np.testing.assert_array_equal(np.asarray(restored["layers"]["w"]),
                                      np.asarray(tree["layers"]["w"]))
        # and plain single-device restore
        plain = mgr.restore(5, tree)
        np.testing.assert_array_equal(np.asarray(plain["layers"]["b"]),
                                      np.asarray(tree["layers"]["b"]))
        print("OK elastic")
    """)


@pytest.mark.slow
def test_dryrun_cell_on_virtual_devices():
    """A reduced-size dry-run cell (lower+compile+HLO analysis) on a small
    virtual mesh — exercises the exact plumbing of launch/dryrun.py."""
    _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs.base import get_arch
        from repro.distributed import sharding as shd
        from repro.launch import hlo_analysis
        from repro.models import api
        from repro.train import optimizer as opt_lib

        cfg = get_arch("granite-moe-1b-a400m").reduced()
        mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4),
                    ("data", "model"))
        with shd.activate_mesh(mesh):
            specs = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
            p_specs = jax.eval_shape(partial(api.init, cfg=cfg),
                                     jax.random.PRNGKey(0))
            o_specs = jax.eval_shape(opt_lib.init_state, p_specs)
            p_sh = shd.named_shardings(p_specs, "train", mesh)
            o_sh = shd.named_shardings(o_specs, "train", mesh)
            b_sh = {"tokens": NamedSharding(mesh, P("data", None))}
            ocfg = opt_lib.OptimizerConfig()

            def train_step(p, o, b):
                with shd.recipe("train"):
                    (l, m), g = jax.value_and_grad(
                        lambda pp: api.loss_fn(pp, cfg, b), has_aux=True)(p)
                    p, o, _ = opt_lib.apply_updates(ocfg, p, o, g)
                    return p, o, l

            fn = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1))
            compiled = fn.lower(p_specs, o_specs, specs).compile()
            res = hlo_analysis.analyze(compiled.as_text())
            assert res["dot_flops_per_device"] > 0
            ma = compiled.memory_analysis()
            # peak_memory_in_bytes only exists on newer jaxlib
            peak = getattr(ma, "peak_memory_in_bytes", None)
            if peak is None:
                peak = (ma.temp_size_in_bytes + ma.argument_size_in_bytes
                        + ma.output_size_in_bytes)
            assert peak > 0
            print("OK dryrun-mini", res["dot_flops_per_device"])
    """)
